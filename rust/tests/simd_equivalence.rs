//! SIMD kernel-table equivalence suite (DESIGN.md §18).
//!
//! Two equivalence classes, tested separately:
//!
//! * **bitwise** — the batched forward sweep kernels (`cascade_row`,
//!   `dprr_row`, `dprr_bias`) preserve each lane's scalar op order
//!   exactly, so the AVX2 table must reproduce the scalar table (and
//!   the per-call `Reservoir::forward`) bit for bit at every batch
//!   size, ragged mixes and frozen lanes included;
//! * **tolerance-bounded** — the Gram/axpy/dot kernels reassociate and
//!   use FMA, so they are pinned within a standard floating-point
//!   accumulation bound (γ_n · Σ|terms|) instead.
//!
//! AVX2-dependent tests skip with a note on hosts without AVX2+FMA; the
//! typed `--simd force` error path runs everywhere (the detection
//! result is injected through `Kernels::try_select_with`).

use dfr_edge::coordinator::{
    scores_from_r_tilde_with, Engine, FeatureRequest, NativeEngine, ReservoirUpdate,
};
use dfr_edge::data::dataset::Sample;
use dfr_edge::data::npz;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{BatchLane, BatchScratch, Nonlinearity, Reservoir};
use dfr_edge::quant::QuantEngine;
use dfr_edge::simd::{avx2_available, Kernels, SimdError, SimdMode};
use dfr_edge::util::prng::Pcg32;

/// The AVX2 table, or `None` (with a skip note) on hosts that cannot
/// run it — mirrors how CI forces the table only where supported.
fn avx2_table(test: &str) -> Option<Kernels> {
    match Kernels::try_select(SimdMode::Force) {
        Ok(k) => Some(k),
        Err(e) => {
            eprintln!("{test}: skipped — {e}");
            None
        }
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x} vs {y} differ in bits"
        );
    }
}

/// Per-lane workload generator shared by the bitwise batch tests:
/// ragged lengths, per-lane masks/(p, q) — everything the batch
/// contract allows to vary.
struct Lanes {
    us: Vec<Vec<f32>>,
    ts: Vec<usize>,
    masks: Vec<Mask>,
    ps: Vec<f32>,
    qs: Vec<f32>,
}

impl Lanes {
    fn random(rng: &mut Pcg32, b: usize, nx: usize) -> Lanes {
        let mut l = Lanes {
            us: Vec::with_capacity(b),
            ts: Vec::with_capacity(b),
            masks: Vec::with_capacity(b),
            ps: Vec::with_capacity(b),
            qs: Vec::with_capacity(b),
        };
        for _ in 0..b {
            let v = 1 + rng.below(3) as usize;
            let t = 1 + rng.below(24) as usize;
            l.us.push((0..t * v).map(|_| 2.0 * (rng.uniform() - 0.5)).collect());
            l.ts.push(t);
            l.masks.push(Mask::random(nx, v, rng));
            l.ps.push(0.1 + 0.5 * rng.uniform());
            let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            l.qs.push(sign * 0.4 * rng.uniform());
        }
        l
    }

    fn lane(&self, l: usize) -> BatchLane<'_> {
        BatchLane {
            u: &self.us[l],
            t: self.ts[l],
            mask: &self.masks[l],
            p: self.ps[l],
            q: self.qs[l],
        }
    }
}

// ---------------------------------------------------------------------------
// bitwise class: the batched forward sweep
// ---------------------------------------------------------------------------

#[test]
fn batched_forward_bitwise_scalar_vs_avx2_across_batch_sizes() {
    let Some(vk) = avx2_table("batched_forward_bitwise_scalar_vs_avx2_across_batch_sizes")
    else {
        return;
    };
    let sk = Kernels::scalar();
    let mut rng = Pcg32::seed(0x51D0_0001);
    let nx = 7;
    // Tanh exercises the scalar-libm round-trip lanes; Mackey–Glass
    // (p_exp = 2) exercises the vectorized mul/div op chain.
    for f in [
        Nonlinearity::Tanh,
        Nonlinearity::MackeyGlass { eta: 0.9, p_exp: 2.0 },
    ] {
        for &b in &[1usize, 2, 7, 8, 9, 64] {
            let lanes = Lanes::random(&mut rng, b, nx);
            let mut sc_s = BatchScratch::new();
            let mut sc_v = BatchScratch::new();
            sc_s.forward_batch_into_with(f, b, |l| lanes.lane(l), &sk);
            sc_v.forward_batch_into_with(f, b, |l| lanes.lane(l), &vk);
            for l in 0..b {
                let a = sc_s.lane(l);
                let c = sc_v.lane(l);
                let tag = format!("{f:?} b={b} lane {l}");
                assert_eq!(a.t_len, c.t_len, "{tag}: t_len");
                assert_bits_eq(a.r_mat, c.r_mat, &format!("{tag}: r_mat"));
                assert_bits_eq(a.x_t, c.x_t, &format!("{tag}: x_t"));
                assert_bits_eq(a.x_tm1, c.x_tm1, &format!("{tag}: x_tm1"));
                assert_bits_eq(a.j_t, c.j_t, &format!("{tag}: j_t"));
                // and both equal the per-call reference forward
                let res = Reservoir {
                    mask: lanes.masks[l].clone(),
                    p: lanes.ps[l],
                    q: lanes.qs[l],
                    f,
                };
                let fwd = res.forward(&lanes.us[l], lanes.ts[l]);
                assert_bits_eq(c.r_mat, &fwd.r_mat, &format!("{tag}: vs per-call r_mat"));
                assert_bits_eq(c.x_t, &fwd.x_t, &format!("{tag}: vs per-call x_t"));
                assert_bits_eq(c.x_tm1, &fwd.x_tm1, &format!("{tag}: vs per-call x_tm1"));
                assert_bits_eq(c.j_t, &fwd.j_t, &format!("{tag}: vs per-call j_t"));
            }
        }
    }
}

/// Frozen lanes must be *blended*, not add-zeroed: a stored `-0.0`
/// keeps its sign bit through a frozen step under both tables. Driven
/// at the kernel level with frozen lanes in the 8-wide vector body AND
/// in the scalar tail (b = 19).
#[test]
fn frozen_lanes_preserve_negative_zero_bits() {
    let Some(vk) = avx2_table("frozen_lanes_preserve_negative_zero_bits") else {
        return;
    };
    let sk = Kernels::scalar();
    let mut rng = Pcg32::seed(0x51D0_0002);
    let b = 19; // 16 vector lanes + 3 tail lanes
    let frozen = [3usize, 5, 17]; // body, body, tail
    let mut active = vec![u32::MAX; b];
    for &l in &frozen {
        active[l] = 0;
    }
    let mk = |rng: &mut Pcg32| -> Vec<f32> {
        (0..b).map(|_| 2.0 * (rng.uniform() - 0.5)).collect()
    };

    // cascade_row: frozen x and cascade keep their exact old bits
    let mut x_s = mk(&mut rng);
    let mut cas_s = mk(&mut rng);
    for &l in &frozen {
        x_s[l] = -0.0;
        cas_s[l] = -0.0;
    }
    let j = mk(&mut rng);
    let ps = mk(&mut rng);
    let qs = mk(&mut rng);
    let (mut x_v, mut cas_v) = (x_s.clone(), cas_s.clone());
    (sk.cascade_row)(Nonlinearity::Tanh, &ps, &qs, &mut x_s, &j, &mut cas_s, &active);
    (vk.cascade_row)(Nonlinearity::Tanh, &ps, &qs, &mut x_v, &j, &mut cas_v, &active);
    assert_bits_eq(&x_s, &x_v, "cascade_row x");
    assert_bits_eq(&cas_s, &cas_v, "cascade_row cascade");
    for &l in &frozen {
        assert!(
            x_v[l] == 0.0 && x_v[l].is_sign_negative(),
            "frozen lane {l} lost its -0.0 ({})",
            x_v[l]
        );
    }

    // dprr_row / dprr_bias: frozen accumulators keep their old bits
    let mut acc_s = mk(&mut rng);
    for &l in &frozen {
        acc_s[l] = -0.0;
    }
    let xi = mk(&mut rng);
    let xm = mk(&mut rng);
    let mut acc_v = acc_s.clone();
    (sk.dprr_row)(&mut acc_s, &xi, &xm, &active);
    (vk.dprr_row)(&mut acc_v, &xi, &xm, &active);
    assert_bits_eq(&acc_s, &acc_v, "dprr_row acc");
    let mut bias_s = acc_s.clone();
    let mut bias_v = acc_v.clone();
    (sk.dprr_bias)(&mut bias_s, &xi, &active);
    (vk.dprr_bias)(&mut bias_v, &xi, &active);
    assert_bits_eq(&bias_s, &bias_v, "dprr_bias acc");
    for &l in &frozen {
        assert!(
            acc_v[l] == 0.0 && acc_v[l].is_sign_negative(),
            "frozen acc lane {l} lost its -0.0 ({})",
            acc_v[l]
        );
    }
}

// ---------------------------------------------------------------------------
// tolerance class: Gram / axpy / dot
// ---------------------------------------------------------------------------

/// Accumulation-error budget for an n-term f32 sum whose terms have
/// absolute-value total `abs_sum`: both orderings satisfy the textbook
/// |fl(Σ) − Σ| ≤ γ_n·Σ|t_i| bound, so their difference is within twice
/// that (doubled again for headroom — failures we care about are ULP
/// blowups, not 2× constants).
fn accum_tol(n: usize, abs_sum: f32) -> f32 {
    4.0 * n as f32 * f32::EPSILON * abs_sum + 1e-12
}

#[test]
fn gram_rankk_avx2_within_accumulation_tolerance() {
    let Some(vk) = avx2_table("gram_rankk_avx2_within_accumulation_tolerance") else {
        return;
    };
    let sk = Kernels::scalar();
    let mut rng = Pcg32::seed(0x51D0_0003);
    for &s in &[1usize, 3, 8, 13, 40] {
        for &bs in &[1usize, 4, 8, 9, 32] {
            let tri = s * (s + 1) / 2;
            let init: Vec<f32> = (0..tri).map(|_| rng.uniform() - 0.5).collect();
            let rs: Vec<f32> = (0..bs * s).map(|_| 2.0 * (rng.uniform() - 0.5)).collect();
            let mut p_s = init.clone();
            let mut p_v = init;
            (sk.gram_rankk)(&mut p_s, &rs, s);
            (vk.gram_rankk)(&mut p_v, &rs, s);
            let mut idx = 0;
            for i in 0..s {
                for j in 0..=i {
                    let abs_sum: f32 = (0..bs)
                        .map(|b| (rs[b * s + i] * rs[b * s + j]).abs())
                        .sum::<f32>()
                        + p_s[idx].abs();
                    let tol = accum_tol(bs + 1, abs_sum);
                    assert!(
                        (p_s[idx] - p_v[idx]).abs() <= tol,
                        "s={s} B={bs} P[{i},{j}]: scalar {} vs avx2 {} (tol {tol})",
                        p_s[idx],
                        p_v[idx]
                    );
                    idx += 1;
                }
            }
        }
    }
}

#[test]
fn axpy_and_dot_avx2_within_accumulation_tolerance() {
    let Some(vk) = avx2_table("axpy_and_dot_avx2_within_accumulation_tolerance") else {
        return;
    };
    let sk = Kernels::scalar();
    let mut rng = Pcg32::seed(0x51D0_0004);
    for &n in &[1usize, 3, 7, 8, 9, 11, 64, 931] {
        let a = 2.0 * (rng.uniform() - 0.5);
        let x: Vec<f32> = (0..n).map(|_| 2.0 * (rng.uniform() - 0.5)).collect();
        let y: Vec<f32> = (0..n).map(|_| 2.0 * (rng.uniform() - 0.5)).collect();

        // axpy: per element one FMA vs mul+round+add — at most one
        // extra rounding of each term
        let mut row_s = y.clone();
        let mut row_v = y.clone();
        (sk.axpy)(&mut row_s, a, &x);
        (vk.axpy)(&mut row_v, a, &x);
        for j in 0..n {
            let tol = accum_tol(2, (a * x[j]).abs() + y[j].abs());
            assert!(
                (row_s[j] - row_v[j]).abs() <= tol,
                "axpy n={n} [{j}]: {} vs {} (tol {tol})",
                row_s[j],
                row_v[j]
            );
        }

        // dot: fully reassociated n-term reduction
        let d_s = (sk.dot)(&x, &y);
        let d_v = (vk.dot)(&x, &y);
        let abs_sum: f32 = x.iter().zip(&y).map(|(p, q)| (p * q).abs()).sum();
        let tol = accum_tol(n, abs_sum);
        assert!(
            (d_s - d_v).abs() <= tol,
            "dot n={n}: {d_s} vs {d_v} (tol {tol})"
        );
    }
}

// ---------------------------------------------------------------------------
// selection: the --simd force error path (runs on every host)
// ---------------------------------------------------------------------------

#[test]
fn force_without_avx2_is_a_typed_error() {
    // detection injected false: the deterministic seam the CLI error
    // path rides on hosts that DO have AVX2
    let err = Kernels::try_select_with(SimdMode::Force, false)
        .expect_err("force without detection must not hand out a vector table");
    match &err {
        SimdError::Unsupported { wanted, .. } => assert_eq!(*wanted, "avx2+fma"),
        other => panic!("expected Unsupported, got {other:?}"),
    }
    // the operator-facing message names the flag and the ways out
    let msg = err.to_string();
    assert!(msg.contains("--simd force"), "{msg}");
    assert!(msg.contains("off"), "{msg}");

    // live detection agrees with the injected seam on this host
    match Kernels::try_select(SimdMode::Force) {
        Ok(k) => {
            assert!(avx2_available());
            assert_eq!(k.name, "avx2");
        }
        Err(e) => {
            assert!(!avx2_available());
            assert!(matches!(e, SimdError::Unsupported { .. }), "{e:?}");
        }
    }

    // Off never fails, anywhere
    assert_eq!(
        Kernels::try_select_with(SimdMode::Off, true).unwrap().name,
        "scalar"
    );
    // and a bad --simd value is the other typed error
    let bad = SimdMode::parse("neon").expect_err("unknown mode must not parse");
    assert!(matches!(bad, SimdError::BadMode(_)), "{bad:?}");
    assert!(bad.to_string().contains("force|off|auto"), "{bad}");
}

// ---------------------------------------------------------------------------
// cross-backend golden-fixture equivalence
// ---------------------------------------------------------------------------

fn golden(name: &str) -> std::collections::BTreeMap<String, npz::Array> {
    let path = format!("artifacts/golden/{name}.npz");
    npz::read_npz(&path).unwrap_or_else(|e| panic!("golden fixture {path}: {e:#}"))
}

/// Every serving backend must agree on the committed golden workloads:
/// the scalar-table native engine (batched AND per-call), the AVX2
/// native engine where the host supports it (bitwise — forward kernels
/// are in the bitwise class), and the quant engine in its f32 fallback
/// (which routes through the same native datapath).
#[test]
fn cross_backend_agreement_on_golden_fixtures() {
    let f = Nonlinearity::Linear { alpha: 1.0 };
    let vk = Kernels::try_select(SimdMode::Force).ok();
    if vk.is_none() {
        eprintln!("cross_backend_agreement_on_golden_fixtures: no AVX2 — scalar/quant legs only");
    }
    for name in ["small", "padded", "paper_nx30"] {
        let g = golden(name);
        let t = g["length"].scalar().unwrap() as usize;
        let v = g["v"].scalar().unwrap() as usize;
        let nx = g["nx"].scalar().unwrap() as usize;
        let c = g["c"].scalar().unwrap() as usize;
        let p = g["p"].scalar().unwrap();
        let q = g["q"].scalar().unwrap();
        let u = Mask::golden_inputs(g["t"].scalar().unwrap() as usize, v);
        let mask = Mask::golden(nx, v);

        // a ragged batch of prefixes of the fixture series
        let ts = [t, t.max(2) - 1, (t / 2).max(1), t];
        let samples: Vec<Sample> = ts
            .iter()
            .map(|&tl| Sample {
                u: u[..tl * v].to_vec(),
                t: tl,
                label: 0,
            })
            .collect();
        let reqs: Vec<FeatureRequest<'_>> = samples
            .iter()
            .map(|s| FeatureRequest { sample: s, mask: &mask, p, q })
            .collect();
        let b = reqs.len();

        let eng_scalar = NativeEngine::with_kernels(nx, c, f, Kernels::scalar());
        let mut feats = vec![Vec::new(); b];
        eng_scalar.features_batch_into(&reqs, &mut feats).unwrap();
        // batched == per-call, bitwise
        for (l, s) in samples.iter().enumerate() {
            let per_call = eng_scalar.features(s, &mask, p, q).unwrap();
            assert_bits_eq(&feats[l], &per_call, &format!("{name} lane {l}: scalar"));
        }

        // AVX2 native engine: bitwise-equal features
        if let Some(k) = vk {
            let eng_simd = NativeEngine::with_kernels(nx, c, f, k);
            let mut feats_v = vec![Vec::new(); b];
            eng_simd.features_batch_into(&reqs, &mut feats_v).unwrap();
            for l in 0..b {
                assert_bits_eq(
                    &feats_v[l],
                    &feats[l],
                    &format!("{name} lane {l}: avx2 vs scalar"),
                );
            }
        }

        // quant engine, pushed into its f32 fallback (p·L_f + |q| ≥ 1
        // → +∞ bound): serving IS the native datapath
        let quant = QuantEngine::new(nx, c);
        let r = quant
            .recalibrate(&ReservoirUpdate {
                p: 0.8,
                q: 0.5,
                n_v: v,
                t_max: t,
                u_max: 2.0,
            })
            .unwrap();
        assert!(r.fell_back, "{name}: fallback recipe stopped working");
        assert!(quant.is_fallback());
        let mut feats_q = vec![Vec::new(); b];
        quant.features_batch_into(&reqs, &mut feats_q).unwrap();
        for l in 0..b {
            assert_bits_eq(
                &feats_q[l],
                &feats[l],
                &format!("{name} lane {l}: quant-fallback vs scalar"),
            );
        }

        // scoring: scalar-table scores are bitwise the per-call infer;
        // vector-table scores agree within the dot reduction budget
        let sdim = feats[0].len();
        let w_tilde: Vec<f32> = (0..c * sdim)
            .map(|i| 0.01 * (0.05 * i as f32).sin())
            .collect();
        for (l, s) in samples.iter().enumerate() {
            let mut z = Vec::new();
            scores_from_r_tilde_with(&w_tilde, &feats[l], &mut z, &Kernels::scalar());
            let per_call = eng_scalar.infer(s, &mask, p, q, &w_tilde).unwrap();
            assert_bits_eq(&z, &per_call, &format!("{name} lane {l}: scalar scores"));
            if let Some(k) = vk {
                let mut zv = Vec::new();
                scores_from_r_tilde_with(&w_tilde, &feats[l], &mut zv, &k);
                for (i, (a, bb)) in z.iter().zip(&zv).enumerate() {
                    assert!(
                        (a - bb).abs() <= 1e-5,
                        "{name} lane {l} score {i}: {a} vs {bb}"
                    );
                }
            }
            // quant fallback infer rides whatever table its inner
            // native engine selected (env-dependent under DFR_SIMD), so
            // the cross-check is tolerance-bounded, not bitwise
            let zq = quant.infer(s, &mask, p, q, &w_tilde).unwrap();
            for (i, (a, bb)) in z.iter().zip(&zq).enumerate() {
                assert!(
                    (a - bb).abs() <= 1e-5,
                    "{name} lane {l} quant score {i}: {a} vs {bb}"
                );
            }
        }
    }
}
