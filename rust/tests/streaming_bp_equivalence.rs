//! Streaming-vs-batch BPTT equivalence: a [`StreamingBpTrainer`] driven
//! one sample at a time, in exactly the order the batch `sgd_phase`
//! would shuffle, must reproduce the batch trajectory **bit for bit** —
//! same final (p, q), same per-epoch loss trace, same output layer, and
//! (with plateau stopping enabled) the same stopping point.
//!
//! `sgd_phase` is a thin wrapper over the trainer since the extraction,
//! so this pins the wrapper's epoch loop (decay-before-shuffle ordering,
//! shared RNG stream, stop condition) against an independent driver.
//! Run in CI in both debug and release (a named release step): f32
//! trajectory identity must hold at every opt level.

use dfr_edge::data::dataset::Dataset;
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::optim::{OptimConfig, StreamingBpTrainer};
use dfr_edge::dfr::train::{sgd_phase, TrainConfig};
use dfr_edge::util::prng::Pcg32;

fn dataset() -> Dataset {
    let prof = Profile {
        name: "mini",
        n_v: 3,
        n_c: 3,
        train: 40,
        test: 10,
        t_min: 12,
        t_max: 18,
    };
    synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.4,
            freq_sep: 0.12,
            ar: 0.4,
        },
        11,
    )
}

fn config() -> TrainConfig {
    TrainConfig {
        nx: 10,
        epochs: 10,
        res_decay_epochs: vec![3, 6],
        out_decay_epochs: vec![4, 7],
        ..Default::default()
    }
}

/// Drive the trainer exactly as `sgd_phase` does: decay at epoch start,
/// one shuffle per epoch from the same RNG stream, stop on the same
/// condition.
fn drive_streaming(
    ds: &Dataset,
    cfg: &TrainConfig,
    mask: Mask,
    rng: &mut Pcg32,
) -> StreamingBpTrainer {
    let mut tr = StreamingBpTrainer::new(
        mask,
        cfg.f,
        cfg.p_init,
        cfg.q_init,
        ds.n_c,
        OptimConfig::from(cfg),
    );
    let mut order: Vec<usize> = (0..ds.train.len()).collect();
    while !tr.stopped() {
        tr.begin_epoch();
        rng.shuffle(&mut order);
        for &i in &order {
            tr.step(&ds.train[i]);
        }
        tr.end_epoch();
    }
    tr
}

#[test]
fn streaming_trainer_reproduces_sgd_phase_bit_for_bit() {
    let ds = dataset();
    let cfg = config();
    let mut rng = Pcg32::seed(0xB17);
    let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);

    let (res_b, out_b, losses_b) = sgd_phase(&ds, &cfg, mask.clone(), &mut Pcg32::seed(0x0D1));
    let tr = drive_streaming(&ds, &cfg, mask, &mut Pcg32::seed(0x0D1));

    // exact f32 equality — not tolerances: the two paths must execute
    // the identical operation sequence
    assert_eq!(tr.reservoir().p, res_b.p, "final p diverged");
    assert_eq!(tr.reservoir().q, res_b.q, "final q diverged");
    assert_eq!(tr.epoch_losses(), &losses_b[..], "loss trace diverged");
    assert_eq!(tr.output().w, out_b.w, "output weights diverged");
    assert_eq!(tr.output().b, out_b.b, "output bias diverged");
    assert_eq!(tr.epoch_losses().len(), cfg.epochs);
    // sanity: this is a real trajectory, not a frozen one
    assert!(
        (res_b.p - cfg.p_init).abs() > 1e-6 || (res_b.q - cfg.q_init).abs() > 1e-6,
        "(p, q) never moved — vacuous equivalence"
    );
}

#[test]
fn plateau_stopping_point_is_identical() {
    let ds = dataset();
    // min_delta so large only the first epoch counts as an improvement:
    // both paths must stop after exactly 1 + patience epochs
    let cfg = TrainConfig {
        plateau_patience: Some(3),
        plateau_min_delta: 1e9,
        epochs: 25,
        ..config()
    };
    let mut rng = Pcg32::seed(0xB18);
    let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);

    let (res_b, _, losses_b) = sgd_phase(&ds, &cfg, mask.clone(), &mut Pcg32::seed(0x0D2));
    let tr = drive_streaming(&ds, &cfg, mask, &mut Pcg32::seed(0x0D2));

    assert_eq!(losses_b.len(), 4, "batch path must stop at 1 + patience");
    assert_eq!(tr.epoch_losses().len(), losses_b.len(), "stopping point diverged");
    assert_eq!(tr.epoch_losses(), &losses_b[..]);
    assert_eq!(tr.reservoir().p, res_b.p);
    assert_eq!(tr.reservoir().q, res_b.q);
}

#[test]
fn feed_order_matters_for_the_trajectory() {
    // negative control: a different sample order produces a different
    // trajectory, so the bit-for-bit assertions above are discriminating
    let ds = dataset();
    let cfg = config();
    let mut rng = Pcg32::seed(0xB19);
    let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);
    let (res_a, _, _) = sgd_phase(&ds, &cfg, mask.clone(), &mut Pcg32::seed(1));
    let (res_b, _, _) = sgd_phase(&ds, &cfg, mask, &mut Pcg32::seed(2));
    assert!(
        res_a.p != res_b.p || res_a.q != res_b.q,
        "shuffle seed had no effect — the equivalence test would be vacuous"
    );
}
