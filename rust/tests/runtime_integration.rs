//! Integration tests over the PJRT runtime + coordinator: the artifact
//! path (JAX/Pallas-lowered HLO executed by the Rust binary) must agree
//! with the pure-Rust reference numerically, and the coordinator must
//! train and serve through it end to end.
//!
//! Requires `make artifacts`; every test skips with a notice otherwise.
//! PJRT's CPU client is process-global, so all tests share one executor
//! behind a OnceLock.

use dfr_edge::coordinator::{NativeEngine, PjrtEngine, Request, Response, Server, ServerConfig, SessionConfig};
use dfr_edge::data::dataset::Sample;
use dfr_edge::data::{profiles::Profile, synth};
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};
use dfr_edge::runtime::executor::TrainState;
use dfr_edge::runtime::{DfrExecutor, Manifest};
use dfr_edge::util::prng::Pcg32;

// The xla crate's client is Rc-based (!Sync), so each test builds its own
// executor (compilation of the five jpvow entry points is ~1 s).
fn executor() -> Option<DfrExecutor> {
    let manifest = Manifest::load("artifacts").ok()?;
    let prof = manifest.profile("jpvow").ok()?;
    match DfrExecutor::new(prof) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e:#}");
            None
        }
    }
}

macro_rules! require_artifacts {
    () => {
        match executor() {
            Some(e) => e,
            None => {
                eprintln!("skipped: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn jpvow_sample(seed: u64, t: usize) -> Sample {
    let mut rng = Pcg32::seed(seed);
    Sample {
        u: (0..t * 12).map(|_| rng.normal()).collect(),
        t,
        label: (seed % 9) as usize,
    }
}

fn jpvow_mask(seed: u64) -> Mask {
    Mask::random(30, 12, &mut Pcg32::seed(seed))
}

#[test]
fn forward_matches_native_reference() {
    let exec = require_artifacts!();
    let mask = jpvow_mask(1);
    for (seed, t) in [(1u64, 29usize), (2, 7), (3, 15)] {
        let s = jpvow_sample(seed, t);
        let (p, q) = (0.21f32, 0.13f32);
        let out = exec.forward(&s, &mask, p, q).expect("pjrt forward");
        let res = Reservoir {
            mask: mask.clone(),
            p,
            q,
            f: Nonlinearity::Linear { alpha: 1.0 },
        };
        let native = res.forward(&s.u, s.t);
        assert_close(&out.r_mat, &native.r_mat, 2e-3, "r_mat t={t}");
        assert_close(&out.x_t, &native.x_t, 1e-4, "x_t");
        assert_close(&out.x_tm1, &native.x_tm1, 1e-4, "x_tm1");
        assert_close(&out.j_t, &native.j_t, 1e-4, "j_t");
    }
}

#[test]
fn features_match_native_r_tilde() {
    let exec = require_artifacts!();
    let mask = jpvow_mask(2);
    let s = jpvow_sample(5, 20);
    let feats = exec.features(&s, &mask, 0.15, 0.1).unwrap();
    let res = Reservoir {
        mask: mask.clone(),
        p: 0.15,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let native = res.forward(&s.u, s.t).r_tilde();
    assert_eq!(feats.len(), 931);
    assert_close(&feats, &native, 2e-3, "features");
    assert_eq!(*feats.last().unwrap(), 1.0);
}

#[test]
fn train_step_matches_native_engine() {
    use dfr_edge::coordinator::Engine;
    let exec = require_artifacts!();
    let mask = jpvow_mask(3);
    let s = jpvow_sample(7, 25);

    let mut st_p = TrainState::init(9, 30, 0.1, 0.1);
    // seed W so reservoir grads are nonzero
    let mut rng = Pcg32::seed(11);
    for w in st_p.w.iter_mut() {
        *w = 0.01 * rng.normal();
    }
    let mut st_n = st_p.clone();

    let native = NativeEngine::new(30, 9);
    let loss_p = exec
        .train_step(&s, &mask, &mut st_p, 0.05, 0.05)
        .expect("pjrt train_step");
    let loss_n = native
        .train_step(&s, &mask, &mut st_n, 0.05, 0.05)
        .unwrap();

    assert!((loss_p - loss_n).abs() < 2e-3 * loss_n.abs().max(1.0), "{loss_p} vs {loss_n}");
    assert!((st_p.p - st_n.p).abs() < 1e-4, "{} vs {}", st_p.p, st_n.p);
    assert!((st_p.q - st_n.q).abs() < 1e-4, "{} vs {}", st_p.q, st_n.q);
    assert_close(&st_p.b, &st_n.b, 1e-4, "b");
    // W is large; spot-check norm agreement
    let norm = |v: &[f32]| v.iter().map(|x| x * x).sum::<f32>().sqrt();
    let (np_, nn) = (norm(&st_p.w), norm(&st_n.w));
    assert!((np_ - nn).abs() < 2e-3 * nn.max(1.0), "{np_} vs {nn}");
}

#[test]
fn stream_step_chain_matches_forward() {
    let exec = require_artifacts!();
    let mask = jpvow_mask(4);
    let s = jpvow_sample(9, 12);
    let (p, q) = (0.2f32, 0.15f32);
    let mut x = vec![0.0f32; 30];
    for k in 0..s.t {
        x = exec.step(&x, s.row(k, 12), &mask, p, q).unwrap();
    }
    let fwd = exec.forward(&s, &mask, p, q).unwrap();
    assert_close(&x, &fwd.x_t, 1e-4, "streamed x_t");
}

#[test]
fn infer_probabilities_sum_to_one() {
    let exec = require_artifacts!();
    let mask = jpvow_mask(5);
    let s = jpvow_sample(11, 18);
    let mut rng = Pcg32::seed(13);
    let w_tilde: Vec<f32> = (0..9 * 931).map(|_| 0.01 * rng.normal()).collect();
    let y = exec.infer(&s, &mask, 0.2, 0.1, &w_tilde).unwrap();
    assert_eq!(y.len(), 9);
    assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-4);
}

#[test]
fn coordinator_end_to_end_over_pjrt() {
    // build a fresh executor for the server (it takes ownership)
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let prof_art = manifest.profile("jpvow").unwrap();
    let exec = match DfrExecutor::new(prof_art) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipped: {e:#}");
            return;
        }
    };
    let profile = Profile::by_name("jpvow").unwrap();
    let ds = synth::generate(profile, 42);

    // small online run: 60 collected samples, 3 epochs
    let mut scfg = SessionConfig::new(12, 9, 60);
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    let srv = Server::spawn(
        Box::new(PjrtEngine::new(exec)),
        ServerConfig {
            queue_cap: 128,
            seed: 7,
            // PJRT replicas recompile the artifacts per shard; keep the
            // smoke test single-shard
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        },
    );
    let mut trained = false;
    for s in ds.train.iter().take(60) {
        if let Response::Trained { .. } = srv
            .call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            trained = true;
        }
    }
    assert!(trained, "session never trained");
    let mut ok = 0;
    let n = 40;
    for s in ds.test.iter().take(n) {
        if let Response::Prediction { class, .. } = srv
            .call(Request::Infer {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            if class == s.label {
                ok += 1;
            }
        }
    }
    // chance is 1/9 ≈ 4.4/40; require clear learning through the
    // full PJRT path
    assert!(ok > 20, "pjrt end-to-end accuracy {ok}/{n}");
    srv.shutdown();
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let t = tol * y.abs().max(1.0);
        assert!(
            (x - y).abs() <= t,
            "{what}[{i}]: {x} vs {y} (tol {t})"
        );
    }
}
