//! Cross-language golden tests: the Rust `dfr` stack must reproduce the
//! JAX reference numbers recorded by `python/tests/make_golden.py`
//! (closed-form inputs, so both sides regenerate identical data).
//!
//! The fixtures are **committed** under `rust/artifacts/golden/` (small,
//! stored npz), so this suite always runs under tier-1 — a missing
//! fixture is a hard failure, not a skip. Regenerate after touching the
//! JAX model with:
//!
//! ```text
//! python3 python/tests/make_golden.py rust/artifacts/golden
//! ```

use std::path::Path;

use dfr_edge::data::npz;
use dfr_edge::dfr::backprop::{truncated_grads, OutputLayer};
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};

fn golden(name: &str) -> std::collections::BTreeMap<String, npz::Array> {
    // cargo runs test binaries with cwd = the package root (rust/)
    let path = format!("artifacts/golden/{name}.npz");
    assert!(
        Path::new(&path).exists(),
        "golden fixture {path} missing (cwd {:?}) — the fixtures are committed; \
         regenerate with `python3 python/tests/make_golden.py rust/artifacts/golden`",
        std::env::current_dir().ok()
    );
    npz::read_npz(path).expect("golden npz parses")
}

/// Regenerate the closed-form inputs exactly as make_golden.py does
/// (single definition shared with the quant equivalence suite).
fn inputs(t: usize, v: usize) -> Vec<f32> {
    Mask::golden_inputs(t, v)
}

fn run_case(name: &str) {
    let g = golden(name);
    let t = g["t"].scalar().unwrap() as usize;
    let v = g["v"].scalar().unwrap() as usize;
    let nx = g["nx"].scalar().unwrap() as usize;
    let c = g["c"].scalar().unwrap() as usize;
    let p = g["p"].scalar().unwrap();
    let q = g["q"].scalar().unwrap();
    let length = g["length"].scalar().unwrap() as usize;

    // inputs must regenerate bit-identically
    let u = inputs(t, v);
    assert_eq!(u.len(), g["u"].data.len());
    for (a, b) in u.iter().zip(&g["u"].data) {
        assert!((a - b).abs() < 1e-6, "input mismatch {a} vs {b}");
    }
    let mask = Mask::golden(nx, v);
    for (a, b) in mask.m.iter().zip(&g["mask"].data) {
        assert_eq!(a, b, "mask mismatch");
    }

    // forward over the valid prefix
    let res = Reservoir {
        mask,
        p,
        q,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let fwd = res.forward(&u[..length * v], length);
    close(&fwd.r_mat, &g["r_mat"].data, 5e-4, "r_mat");
    close(&fwd.x_t, &g["x_t"].data, 5e-5, "x_t");
    close(&fwd.x_tm1, &g["x_tm1"].data, 5e-5, "x_tm1");
    close(&fwd.j_t, &g["j_t"].data, 5e-5, "j_t");

    // truncated gradients
    let out = OutputLayer {
        w: g["w"].data.clone(),
        b: g["b"].data.clone(),
        ny: c,
        nr: nx * (nx + 1),
    };
    let label = g["e"]
        .data
        .iter()
        .position(|&x| x == 1.0)
        .expect("one-hot");
    let grads = truncated_grads(&fwd, label, p, q, res.f, &out);
    let loss = g["loss"].scalar().unwrap();
    assert!(
        (grads.loss - loss).abs() < 5e-4 * loss.abs().max(1.0),
        "loss {} vs {}",
        grads.loss,
        loss
    );
    let dp = g["dp"].scalar().unwrap();
    let dq = g["dq"].scalar().unwrap();
    assert!((grads.dp - dp).abs() < 5e-4 * dp.abs().max(1e-3), "dp {} vs {dp}", grads.dp);
    assert!((grads.dq - dq).abs() < 5e-4 * dq.abs().max(1e-3), "dq {} vs {dq}", grads.dq);
    close(&grads.dw, &g["dw"].data, 1e-3, "dw");
    close(&grads.db, &g["db"].data, 1e-4, "db");
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let t = tol * y.abs().max(1.0);
        assert!((x - y).abs() <= t, "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn golden_small() {
    run_case("small");
}

#[test]
fn golden_padded_negative_q() {
    run_case("padded");
}

#[test]
fn golden_paper_scale_nx30() {
    run_case("paper_nx30");
}
