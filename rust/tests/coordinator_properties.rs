//! Property-based invariants of the coordinator (routing, batching/
//! buffering, state machine) and the linalg core, via the in-house
//! `util::proptest` driver.

use dfr_edge::coordinator::engine::NativeEngine;
use dfr_edge::coordinator::session::{FeedOutcome, InferError, Phase, Session, SessionConfig};
use dfr_edge::coordinator::{Request, Response, Server, ServerConfig};
use dfr_edge::data::dataset::Sample;
use dfr_edge::linalg::ridge::{RidgeAccumulator, RidgeMethod};
use dfr_edge::linalg::{tri, tri_len};
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::proptest::{run_prop, Config};

fn sample(rng: &mut Pcg32, t: usize, v: usize, n_c: usize) -> Sample {
    Sample {
        u: (0..t * v).map(|_| rng.normal()).collect(),
        t,
        label: rng.below(n_c as u32) as usize,
    }
}

fn mini_session(collect: usize, cap: usize) -> (NativeEngine, Session) {
    let mut cfg = SessionConfig::new(2, 2, collect);
    cfg.buffer_cap = cap;
    cfg.train.nx = 6;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    (NativeEngine::new(6, 2), Session::new(1, cfg, 0x11))
}

#[test]
fn prop_session_phase_machine_is_sound() {
    // invariants under arbitrary labelled-feed sequences:
    //  - phase only moves Collect -> Serve (never backwards without retrain)
    //  - buffer never exceeds cap
    //  - inference succeeds iff phase == Serve
    run_prop(
        "session FSM",
        Config {
            cases: 24,
            max_size: 12,
            ..Default::default()
        },
        |rng, size| {
            let collect = 2 + (size as usize % 8);
            let cap = collect + 3;
            let (eng, mut sess) = mini_session(collect, cap);
            for step in 0..(size as usize + collect) {
                let s = sample(rng, 5 + (step % 4), 2, 2);
                let before = sess.phase;
                let out = sess
                    .feed_labelled(&eng, s)
                    .map_err(|e| format!("engine: {e:#}"))?;
                if sess.buffered() > cap {
                    return Err(format!("buffer {} exceeds cap {cap}", sess.buffered()));
                }
                match (before, sess.phase) {
                    (Phase::Collect, Phase::Collect) | (Phase::Collect, Phase::Serve) => {}
                    (Phase::Serve, Phase::Serve) => {}
                    (a, b) => return Err(format!("illegal transition {a:?} -> {b:?}")),
                }
                if matches!(out, FeedOutcome::Trained { .. }) && sess.phase != Phase::Serve {
                    return Err("Trained outcome but not serving".into());
                }
                let infer_ok = {
                    let probe = sample(rng, 5, 2, 2);
                    match sess.infer(&eng, &probe) {
                        Ok(_) => true,
                        Err(InferError::NotServing { .. }) => false,
                        Err(InferError::Engine(e)) => return Err(format!("engine: {e:#}")),
                    }
                };
                if infer_ok != (sess.phase == Phase::Serve) {
                    return Err(format!(
                        "infer availability {infer_ok} inconsistent with {:?}",
                        sess.phase
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_server_routes_by_session_id() {
    // requests for distinct sessions never interfere: training session A
    // does not make session B servable
    run_prop(
        "server routing",
        Config {
            cases: 10,
            max_size: 4,
            ..Default::default()
        },
        |rng, size| {
            let mut scfg = SessionConfig::new(2, 2, 4);
            scfg.train.nx = 6;
            scfg.train.epochs = 1;
            scfg.train.res_decay_epochs = vec![];
            scfg.train.out_decay_epochs = vec![];
            let srv = Server::spawn(
                Box::new(NativeEngine::new(6, 2)),
                ServerConfig {
                    queue_cap: 32,
                    seed: 3,
                    shards: 2,
                    max_batch: 8,
                    ..ServerConfig::new(scfg)
                },
            );
            let n_sessions = 1 + u64::from(size % 3);
            // train session 0 fully; feed others only one sample
            for i in 0..4 {
                let s = sample(rng, 6, 2, 2);
                let _ = srv
                    .call(Request::Labelled { session: 0, sample: s })
                    .map_err(|e| e.to_string())?;
                let _ = i;
            }
            for sid in 1..=n_sessions {
                let s = sample(rng, 6, 2, 2);
                let _ = srv
                    .call(Request::Labelled { session: sid, sample: s })
                    .map_err(|e| e.to_string())?;
            }
            // session 0 serves
            let probe = sample(rng, 6, 2, 2);
            match srv
                .call(Request::Infer { session: 0, sample: probe })
                .map_err(|e| e.to_string())?
            {
                Response::Prediction { .. } => {}
                other => return Err(format!("session 0 should serve: {other:?}")),
            }
            // the others must not
            for sid in 1..=n_sessions {
                let probe = sample(rng, 6, 2, 2);
                match srv
                    .call(Request::Infer { session: sid, sample: probe })
                    .map_err(|e| e.to_string())?
                {
                    Response::Rejected(_) => {}
                    other => return Err(format!("session {sid} leaked training: {other:?}")),
                }
            }
            srv.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_ridge_accumulator_order_invariant() {
    // B and A accumulation is a sum — sample order must not matter
    run_prop(
        "ridge order invariance",
        Config {
            cases: 32,
            max_size: 10,
            ..Default::default()
        },
        |rng, size| {
            let s = 3 + size as usize;
            let n = 8;
            let ny = 2;
            let samples: Vec<(Vec<f32>, usize)> = (0..n)
                .map(|i| {
                    (
                        (0..s).map(|_| rng.normal()).collect(),
                        i % ny,
                    )
                })
                .collect();
            let mut fwd = RidgeAccumulator::new(s, ny);
            for (r, c) in &samples {
                fwd.accumulate(r, *c);
            }
            let mut rev = RidgeAccumulator::new(s, ny);
            for (r, c) in samples.iter().rev() {
                rev.accumulate(r, *c);
            }
            for i in 0..tri_len(s) {
                if (fwd.b_packed[i] - rev.b_packed[i]).abs() > 1e-3 {
                    return Err(format!("B[{i}] differs"));
                }
            }
            for i in 0..ny * s {
                if (fwd.a[i] - rev.a[i]).abs() > 1e-3 {
                    return Err(format!("A[{i}] differs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_b_is_gram_matrix() {
    // after accumulation, B equals the Gram matrix of the samples
    run_prop(
        "packed B = Σ r rᵀ",
        Config {
            cases: 24,
            max_size: 8,
            ..Default::default()
        },
        |rng, size| {
            let s = 2 + size as usize;
            let n = 5;
            let rs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..s).map(|_| rng.normal()).collect())
                .collect();
            let mut acc = RidgeAccumulator::new(s, 1);
            for r in &rs {
                acc.accumulate(r, 0);
            }
            for i in 0..s {
                for j in 0..=i {
                    let want: f32 = rs.iter().map(|r| r[i] * r[j]).sum();
                    let got = acc.b_packed[tri(i, j)];
                    if (got - want).abs() > 1e-3 * want.abs().max(1.0) {
                        return Err(format!("B[{i}][{j}] {got} vs {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_solution_residual_small_for_all_methods() {
    run_prop(
        "ridge residual",
        Config {
            cases: 18,
            max_size: 9,
            ..Default::default()
        },
        |rng, size| {
            let s = 3 + size as usize;
            let ny = 1 + rng.below(2) as usize;
            let mut acc = RidgeAccumulator::new(s, ny);
            for i in 0..(2 * s) {
                let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
                acc.accumulate(&r, i % ny);
            }
            let beta = 0.5;
            for m in [
                RidgeMethod::Gaussian,
                RidgeMethod::Cholesky1d,
                RidgeMethod::CholeskyBuffered,
            ] {
                let sol = acc.solve(beta, m);
                // check W (B + βI) == A row-wise
                let b = dfr_edge::linalg::unpack_symmetric(&acc.b_packed, s);
                for i in 0..ny {
                    for j in 0..s {
                        let mut acc_v = 0.0f32;
                        for k in 0..s {
                            let bkj =
                                b[k * s + j] + if k == j { beta } else { 0.0 };
                            acc_v += sol.w_tilde[i * s + k] * bkj;
                        }
                        let want = acc.a[i * s + j];
                        if (acc_v - want).abs() > 2e-2 * want.abs().max(1.0) {
                            return Err(format!(
                                "{m:?} s={s} residual at ({i},{j}): {acc_v} vs {want}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
