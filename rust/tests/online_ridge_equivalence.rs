//! Property tests: the streaming online ridge (rank-1 Cholesky
//! update/downdate + in-place re-solve, `linalg::OnlineRidge`) is
//! equivalent to from-scratch batch solving within f32 tolerance:
//!
//! * a growing stream matches the batch accumulator at every step;
//! * a sliding window (update + downdate) matches a from-scratch packed
//!   Gram + `cholesky_1d` over exactly the window samples;
//! * λ-forgetting matches an explicitly λ-weighted Gram built in f64;
//! * the every-K re-factorization cadence is numerically transparent.
//!
//! Sizes deliberately sweep every residue of s mod 4 — the `dot`
//! kernel's remainder lanes are the classic place for a packed-layout
//! off-by-one to hide.

use dfr_edge::linalg::ridge::{OnlineRidge, OnlineRidgeConfig, RidgeAccumulator, RidgeMethod};
use dfr_edge::linalg::{tri, tri_len};
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::proptest::{assert_close, run_prop, Config};

fn stream(rng: &mut Pcg32, n: usize, s: usize, ny: usize) -> Vec<(Vec<f32>, usize)> {
    (0..n)
        .map(|i| ((0..s).map(|_| rng.normal()).collect(), i % ny))
        .collect()
}

#[test]
fn growing_stream_matches_batch_every_step() {
    run_prop(
        "grow online == batch",
        Config {
            cases: 24,
            max_size: 13,
            ..Default::default()
        },
        |rng, size| {
            let s = size as usize; // 1..=13 — all residues mod 4
            let ny = 1 + (size as usize % 3);
            let beta = 0.5f32;
            let data = stream(rng, 18, s, ny);
            let mut online = OnlineRidge::new(
                s,
                ny,
                OnlineRidgeConfig {
                    beta,
                    lambda: 1.0,
                    window: None,
                    refactor_every: 0,
                },
            );
            let mut batch = RidgeAccumulator::new(s, ny);
            for (i, (r, c)) in data.iter().enumerate() {
                let stats = online.observe(r, *c);
                if stats.updates != i as u64 + 1 {
                    return Err(format!("updates {} at step {i}", stats.updates));
                }
                batch.accumulate(r, *c);
                let sol = batch.solve(beta, RidgeMethod::Cholesky1d);
                assert_close(online.w_tilde(), &sol.w_tilde, 1e-2, 2e-3)
                    .map_err(|e| format!("s={s} ny={ny} step {i}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn sliding_window_matches_from_scratch() {
    run_prop(
        "window online == batch over window",
        Config {
            cases: 28,
            max_size: 12,
            ..Default::default()
        },
        |rng, size| {
            let s = 2 + size as usize; // 3..=14
            let ny = 1 + (size as usize % 3);
            let w = 3 + (size as usize % 6); // 3..=8
            let beta = 0.4f32;
            let data = stream(rng, w + 12, s, ny);
            let mut online = OnlineRidge::new(
                s,
                ny,
                OnlineRidgeConfig {
                    beta,
                    lambda: 1.0,
                    window: Some(w),
                    refactor_every: 0,
                },
            );
            for (i, (r, c)) in data.iter().enumerate() {
                let stats = online.observe(r, *c);
                if stats.window_len != (i + 1).min(w) {
                    return Err(format!(
                        "window occupancy {} at step {i} (cap {w})",
                        stats.window_len
                    ));
                }
                // from scratch over exactly the window samples
                let lo = (i + 1).saturating_sub(w);
                let mut batch = RidgeAccumulator::new(s, ny);
                for (rb, cb) in &data[lo..=i] {
                    batch.accumulate(rb, *cb);
                }
                let sol = batch.solve(beta, RidgeMethod::Cholesky1d);
                assert_close(online.w_tilde(), &sol.w_tilde, 2e-2, 5e-3)
                    .map_err(|e| format!("s={s} ny={ny} w={w} step {i}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn forgetting_matches_weighted_from_scratch() {
    run_prop(
        "λ online == λ-weighted batch",
        Config {
            cases: 20,
            max_size: 10,
            ..Default::default()
        },
        |rng, size| {
            let s = 2 + size as usize; // 3..=12
            let ny = 1 + (size as usize % 2);
            let lambda = 0.85 + 0.1 * rng.uniform();
            let beta = 0.5f32;
            let n = 16usize;
            let data = stream(rng, n, s, ny);
            let mut online = OnlineRidge::new(
                s,
                ny,
                OnlineRidgeConfig {
                    beta,
                    lambda,
                    window: None,
                    refactor_every: 0,
                },
            );
            for (r, c) in &data {
                online.observe(r, *c);
            }
            // explicit λ-weighted system, accumulated in f64: sample i
            // (0-based) carries weight λ^{n-1-i}, the βI seed λ^n
            let mut bw = vec![0.0f64; tri_len(s)];
            let mut aw = vec![0.0f64; ny * s];
            for (i, (r, &c)) in data.iter().enumerate() {
                let wgt = f64::from(lambda).powi((n - 1 - i) as i32);
                for a in 0..s {
                    for b in 0..=a {
                        bw[tri(a, b)] += wgt * f64::from(r[a]) * f64::from(r[b]);
                    }
                }
                for (dst, &x) in aw[c * s..(c + 1) * s].iter_mut().zip(r) {
                    *dst += wgt * f64::from(x);
                }
            }
            let mut batch = RidgeAccumulator::new(s, ny);
            batch.b_packed = bw.iter().map(|&x| x as f32).collect();
            batch.a = aw.iter().map(|&x| x as f32).collect();
            batch.count = n;
            let beta_eff = (f64::from(lambda).powi(n as i32) * f64::from(beta)) as f32;
            let sol = batch.solve(beta_eff, RidgeMethod::Cholesky1d);
            assert_close(online.w_tilde(), &sol.w_tilde, 2e-2, 5e-3)
                .map_err(|e| format!("s={s} ny={ny} λ={lambda}: {e}"))
        },
    );
}

#[test]
fn periodic_refactor_is_transparent() {
    run_prop(
        "refactor-every-K == never",
        Config {
            cases: 16,
            max_size: 9,
            ..Default::default()
        },
        |rng, size| {
            let s = 2 + size as usize;
            let ny = 2;
            let w = 4 + (size as usize % 4);
            let beta = 0.3f32;
            let data = stream(rng, w + 12, s, ny);
            let mk = |k: usize| {
                OnlineRidge::new(
                    s,
                    ny,
                    OnlineRidgeConfig {
                        beta,
                        lambda: 1.0,
                        window: Some(w),
                        refactor_every: k,
                    },
                )
            };
            let mut never = mk(0);
            let mut every3 = mk(3);
            for (i, (r, c)) in data.iter().enumerate() {
                never.observe(r, *c);
                every3.observe(r, *c);
                assert_close(never.w_tilde(), every3.w_tilde(), 1e-2, 2e-3)
                    .map_err(|e| format!("s={s} w={w} step {i}: {e}"))?;
            }
            if every3.refactors() == 0 {
                return Err("refactor cadence never fired".into());
            }
            if never.refactors() != 0 {
                return Err(format!(
                    "refactor_every=0 re-factorized {} times (downdates degenerated)",
                    never.refactors()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn window_equivalence_survives_long_streams_with_refactor() {
    // drift-bounding in action: 300 folds through an 8-sample window,
    // refactor every 32 — the final solution still matches from-scratch
    let mut rng = Pcg32::seed(0x57AB1E);
    let s = 11; // 3 mod 4
    let ny = 3;
    let w = 8;
    let beta = 0.4f32;
    let data = stream(&mut rng, 300, s, ny);
    let mut online = OnlineRidge::new(
        s,
        ny,
        OnlineRidgeConfig {
            beta,
            lambda: 1.0,
            window: Some(w),
            refactor_every: 32,
        },
    );
    for (r, c) in &data {
        online.observe(r, *c);
    }
    assert!(online.refactors() >= 9, "refactors {}", online.refactors());
    let mut batch = RidgeAccumulator::new(s, ny);
    for (rb, cb) in &data[300 - w..] {
        batch.accumulate(rb, *cb);
    }
    let sol = batch.solve(beta, RidgeMethod::Cholesky1d);
    assert_close(online.w_tilde(), &sol.w_tilde, 2e-2, 5e-3).unwrap();
}
