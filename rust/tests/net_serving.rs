//! Network edge integration tests: wire-codec robustness (randomized
//! round-trips, truncation, garbage — typed errors, never panics) and a
//! live TCP server driven end to end through [`Client`].

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::coordinator::{
    decode_request, decode_response, encode_request, encode_response, Client, ErrorKind, NetConfig,
    NetServer, Request, Response, Server, ServerConfig, SessionConfig, WireError,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::runtime::executor::TrainState;
use dfr_edge::util::prng::Pcg32;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

fn spawn_server(ds: &Dataset) -> Server {
    Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 64,
            seed: 0xFEED,
            shards: 2,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(ds.train.len()))
        },
    )
}

fn bind(srv: Server, cfg: NetConfig) -> (Arc<Server>, NetServer) {
    let srv = Arc::new(srv);
    let net = NetServer::bind(Arc::clone(&srv), cfg).unwrap();
    (srv, net)
}

/// Stop the edge first (joins its accept + handler threads, dropping
/// their `Arc<Server>` clones), then drain the coordinator.
fn teardown(srv: Arc<Server>, mut net: NetServer) {
    net.shutdown();
    if let Ok(owned) = Arc::try_unwrap(srv) {
        owned.shutdown();
    }
}

// ---------------------------------------------------------------------------
// codec robustness (no sockets)
// ---------------------------------------------------------------------------

fn random_sample(rng: &mut Pcg32) -> Sample {
    let t = 1 + rng.below(12) as usize;
    Sample {
        u: (0..t * 2).map(|_| rng.normal()).collect(),
        t,
        label: rng.below(4) as usize,
    }
}

fn random_request(rng: &mut Pcg32) -> Request {
    match rng.below(4) {
        0 => Request::Labelled {
            session: rng.next_u64(),
            sample: random_sample(rng),
        },
        1 => Request::Infer {
            session: rng.next_u64(),
            sample: random_sample(rng),
        },
        2 => Request::Finalize {
            session: rng.next_u64(),
        },
        _ => Request::Stats,
    }
}

fn random_response(rng: &mut Pcg32) -> Response {
    match rng.below(9) {
        0 => Response::Accepted {
            phase: "collect",
            buffered: rng.below(1000) as usize,
        },
        1 => Response::Prediction {
            class: rng.below(8) as usize,
            scores: (0..rng.below(8)).map(|_| rng.normal()).collect(),
        },
        2 => Response::Trained {
            p: rng.normal(),
            q: rng.normal(),
            beta: rng.uniform_in(1e-8, 1.0),
            train_seconds: f64::from(rng.uniform()),
        },
        3 => Response::Observed {
            updates: rng.next_u64(),
            window: rng.below(512) as usize,
        },
        4 => Response::Adapted {
            generation: rng.next_u64(),
            p: rng.normal(),
            q: rng.normal(),
            updates: rng.next_u64(),
        },
        5 => Response::StatsText(format!("counter x {}\n", rng.next_u32())),
        6 => Response::Rejected(format!("reason {}", rng.next_u32())),
        7 => Response::Error {
            kind: match rng.below(3) {
                0 => ErrorKind::Panic,
                1 => ErrorKind::Engine,
                _ => ErrorKind::NonFinite,
            },
            detail: format!("detail {}", rng.next_u32()),
        },
        _ => Response::Bye,
    }
}

#[test]
fn randomized_requests_roundtrip_bitwise() {
    let mut rng = Pcg32::seed(0xC0DEC);
    for _ in 0..500 {
        let req = random_request(&mut rng);
        let bytes = encode_request(&req).unwrap();
        assert_eq!(decode_request(&bytes).unwrap(), req);
    }
}

#[test]
fn randomized_responses_roundtrip_bitwise() {
    let mut rng = Pcg32::seed(0xD0C5);
    for _ in 0..500 {
        let resp = random_response(&mut rng);
        let bytes = encode_response(&resp).unwrap();
        assert_eq!(decode_response(&bytes).unwrap(), resp);
    }
}

#[test]
fn every_truncation_is_a_typed_error_never_a_panic() {
    let mut rng = Pcg32::seed(0x7A7A);
    for _ in 0..40 {
        let req = random_request(&mut rng);
        let bytes = encode_request(&req).unwrap();
        for cut in 0..bytes.len() {
            assert!(
                decode_request(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} decoded for {req:?}",
                bytes.len()
            );
        }
        let resp = random_response(&mut rng);
        let bytes = encode_response(&resp).unwrap();
        for cut in 0..bytes.len() {
            assert!(
                decode_response(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} decoded for {resp:?}",
                bytes.len()
            );
        }
    }
}

#[test]
fn garbage_bytes_decode_to_typed_errors() {
    let mut rng = Pcg32::seed(0xBAD);
    for _ in 0..2000 {
        let len = rng.below(64) as usize;
        let buf: Vec<u8> = (0..len).map(|_| (rng.next_u32() & 0xFF) as u8).collect();
        // must return, not panic; Ok is acceptable only if re-encoding
        // reproduces the exact bytes (an accidental valid message)
        if let Ok(req) = decode_request(&buf) {
            assert_eq!(encode_request(&req).unwrap(), buf);
        }
        if let Ok(resp) = decode_response(&buf) {
            assert_eq!(encode_response(&resp).unwrap(), buf);
        }
    }
}

#[test]
fn trailing_garbage_after_a_valid_message_is_refused() {
    let mut bytes = encode_request(&Request::Stats).unwrap();
    bytes.extend_from_slice(&[0, 0, 0]);
    assert!(matches!(
        decode_request(&bytes),
        Err(WireError::TrailingBytes(3))
    ));
}

// ---------------------------------------------------------------------------
// live TCP end-to-end
// ---------------------------------------------------------------------------

#[test]
fn tcp_client_roundtrips_the_full_lifecycle() {
    let ds = mini_dataset(31);
    let (srv, net) = bind(spawn_server(&ds), NetConfig::default());
    let mut client = Client::connect(net.local_addr()).unwrap();

    // train session 1 over the wire
    let mut trained = false;
    for s in &ds.train {
        match client
            .call(&Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            Response::Accepted { .. } => {}
            Response::Trained { .. } => trained = true,
            other => panic!("unexpected: {other:?}"),
        }
    }
    assert!(trained, "collect target == train split must train");

    // inference over the wire matches a direct in-process call bitwise
    for s in ds.test.iter().take(4) {
        let over_wire = client
            .call(&Request::Infer {
                session: 1,
                sample: s.clone(),
            })
            .unwrap();
        let direct = srv
            .call(Request::Infer {
                session: 1,
                sample: s.clone(),
            })
            .unwrap();
        assert_eq!(over_wire, direct);
        assert!(matches!(over_wire, Response::Prediction { .. }));
    }

    // Finalize on a fresh session (no samples): a typed server answer,
    // not a transport error
    let r = client.call(&Request::Finalize { session: 9 }).unwrap();
    assert!(
        matches!(r, Response::Rejected(_) | Response::Error { .. }),
        "{r:?}"
    );

    // Stats over the wire includes the edge's own instruments
    match client.call(&Request::Stats).unwrap() {
        Response::StatsText(t) => {
            assert!(t.contains("net_requests_total"), "{t}");
            assert!(t.contains("net_connections_total"), "{t}");
        }
        other => panic!("unexpected: {other:?}"),
    }
    teardown(srv, net);
}

#[test]
fn bad_magic_is_rejected_and_the_connection_closed() {
    let ds = mini_dataset(32);
    let (srv, net) = bind(spawn_server(&ds), NetConfig::default());
    let mut client = Client::connect(net.local_addr()).unwrap();
    client.send_raw(b"ZZ______garbage").unwrap();
    match client.read_response().unwrap() {
        Response::Rejected(m) => assert!(m.contains("frame"), "{m}"),
        other => panic!("unexpected: {other:?}"),
    }
    // server closed the stream: the next exchange must fail
    let err = client.call(&Request::Stats);
    assert!(err.is_err(), "connection should be closed: {err:?}");
    teardown(srv, net);
}

#[test]
fn payload_garbage_keeps_the_connection_serving() {
    let ds = mini_dataset(33);
    let (srv, net) = bind(spawn_server(&ds), NetConfig::default());
    let mut client = Client::connect(net.local_addr()).unwrap();
    // well-formed frame header, hostile payload (tag 0xEE does not exist)
    let payload = [0xEEu8, 1, 2, 3];
    let mut raw = Vec::new();
    raw.extend_from_slice(b"DF");
    raw.push(1); // version
    raw.push(0); // request kind
    raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    raw.extend_from_slice(&payload);
    client.send_raw(&raw).unwrap();
    match client.read_response().unwrap() {
        Response::Rejected(m) => assert!(m.contains("decode"), "{m}"),
        other => panic!("unexpected: {other:?}"),
    }
    // framing stayed aligned — the same connection still serves
    assert!(matches!(
        client.call(&Request::Stats).unwrap(),
        Response::StatsText(_)
    ));
    teardown(srv, net);
}

#[test]
fn oversized_frame_is_refused_up_front() {
    let ds = mini_dataset(34);
    let cfg = NetConfig {
        max_frame: 1024,
        ..NetConfig::default()
    };
    let (srv, net) = bind(spawn_server(&ds), cfg);
    let mut client = Client::connect(net.local_addr()).unwrap();
    let mut raw = Vec::new();
    raw.extend_from_slice(b"DF");
    raw.push(1);
    raw.push(0);
    raw.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 GiB claim, no body
    client.send_raw(&raw).unwrap();
    match client.read_response().unwrap() {
        Response::Rejected(m) => assert!(m.contains("frame"), "{m}"),
        other => panic!("unexpected: {other:?}"),
    }
    teardown(srv, net);
}

#[test]
fn connection_cap_refuses_with_a_framed_rejection() {
    let ds = mini_dataset(35);
    let cfg = NetConfig {
        max_conns: 1,
        ..NetConfig::default()
    };
    let (srv, net) = bind(spawn_server(&ds), cfg);
    let mut first = Client::connect(net.local_addr()).unwrap();
    assert!(matches!(
        first.call(&Request::Stats).unwrap(),
        Response::StatsText(_)
    ));
    // second connection is over the cap: refused before any request
    let mut second = Client::connect(net.local_addr()).unwrap();
    match second.read_response().unwrap() {
        Response::Rejected(m) => assert!(m.contains("capacity"), "{m}"),
        other => panic!("unexpected: {other:?}"),
    }
    teardown(srv, net);
}

/// An engine that sleeps in the hot operations so a short net-side call
/// budget deterministically expires.
struct SlowEngine {
    inner: NativeEngine,
    delay: Duration,
}

impl Engine for SlowEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> anyhow::Result<f32> {
        thread::sleep(self.delay);
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> anyhow::Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }

    fn infer(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        thread::sleep(self.delay);
        self.inner.infer(s, mask, p, q, w_tilde)
    }

    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn shard_backpressure_becomes_a_wire_visible_rejection() {
    let ds = mini_dataset(36);
    let srv = Server::spawn(
        Box::new(SlowEngine {
            inner: NativeEngine::new(8, 2),
            delay: Duration::from_millis(400),
        }),
        ServerConfig {
            queue_cap: 2,
            seed: 0xFEED,
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(1))
        },
    );
    let cfg = NetConfig {
        call_timeout: Duration::from_millis(50),
        ..NetConfig::default()
    };
    let (srv, net) = bind(srv, cfg);
    let mut client = Client::connect(net.local_addr()).unwrap();
    // collect target 1 → the first labelled sample trains for ~400 ms,
    // far past the 50 ms edge budget
    match client
        .call(&Request::Labelled {
            session: 0,
            sample: ds.train[0].clone(),
        })
        .unwrap()
    {
        Response::Rejected(m) => assert!(m.contains("transport"), "{m}"),
        other => panic!("unexpected: {other:?}"),
    }
    teardown(srv, net);
}
