//! Counting-allocator proof of the zero-allocation steady state
//! (DESIGN.md §9): after one warmup call has sized every reusable
//! buffer, `NativeEngine::features_into` and `infer_into` perform **no
//! heap allocation at all**, and the whole masking → reservoir → DPRR →
//! r̃ pipeline runs out of the per-replica workspace.
//!
//! The counter is thread-local, so allocations made concurrently by the
//! libtest harness or sibling test threads cannot pollute the count.
//!
//! The batched forward pass (DESIGN.md §14) is covered at the engine and
//! session layers (`features_batch_into`, `feed_labelled_with_features`)
//! — the complete per-request hot path of the server's batched drain.
//! The drain loop itself runs on shard threads this thread-local counter
//! cannot observe; its only steady-state allocation is the one small
//! per-drain-cycle `Vec<FeatureRequest>` the planner builds (borrow
//! lifetimes prevent reusing it across cycles), which is O(max_batch)
//! pointers per cycle and documented in DESIGN.md §14.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::data::dataset::Sample;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::util::prng::Pcg32;

std::thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the bookkeeping only
// touches const-initialized thread-locals (no allocation, no recursion)
// and `try_with` tolerates TLS teardown.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TRACKING.try_with(|t| {
            if t.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Count heap allocations performed by `f` on this thread.
fn allocations_in(f: impl FnOnce()) -> u64 {
    ALLOCS.with(|a| a.set(0));
    TRACKING.with(|t| t.set(true));
    f();
    TRACKING.with(|t| t.set(false));
    ALLOCS.with(|a| a.get())
}

#[test]
fn features_and_infer_are_allocation_free_after_warmup() {
    // paper scale: Nx = 30, V = 12 (jpvow shape), 9 classes
    let (nx, v, n_c, t) = (30usize, 12usize, 9usize, 29usize);
    let mut rng = Pcg32::seed(0xA110C);
    let eng = NativeEngine::new(nx, n_c);
    let mask = Mask::random(nx, v, &mut rng);
    let sample = Sample {
        u: (0..t * v).map(|_| rng.normal()).collect(),
        t,
        label: 0,
    };
    let s_dim = nx * nx + nx + 1;
    let w_tilde: Vec<f32> = (0..n_c * s_dim).map(|_| 0.01 * rng.normal()).collect();

    let mut feat = Vec::new();
    let mut scores = Vec::new();
    // warmup: sizes the engine workspace and the caller buffers
    eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
    eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
        .unwrap();

    let n = allocations_in(|| {
        for _ in 0..50 {
            eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
            eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
                .unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state features_into/infer_into performed {n} heap allocations"
    );

    // the zero-allocation path still computes the real thing
    assert_eq!(feat.len(), s_dim);
    assert_eq!(*feat.last().unwrap(), 1.0);
    assert_eq!(scores.len(), n_c);
    assert!((scores.iter().sum::<f32>() - 1.0).abs() < 1e-5);
}

#[test]
fn quant_engine_features_and_infer_are_allocation_free_after_warmup() {
    use dfr_edge::quant::QuantEngine;
    // paper scale, same shapes as the native test: the quantized
    // steady state (mask refresh, input quantization, LUT cascade, wide
    // DPRR, weight requantization, integer MAC) must also be alloc-free
    let (nx, v, n_c, t) = (30usize, 12usize, 9usize, 29usize);
    let mut rng = Pcg32::seed(0xA110F);
    let eng = QuantEngine::new(nx, n_c);
    let mask = Mask::random(nx, v, &mut rng);
    let sample = Sample {
        u: (0..t * v).map(|_| 0.25 * rng.normal()).collect(),
        t,
        label: 0,
    };
    let s_dim = nx * nx + nx + 1;
    let w_tilde: Vec<f32> = (0..n_c * s_dim).map(|_| 0.01 * rng.normal()).collect();

    let mut feat = Vec::new();
    let mut scores = Vec::new();
    eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
    eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
        .unwrap();

    let n = allocations_in(|| {
        for _ in 0..50 {
            eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
            eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
                .unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state quant features_into/infer_into performed {n} heap allocations"
    );
    assert_eq!(feat.len(), s_dim);
    assert_eq!(*feat.last().unwrap(), 1.0);
    assert_eq!(scores.len(), n_c);
    assert!((scores.iter().sum::<f32>() - 1.0).abs() < 1e-5);
}

#[test]
fn batched_features_are_allocation_free_after_warmup() {
    use dfr_edge::coordinator::engine::FeatureRequest;
    // paper scale, a full default drain batch of independent sessions:
    // distinct masks, distinct (p, q), ragged series lengths. After one
    // warmup sweep has grown the engine's BatchScratch, repeated sweeps
    // must be allocation-free — asserted at TWO batch sizes (8 and a
    // 4-lane prefix) so lane-count shrink/regrow stays grow-only.
    let (nx, v, n_c) = (30usize, 12usize, 9usize);
    let mut rng = Pcg32::seed(0xBA7C0);
    let eng = NativeEngine::new(nx, n_c);
    let masks: Vec<Mask> = (0..8).map(|_| Mask::random(nx, v, &mut rng)).collect();
    let samples: Vec<Sample> = (0..8)
        .map(|i| {
            let t = 21 + i; // ragged pending counts
            Sample {
                u: (0..t * v).map(|_| rng.normal()).collect(),
                t,
                label: 0,
            }
        })
        .collect();
    let reqs: Vec<FeatureRequest<'_>> = masks
        .iter()
        .zip(&samples)
        .enumerate()
        .map(|(i, (mask, sample))| FeatureRequest {
            sample,
            mask,
            p: 0.15 + 0.01 * i as f32,
            q: 0.1,
        })
        .collect();
    let mut outs: Vec<Vec<f32>> = vec![Vec::new(); 8];
    // warmup sizes the batch workspace at the deepest lane count
    eng.features_batch_into(&reqs, &mut outs).unwrap();
    eng.features_batch_into(&reqs[..4], &mut outs[..4]).unwrap();

    let n = allocations_in(|| {
        for _ in 0..25 {
            eng.features_batch_into(&reqs, &mut outs).unwrap();
            eng.features_batch_into(&reqs[..4], &mut outs[..4]).unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state features_batch_into performed {n} heap allocations"
    );
    // the zero-allocation sweep still computes the real thing
    let s_dim = nx * nx + nx + 1;
    for out in &outs {
        assert_eq!(out.len(), s_dim);
        assert_eq!(*out.last().unwrap(), 1.0);
    }
}

#[test]
fn simd_batched_features_are_allocation_free_after_warmup() {
    use dfr_edge::coordinator::engine::FeatureRequest;
    use dfr_edge::dfr::reservoir::Nonlinearity;
    use dfr_edge::simd::{Kernels, SimdMode};
    // the AVX2 kernel table must not change the allocation story: the
    // vector kernels work in place on the same grow-only BatchScratch
    // buffers (no stack-to-heap spills, no per-sweep staging)
    let Ok(k) = Kernels::try_select(SimdMode::Force) else {
        eprintln!("simd_batched_features_are_allocation_free_after_warmup: no AVX2 — skipped");
        return;
    };
    let (nx, v, n_c) = (30usize, 12usize, 9usize);
    let mut rng = Pcg32::seed(0xBA7C1);
    let eng = NativeEngine::with_kernels(nx, n_c, Nonlinearity::Linear { alpha: 1.0 }, k);
    let masks: Vec<Mask> = (0..8).map(|_| Mask::random(nx, v, &mut rng)).collect();
    let samples: Vec<Sample> = (0..8)
        .map(|i| {
            let t = 21 + i; // ragged lanes: the blend/tail paths run too
            Sample {
                u: (0..t * v).map(|_| rng.normal()).collect(),
                t,
                label: 0,
            }
        })
        .collect();
    let reqs: Vec<FeatureRequest<'_>> = masks
        .iter()
        .zip(&samples)
        .enumerate()
        .map(|(i, (mask, sample))| FeatureRequest {
            sample,
            mask,
            p: 0.15 + 0.01 * i as f32,
            q: 0.1,
        })
        .collect();
    let mut outs: Vec<Vec<f32>> = vec![Vec::new(); 8];
    eng.features_batch_into(&reqs, &mut outs).unwrap();

    let n = allocations_in(|| {
        for _ in 0..25 {
            eng.features_batch_into(&reqs, &mut outs).unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state SIMD features_batch_into performed {n} heap allocations"
    );
    let s_dim = nx * nx + nx + 1;
    for out in &outs {
        assert_eq!(out.len(), s_dim);
        assert_eq!(*out.last().unwrap(), 1.0);
    }
}

#[test]
fn session_batched_feed_is_allocation_free_after_warmup() {
    use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
    use dfr_edge::data::profiles::Profile;
    use dfr_edge::data::synth;

    // the batched drain's Feed tail: features arrive pre-extracted from
    // the planner's sweep, the session copies them into its scratch and
    // folds — must be allocation-free in steady state just like the
    // per-call `feed_labelled` path it mirrors
    let prof = Profile {
        name: "mini",
        n_v: 2,
        n_c: 2,
        train: 20,
        test: 5,
        t_min: 10,
        t_max: 12,
    };
    let ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        33,
    );
    let mut cfg = SessionConfig::new(2, 2, ds.train.len());
    cfg.train.nx = 8;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    cfg.train.window = Some(12);
    cfg.train.refactor_every = 6;
    cfg.buffer_cap = ds.train.len();
    let eng = NativeEngine::new(8, 2);
    let mut sess = Session::new(1, cfg, 0xF00F);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert!(sess.streaming_serve(), "streaming path active");

    // pre-extract features OUTSIDE the measured region, exactly as the
    // server's batched planner does (through the engine's BatchScratch),
    // and pre-clone the streamed samples (the server clones per request)
    let (p, q) = sess.serving_params();
    let stream: Vec<Sample> = ds.train.iter().take(16).cloned().collect();
    let feats: Vec<Vec<f32>> = stream
        .iter()
        .map(|s| {
            let mut f = Vec::new();
            eng.features_into(s, &sess.mask, p, q, &mut f).unwrap();
            f
        })
        .collect();
    let mut it = stream.into_iter().zip(&feats);
    for (s, f) in it.by_ref().take(8) {
        let out = sess.feed_labelled_with_features(&eng, s, f).unwrap();
        assert!(matches!(out, FeedOutcome::Observed { .. }), "{out:?}");
    }
    let n = allocations_in(|| {
        for (s, f) in it {
            let out = sess.feed_labelled_with_features(&eng, s, f).unwrap();
            assert!(matches!(out, FeedOutcome::Observed { .. }), "{out:?}");
        }
    });
    assert_eq!(
        n, 0,
        "steady-state feed_labelled_with_features performed {n} heap allocations"
    );
}

#[test]
fn online_ridge_observe_is_allocation_free_after_warmup() {
    use dfr_edge::linalg::ridge::{OnlineRidge, OnlineRidgeConfig};
    // moderate scale, odd s to exercise the kernels' remainder lanes;
    // window + refactor cadence so the measured section crosses every
    // sub-path: eviction downdate, rank-1 update, periodic refactor,
    // in-place re-solve
    let (s, ny) = (301usize, 5usize);
    let mut rng = Pcg32::seed(0xA110E);
    let mut online = OnlineRidge::new(
        s,
        ny,
        OnlineRidgeConfig {
            beta: 0.5,
            lambda: 1.0,
            window: Some(24),
            refactor_every: 8,
        },
    );
    let samples: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..s).map(|_| rng.normal()).collect())
        .collect();
    // warmup fills the window and crosses at least one refactor
    for (i, r) in samples.iter().take(30).enumerate() {
        online.observe(r, i % ny);
    }
    let n = allocations_in(|| {
        for (i, r) in samples.iter().enumerate().skip(30) {
            let stats = online.observe(r, i % ny);
            assert_eq!(stats.window_len, 24);
        }
    });
    assert_eq!(
        n, 0,
        "steady-state OnlineRidge::observe performed {n} heap allocations"
    );
    assert!(online.refactors() >= 4, "refactor cadence exercised");
    assert_eq!(online.updates(), 40);
}

#[test]
fn session_streaming_feed_is_allocation_free_after_warmup() {
    use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
    use dfr_edge::data::profiles::Profile;
    use dfr_edge::data::synth;

    let prof = Profile {
        name: "mini",
        n_v: 2,
        n_c: 2,
        train: 20,
        test: 5,
        t_min: 10,
        t_max: 12,
    };
    let ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        31,
    );
    let mut cfg = SessionConfig::new(2, 2, ds.train.len());
    cfg.train.nx = 8;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    cfg.train.window = Some(12);
    cfg.train.refactor_every = 6;
    // recent-sample FIFO recycles from the first streamed feed
    cfg.buffer_cap = ds.train.len();
    let eng = NativeEngine::new(8, 2);
    let mut sess = Session::new(1, cfg, 0xF00D);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert!(sess.online().is_some(), "streaming path active");

    // pre-clone the streamed samples OUTSIDE the measured region (the
    // server clones per request; the session itself must not allocate)
    let warm: Vec<_> = ds.train.iter().take(8).cloned().collect();
    let hot: Vec<_> = ds.train.iter().skip(8).take(8).cloned().collect();
    for s in warm {
        let out = sess.feed_labelled(&eng, s).unwrap();
        assert!(matches!(out, FeedOutcome::Observed { .. }), "{out:?}");
    }
    let n = allocations_in(|| {
        for s in hot {
            let out = sess.feed_labelled(&eng, s).unwrap();
            assert!(matches!(out, FeedOutcome::Observed { .. }), "{out:?}");
        }
    });
    assert_eq!(
        n, 0,
        "steady-state streaming feed_labelled performed {n} heap allocations"
    );
}

#[test]
fn streaming_bp_trainer_steps_are_allocation_free_after_warmup() {
    use dfr_edge::dfr::optim::{OptimConfig, StreamingBpTrainer};
    use dfr_edge::dfr::reservoir::Nonlinearity;
    // paper scale: the per-sample forward + truncated backward + SGD
    // update must run entirely out of the trainer's workspaces
    let (nx, v, n_c, t) = (30usize, 12usize, 9usize, 29usize);
    let mut rng = Pcg32::seed(0xA1107);
    let mask = Mask::random(nx, v, &mut rng);
    let samples: Vec<Sample> = (0..12)
        .map(|i| Sample {
            u: (0..t * v).map(|_| rng.normal()).collect(),
            t,
            label: i % n_c,
        })
        .collect();
    let mut tr = StreamingBpTrainer::new(
        mask,
        Nonlinearity::Linear { alpha: 1.0 },
        0.1,
        0.1,
        n_c,
        OptimConfig::default(),
    );
    tr.begin_epoch();
    // warmup sizes ForwardScratch growth + GradScratch + probs buffers
    for s in samples.iter().take(4) {
        tr.step(s);
    }
    let n = allocations_in(|| {
        for s in samples.iter().skip(4) {
            let loss = tr.step(s);
            assert!(loss.is_finite());
        }
    });
    assert_eq!(
        n, 0,
        "steady-state StreamingBpTrainer::step performed {n} heap allocations"
    );
    assert_eq!(tr.steps(), 12);
}

#[test]
fn session_adaptation_steps_are_allocation_free_after_warmup() {
    use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
    use dfr_edge::data::profiles::Profile;
    use dfr_edge::data::synth;

    // streaming feed WITH reservoir adaptation: features + ridge fold +
    // re-solve + truncated-BP step must all stay allocation-free while
    // the drift threshold is not crossed (the generation reseed itself
    // is allowed to allocate — it is not steady state)
    let prof = Profile {
        name: "mini",
        n_v: 2,
        n_c: 2,
        train: 20,
        test: 5,
        t_min: 10,
        t_max: 12,
    };
    let ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        37,
    );
    let mut cfg = SessionConfig::new(2, 2, ds.train.len());
    cfg.train.nx = 8;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    cfg.train.window = Some(12);
    cfg.train.refactor_every = 6;
    cfg.buffer_cap = ds.train.len();
    cfg.adapt_reservoir = true;
    cfg.adapt_lr = 0.01;
    cfg.adapt_drift_eps = 1e9; // never roll the generation mid-measurement
    let eng = NativeEngine::new(8, 2);
    let mut sess = Session::new(1, cfg, 0xF00E);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert!(sess.online().is_some(), "streaming path active");

    let warm: Vec<_> = ds.train.iter().take(8).cloned().collect();
    let hot: Vec<_> = ds.train.iter().skip(8).take(8).cloned().collect();
    for s in warm {
        let out = sess.feed_labelled(&eng, s).unwrap();
        assert!(
            matches!(out, FeedOutcome::Observed { reservoir_step: true, .. }),
            "{out:?}"
        );
    }
    let n = allocations_in(|| {
        for s in hot {
            let out = sess.feed_labelled(&eng, s).unwrap();
            assert!(
                matches!(out, FeedOutcome::Observed { reservoir_step: true, .. }),
                "{out:?}"
            );
        }
    });
    assert_eq!(
        n, 0,
        "steady-state adapting feed_labelled performed {n} heap allocations"
    );
}

#[test]
fn trace_span_recording_is_allocation_free_after_warmup() {
    use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
    use dfr_edge::data::profiles::Profile;
    use dfr_edge::data::synth;
    use dfr_edge::util::metrics::Registry;
    use dfr_edge::util::trace::{self, Stage, TraceRecord, TraceRing};

    // the per-request observability tail the shard loop runs in steady
    // state: open a trace, run an instrumented streaming feed with the
    // span guards ARMED (the session layer holds score_fold/online_ridge
    // guards on this path), harvest the stage array, feed the stage
    // histogram, and push the record into the seqlock ring — all of it
    // must be allocation-free, or tracing would tax the serve path it
    // measures
    let prof = Profile {
        name: "mini",
        n_v: 2,
        n_c: 2,
        train: 20,
        test: 5,
        t_min: 10,
        t_max: 12,
    };
    let ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        35,
    );
    let mut cfg = SessionConfig::new(2, 2, ds.train.len());
    cfg.train.nx = 8;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    cfg.train.window = Some(12);
    cfg.train.refactor_every = 6;
    cfg.buffer_cap = ds.train.len();
    let eng = NativeEngine::new(8, 2);
    let mut sess = Session::new(1, cfg, 0xF00C);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert!(sess.online().is_some(), "streaming path active");

    let ring = TraceRing::new(64);
    let reg = Registry::default();
    let hist = reg.histogram("stage_latency");
    let warm: Vec<_> = ds.train.iter().take(8).cloned().collect();
    let hot: Vec<_> = ds.train.iter().skip(8).take(8).cloned().collect();
    let mut run_one = |sample, trace_id: u64| {
        trace::begin();
        trace::add_stage_us(Stage::QueueWait, 3);
        let out = {
            let _span = trace::span(Stage::Reply); // outer guard, nested with the session's own
            sess.feed_labelled(&eng, sample).unwrap()
        };
        assert!(matches!(out, FeedOutcome::Observed { .. }), "{out:?}");
        let stages_us = trace::take_stages();
        for &us in stages_us.iter() {
            if us > 0 {
                hist.record_us(us);
            }
        }
        ring.push(&TraceRecord {
            trace_id,
            session: 1,
            shard: 0,
            kind: 1,
            outcome: 4,
            batch: 1,
            end_us: trace::epoch_us(),
            total_us: stages_us.iter().sum(),
            stages_us,
        });
    };
    for (i, s) in warm.into_iter().enumerate() {
        run_one(s, i as u64 + 1);
    }
    let n = allocations_in(|| {
        for (i, s) in hot.into_iter().enumerate() {
            run_one(s, i as u64 + 100);
        }
    });
    assert_eq!(
        n, 0,
        "steady-state span recording performed {n} heap allocations"
    );
    // the records really landed, torn-free, with armed spans captured
    let mut out = Vec::new();
    ring.snapshot_last(16, &mut out);
    assert_eq!(out.len(), 16);
    assert!(
        out.iter()
            .all(|r| r.stages_us[Stage::QueueWait as usize] == 3),
        "stage accumulator lost a recorded span"
    );
}

#[test]
fn forward_scratch_is_allocation_free_after_warmup() {
    use dfr_edge::dfr::reservoir::{ForwardScratch, Nonlinearity, Reservoir};
    let mut rng = Pcg32::seed(0xA110D);
    let res = Reservoir {
        mask: Mask::random(30, 12, &mut rng),
        p: 0.2,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let t = 29;
    let u: Vec<f32> = (0..t * 12).map(|_| rng.normal()).collect();
    let mut scratch = ForwardScratch::new(30);
    res.forward_into(&u, t, &mut scratch); // warmup (no-op resize)
    let n = allocations_in(|| {
        for _ in 0..20 {
            res.forward_into(&u, t, &mut scratch);
        }
    });
    assert_eq!(n, 0, "forward_into allocated {n} times in steady state");
}
