//! Counting-allocator proof of the zero-allocation steady state
//! (DESIGN.md §9): after one warmup call has sized every reusable
//! buffer, `NativeEngine::features_into` and `infer_into` perform **no
//! heap allocation at all**, and the whole masking → reservoir → DPRR →
//! r̃ pipeline runs out of the per-replica workspace.
//!
//! The counter is thread-local, so allocations made concurrently by the
//! libtest harness or sibling test threads cannot pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::data::dataset::Sample;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::util::prng::Pcg32;

std::thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the bookkeeping only
// touches const-initialized thread-locals (no allocation, no recursion)
// and `try_with` tolerates TLS teardown.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TRACKING.try_with(|t| {
            if t.get() {
                let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
            }
        });
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Count heap allocations performed by `f` on this thread.
fn allocations_in(f: impl FnOnce()) -> u64 {
    ALLOCS.with(|a| a.set(0));
    TRACKING.with(|t| t.set(true));
    f();
    TRACKING.with(|t| t.set(false));
    ALLOCS.with(|a| a.get())
}

#[test]
fn features_and_infer_are_allocation_free_after_warmup() {
    // paper scale: Nx = 30, V = 12 (jpvow shape), 9 classes
    let (nx, v, n_c, t) = (30usize, 12usize, 9usize, 29usize);
    let mut rng = Pcg32::seed(0xA110C);
    let eng = NativeEngine::new(nx, n_c);
    let mask = Mask::random(nx, v, &mut rng);
    let sample = Sample {
        u: (0..t * v).map(|_| rng.normal()).collect(),
        t,
        label: 0,
    };
    let s_dim = nx * nx + nx + 1;
    let w_tilde: Vec<f32> = (0..n_c * s_dim).map(|_| 0.01 * rng.normal()).collect();

    let mut feat = Vec::new();
    let mut scores = Vec::new();
    // warmup: sizes the engine workspace and the caller buffers
    eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
    eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
        .unwrap();

    let n = allocations_in(|| {
        for _ in 0..50 {
            eng.features_into(&sample, &mask, 0.2, 0.1, &mut feat).unwrap();
            eng.infer_into(&sample, &mask, 0.2, 0.1, &w_tilde, &mut scores)
                .unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state features_into/infer_into performed {n} heap allocations"
    );

    // the zero-allocation path still computes the real thing
    assert_eq!(feat.len(), s_dim);
    assert_eq!(*feat.last().unwrap(), 1.0);
    assert_eq!(scores.len(), n_c);
    assert!((scores.iter().sum::<f32>() - 1.0).abs() < 1e-5);
}

#[test]
fn forward_scratch_is_allocation_free_after_warmup() {
    use dfr_edge::dfr::reservoir::{ForwardScratch, Nonlinearity, Reservoir};
    let mut rng = Pcg32::seed(0xA110D);
    let res = Reservoir {
        mask: Mask::random(30, 12, &mut rng),
        p: 0.2,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let t = 29;
    let u: Vec<f32> = (0..t * 12).map(|_| rng.normal()).collect();
    let mut scratch = ForwardScratch::new(30);
    res.forward_into(&u, t, &mut scratch); // warmup (no-op resize)
    let n = allocations_in(|| {
        for _ in 0..20 {
            res.forward_into(&u, t, &mut scratch);
        }
    });
    assert_eq!(n, 0, "forward_into allocated {n} times in steady state");
}
