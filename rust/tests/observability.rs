//! Observability surface integration tests (DESIGN.md §17).
//!
//! * **Prometheus conformance** — `render_prometheus()` output passes a
//!   hand-rolled text-format 0.0.4 parser: every sample line well-formed,
//!   every family `dfr_`-prefixed and announced by `# TYPE`, histogram
//!   buckets cumulative with `le` ascending and `+Inf` == `_count`, no
//!   duplicate series.
//! * **Complete traces** — under `max_batch ∈ {1, 8}` every request
//!   yields a trace whose disjoint stage spans sum to within the
//!   measured request latency, with unique trace ids.
//! * **Mid-batch generation rolls** — a burst that splits batches on
//!   every adapting feed still produces one complete trace per request;
//!   ids survive the re-plan.
//! * **Scrape under load** — concurrent `/metrics` scrapes against a
//!   busy server all parse and stay internally consistent.
//! * **Readiness** — `/readyz` flips to 503 while a `FaultyEngine`
//!   shard kill is being repaired and recovers once the supervisor
//!   respawns the shard; the death/respawn pair lands in the event
//!   journal.

use std::collections::HashSet;
use std::io::{Read as IoRead, Write as IoWrite};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use dfr_edge::coordinator::engine::{Engine, FeatureRequest, NativeEngine};
use dfr_edge::coordinator::{
    silence_injected_panics, CheckpointConfig, FaultSpec, FaultyEngine, MetricsExporter, Request,
    Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::runtime::executor::TrainState;
use dfr_edge::util::json::Json;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

fn streaming_session_config(collect: usize) -> SessionConfig {
    let mut scfg = mini_session_config(collect);
    scfg.train.window = Some(16);
    scfg
}

fn server_config(session: SessionConfig, shards: usize, max_batch: usize) -> ServerConfig {
    ServerConfig {
        queue_cap: 256,
        seed: 0xFEED,
        shards,
        max_batch,
        ..ServerConfig::new(session)
    }
}

fn labelled(session: u64, s: &Sample) -> Request {
    Request::Labelled {
        session,
        sample: s.clone(),
    }
}

fn infer_req(session: u64, s: &Sample) -> Request {
    Request::Infer {
        session,
        sample: s.clone(),
    }
}

/// Fetch trace JSON lines, polling until at least `want` are visible:
/// the shard records a trace *after* shipping the reply, so the caller
/// of request k can race the ring write of request k's own record.
fn traces_at_least(srv: &Server, want: usize, n: usize) -> Vec<Json> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let text = match srv.call(Request::Traces { n }).unwrap() {
            Response::Traces(t) => t,
            other => panic!("unexpected {other:?}"),
        };
        let parsed: Vec<Json> = text
            .lines()
            .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad trace JSON {e:?}: {l}")))
            .collect();
        if parsed.len() >= want {
            return parsed;
        }
        assert!(
            Instant::now() < deadline,
            "only {}/{want} traces became visible",
            parsed.len()
        );
        thread::sleep(Duration::from_millis(5));
    }
}

fn events_json(srv: &Server) -> Vec<Json> {
    match srv.call(Request::Events { n: 1024 }).unwrap() {
        Response::Events(t) => t
            .lines()
            .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad event JSON {e:?}: {l}")))
            .collect(),
        other => panic!("unexpected {other:?}"),
    }
}

fn u64_field(j: &Json, key: &str) -> u64 {
    j.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("missing numeric {key} in {}", j.to_string())) as u64
}

// ---------------------------------------------------------------------------
// Prometheus text-format 0.0.4 conformance
// ---------------------------------------------------------------------------

/// One parsed sample line: family name, sorted label pairs, value.
#[derive(Debug, Clone, PartialEq)]
struct PromSample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> f64 {
    match s {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        other => other
            .parse()
            .unwrap_or_else(|_| panic!("bad sample value {other:?}")),
    }
}

fn parse_sample_line(line: &str) -> PromSample {
    let (series, value) = line
        .rsplit_once(' ')
        .unwrap_or_else(|| panic!("no value separator in {line:?}"));
    let (name, labels) = match series.split_once('{') {
        None => (series.to_string(), Vec::new()),
        Some((name, rest)) => {
            let body = rest
                .strip_suffix('}')
                .unwrap_or_else(|| panic!("unterminated label set in {line:?}"));
            let mut labels = Vec::new();
            for pair in body.split(',').filter(|p| !p.is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .unwrap_or_else(|| panic!("bad label pair {pair:?} in {line:?}"));
                assert!(valid_label_name(k), "bad label name {k:?} in {line:?}");
                let v = v
                    .strip_prefix('"')
                    .and_then(|v| v.strip_suffix('"'))
                    .unwrap_or_else(|| panic!("unquoted label value in {line:?}"));
                labels.push((k.to_string(), v.to_string()));
            }
            (name.to_string(), labels)
        }
    };
    assert!(valid_metric_name(&name), "bad metric name {name:?}");
    PromSample {
        name,
        labels,
        value: parse_value(value),
    }
}

/// The conformance check: parse the full exposition, validate structure,
/// return the samples for further assertions.
fn check_prometheus(text: &str) -> Vec<PromSample> {
    let mut typed: Vec<(String, String)> = Vec::new(); // (family, type)
    let mut samples = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let fam = it.next().expect("TYPE family").to_string();
            let ty = it.next().expect("TYPE kind").to_string();
            assert!(
                matches!(ty.as_str(), "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                "unknown TYPE {ty:?}"
            );
            assert!(
                !typed.iter().any(|(f, _)| *f == fam),
                "family {fam} announced twice"
            );
            typed.push((fam, ty));
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP / comments
        }
        samples.push(parse_sample_line(line));
    }
    // every sample belongs to an announced family, and is dfr_-prefixed
    for s in &samples {
        assert!(s.name.starts_with("dfr_"), "family not namespaced: {}", s.name);
        let fam = typed.iter().find(|(f, _)| {
            s.name == *f
                || (s.name.strip_prefix(f.as_str()).is_some_and(|suf| {
                    matches!(suf, "_bucket" | "_sum" | "_count")
                }))
        });
        let (fam, ty) = fam.unwrap_or_else(|| panic!("sample {} has no # TYPE", s.name));
        if s.name != *fam {
            assert_eq!(ty, "histogram", "suffixed sample under non-histogram {fam}");
        }
    }
    // no duplicate series
    let mut seen = HashSet::new();
    for s in &samples {
        let key = format!("{}{:?}", s.name, s.labels);
        assert!(seen.insert(key), "duplicate series: {} {:?}", s.name, s.labels);
    }
    // histogram structure: per series (labels minus `le`), buckets are
    // cumulative, le ascending, +Inf == _count, _sum present
    for (fam, ty) in typed.iter().filter(|(_, t)| t == "histogram") {
        let bucket_name = format!("{fam}_bucket");
        let mut by_series: Vec<(Vec<(String, String)>, Vec<(f64, f64)>)> = Vec::new();
        for s in samples.iter().filter(|s| s.name == bucket_name) {
            let mut labels = s.labels.clone();
            let le = labels
                .iter()
                .position(|(k, _)| k == "le")
                .map(|i| labels.remove(i).1)
                .unwrap_or_else(|| panic!("bucket without le: {s:?}"));
            let le = parse_value(&le);
            match by_series.iter_mut().find(|(l, _)| *l == labels) {
                Some((_, v)) => v.push((le, s.value)),
                None => by_series.push((labels, vec![(le, s.value)])),
            }
        }
        for (labels, buckets) in &by_series {
            for w in buckets.windows(2) {
                assert!(w[0].0 < w[1].0, "{fam} le not ascending: {buckets:?}");
                assert!(
                    w[0].1 <= w[1].1,
                    "{fam}{labels:?} buckets not cumulative: {buckets:?}"
                );
            }
            let last = buckets.last().expect("at least one bucket");
            assert!(last.0.is_infinite(), "{fam} last bucket is not +Inf");
            let count = samples
                .iter()
                .find(|s| s.name == format!("{fam}_count") && s.labels == *labels)
                .unwrap_or_else(|| panic!("{fam}_count missing for {labels:?}"));
            assert_eq!(last.1, count.value, "{fam} +Inf bucket != _count");
            assert!(
                samples
                    .iter()
                    .any(|s| s.name == format!("{fam}_sum") && s.labels == *labels),
                "{fam}_sum missing for {labels:?}"
            );
        }
    }
    samples
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[test]
fn prometheus_exposition_conforms() {
    let ds = mini_dataset(17);
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, 8),
    );
    for session in 0..2u64 {
        for s in &ds.train {
            srv.call(labelled(session, s)).unwrap();
        }
        for s in &ds.test {
            srv.call(infer_req(session, s)).unwrap();
        }
    }
    let text = srv.metrics.render_prometheus();
    let samples = check_prometheus(&text);
    // the families the scrape dashboard is built on all exist
    for fam in [
        "dfr_requests_total",
        "dfr_shards_active",
        "dfr_stage_latency_seconds_count",
    ] {
        assert!(
            samples.iter().any(|s| s.name == *fam),
            "{fam} missing from exposition:\n{text}"
        );
    }
    // traffic actually flowed into the stage histograms
    let total_stage_count: f64 = samples
        .iter()
        .filter(|s| s.name == "dfr_stage_latency_seconds_count")
        .map(|s| s.value)
        .sum();
    assert!(total_stage_count > 0.0, "no stage spans recorded:\n{text}");
    srv.shutdown();
}

fn assert_complete_traces(max_batch: usize) {
    let ds = mini_dataset(23);
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, max_batch),
    );
    let mut calls = 0usize;
    for session in 0..2u64 {
        for s in &ds.train {
            srv.call(labelled(session, s)).unwrap();
            calls += 1;
        }
        for s in &ds.test {
            srv.call(infer_req(session, s)).unwrap();
            calls += 1;
        }
    }
    let traces = traces_at_least(&srv, calls, 4096);
    assert!(
        traces.len() >= calls,
        "incomplete trace coverage: {} traces for {calls} requests",
        traces.len()
    );
    let mut ids = HashSet::new();
    for t in &traces {
        let id = u64_field(t, "trace_id");
        assert!(id > 0, "unminted trace id in {}", t.to_string());
        assert!(ids.insert(id), "duplicate trace id {id}");
        let total = u64_field(t, "total_us");
        let stages = t
            .get("stages_us")
            .and_then(Json::as_obj)
            .unwrap_or_else(|| panic!("no stages_us in {}", t.to_string()));
        assert_eq!(stages.len(), 7, "stage taxonomy incomplete: {stages:?}");
        let sum: u64 = stages
            .values()
            .map(|v| v.as_f64().expect("numeric stage") as u64)
            .sum();
        // disjoint spans: the per-stage sum is bounded by the measured
        // envelope residency (enqueue → reply shipped)
        assert!(
            sum <= total,
            "stage spans exceed request latency: sum={sum} total={total} in {}",
            t.to_string()
        );
        let kind = t.get("kind").and_then(Json::as_str).expect("kind");
        assert!(
            matches!(kind, "labelled" | "infer"),
            "unexpected request kind {kind}"
        );
        let batch = u64_field(t, "batch");
        assert!(
            batch >= 1 && batch <= max_batch as u64,
            "batch depth {batch} out of range for max_batch={max_batch}"
        );
    }
    srv.shutdown();
}

#[test]
fn every_request_traces_completely_per_call() {
    assert_complete_traces(1);
}

#[test]
fn every_request_traces_completely_batched() {
    assert_complete_traces(8);
}

// ---------------------------------------------------------------------------
// mid-batch generation rolls
// ---------------------------------------------------------------------------

/// NativeEngine wrapper that sleeps in `train_step` only, keeping the
/// shard busy so a burst queues into multi-request drain cycles (same
/// technique as `tests/batch_equivalence.rs`).
struct SlowAdaptEngine {
    inner: NativeEngine,
    delay: Duration,
}

impl Engine for SlowAdaptEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        thread::sleep(self.delay);
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }
    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }
    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.inner.features_into(s, mask, p, q, out)
    }
    fn features_batch_into(
        &self,
        reqs: &[FeatureRequest<'_>],
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        self.inner.features_batch_into(reqs, outs)
    }
    fn scores_from_features_exact(&self) -> bool {
        self.inner.scores_from_features_exact()
    }
    fn kernels(&self) -> dfr_edge::simd::Kernels {
        self.inner.kernels()
    }
    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w: &[f32]) -> Result<Vec<f32>> {
        self.inner.infer(s, mask, p, q, w)
    }
    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        self.inner.infer_into(s, mask, p, q, w, scores)
    }
    fn name(&self) -> &'static str {
        "slow-adapt"
    }
    fn fork(&self) -> Option<Box<dyn Engine>> {
        Some(Box::new(SlowAdaptEngine {
            inner: NativeEngine::new(8, 2),
            delay: self.delay,
        }))
    }
}

#[test]
fn trace_ids_survive_mid_batch_generation_roll_splits() {
    let ds = mini_dataset(41);
    let mut scfg = streaming_session_config(ds.train.len());
    scfg.adapt_reservoir = true;
    scfg.adapt_lr = 0.05;
    scfg.adapt_drift_eps = 1e-6; // every adapting feed rolls a generation
    let srv = Server::spawn(
        Box::new(SlowAdaptEngine {
            inner: NativeEngine::new(8, 2),
            delay: Duration::from_millis(2),
        }),
        server_config(scfg, 1, 8),
    );
    // deterministic prefix: train both sessions
    let mut prefix = 0usize;
    for session in 0..2u64 {
        let mut trained = false;
        for s in &ds.train {
            if let Response::Trained { .. } = srv.call(labelled(session, s)).unwrap() {
                trained = true;
            }
            prefix += 1;
        }
        assert!(trained, "session {session} never trained");
    }
    // burst: enqueue faster than the 2 ms/step shard drains, so cycles
    // batch several same-session feeds and the first roll of each cycle
    // forces the rest through the re-planned per-call path
    let mut pending = Vec::new();
    for i in 0..16 {
        for session in 0..2u64 {
            let rx = srv
                .try_call(labelled(session, &ds.train[i % ds.train.len()]))
                .unwrap()
                .expect("queue_cap sized for the whole burst");
            pending.push(rx);
        }
    }
    let burst = pending.len();
    let mut adapted = 0;
    for rx in pending {
        if let Response::Adapted { .. } = rx.recv().unwrap() {
            adapted += 1;
        }
    }
    assert!(adapted > 0, "burst never adapted — rolls were not exercised");
    let traces = traces_at_least(&srv, prefix + burst, 4096);
    // every burst request has exactly one complete trace with a unique id
    let mut ids = HashSet::new();
    let mut adapted_traces = 0;
    for t in &traces {
        let id = u64_field(t, "trace_id");
        assert!(id > 0 && ids.insert(id), "bad/duplicate trace id {id}");
        let total = u64_field(t, "total_us");
        let sum: u64 = t
            .get("stages_us")
            .and_then(Json::as_obj)
            .expect("stages_us")
            .values()
            .map(|v| v.as_f64().expect("numeric") as u64)
            .sum();
        assert!(sum <= total, "span sum {sum} > latency {total}");
        if t.get("outcome").and_then(Json::as_str) == Some("adapted") {
            adapted_traces += 1;
        }
    }
    assert!(
        traces.len() >= prefix + burst,
        "re-planned requests lost their traces: {} < {}",
        traces.len(),
        prefix + burst
    );
    assert_eq!(
        adapted_traces, adapted,
        "adapted responses and adapted traces disagree"
    );
    // the generation rolls were journaled
    let events = events_json(&srv);
    assert!(
        events
            .iter()
            .any(|e| e.get("kind").and_then(Json::as_str) == Some("generation_roll")),
        "no generation_roll event despite {adapted} Adapted responses"
    );
    srv.shutdown();
}

// ---------------------------------------------------------------------------
// concurrent scrape under load
// ---------------------------------------------------------------------------

fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nAccept: text/plain\r\n\r\n").unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let raw = String::from_utf8(raw).expect("utf-8 response");
    let (head, body) = raw.split_once("\r\n\r\n").expect("header terminator");
    (head.to_string(), body.to_string())
}

#[test]
fn concurrent_scrapes_under_load_stay_consistent() {
    let ds = mini_dataset(31);
    let srv = Arc::new(Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, 8),
    ));
    let exporter = MetricsExporter::bind(Arc::clone(&srv), "127.0.0.1:0").unwrap();
    let addr = exporter.local_addr();

    // feeder thread: continuous labelled + infer traffic
    let feeder = {
        let srv = Arc::clone(&srv);
        let ds = ds.clone();
        thread::spawn(move || {
            for round in 0..6 {
                for (i, s) in ds.train.iter().enumerate() {
                    let session = (round * ds.train.len() + i) as u64 % 4;
                    let _ = srv.call(labelled(session, s));
                }
            }
        })
    };
    // scrapers: every response parses and is internally consistent
    let scrapers: Vec<_> = (0..4)
        .map(|_| {
            thread::spawn(move || {
                for _ in 0..10 {
                    let (head, body) = http_get(addr, "/metrics");
                    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
                    check_prometheus(&body);
                }
            })
        })
        .collect();
    for h in scrapers {
        h.join().unwrap();
    }
    feeder.join().unwrap();
    drop(exporter);
    if let Ok(owned) = Arc::try_unwrap(srv) {
        owned.shutdown();
    }
}

// ---------------------------------------------------------------------------
// readiness under shard failure
// ---------------------------------------------------------------------------

#[test]
fn readyz_flips_during_shard_kill_and_recovers() {
    silence_injected_panics();
    let ds = mini_dataset(29);
    let dir = std::env::temp_dir().join(format!("dfr-obs-readyz-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = FaultSpec {
        seed: 1,
        kill_after: Some(5),
        kill_replica: Some(1),
        ..FaultSpec::default()
    };
    let mut cfg = server_config(mini_session_config(ds.train.len()), 2, 8);
    cfg.checkpoint = Some(CheckpointConfig {
        dir: dir.clone(),
        every: 1,
    });
    let srv = Arc::new(Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        cfg,
    ));
    let exporter = MetricsExporter::bind(Arc::clone(&srv), "127.0.0.1:0").unwrap();
    let addr = exporter.local_addr();

    // ready while healthy
    let (head, body) = http_get(addr, "/readyz");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}: {body}");

    // drive session 1 (shard 1) into the scheduled kill; the killing
    // call loses its reply
    let mut died = false;
    let mut saw_unready = false;
    for s in &ds.train {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match srv.call_timeout(labelled(1, s), Duration::from_millis(500)) {
                Ok(_) => break,
                Err(_) => {
                    died = true;
                    // the shard is down right now: its queue receiver is
                    // gone until the supervisor swaps in the respawn, so
                    // readiness must report the outage
                    if srv.readiness().is_err() {
                        saw_unready = true;
                    }
                    assert!(Instant::now() < deadline, "shard recovery exceeded 30 s");
                }
            }
        }
    }
    assert!(died, "the kill schedule must have taken shard 1 down");
    assert!(
        saw_unready,
        "readiness never reported the dead shard while calls were failing"
    );

    // ... and /readyz converges back to 200 once the supervisor respawns
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (head, body) = http_get(addr, "/readyz");
        if head.starts_with("HTTP/1.1 200") {
            break;
        }
        assert!(
            head.starts_with("HTTP/1.1 503"),
            "unexpected readiness status {head}: {body}"
        );
        assert!(Instant::now() < deadline, "readiness never recovered: {body}");
        thread::sleep(Duration::from_millis(20));
    }

    // the outage is journaled as a death/respawn pair
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let events = events_json(&srv);
        let deaths = events
            .iter()
            .filter(|e| e.get("kind").and_then(Json::as_str) == Some("shard_death"))
            .count();
        let respawns = events
            .iter()
            .filter(|e| e.get("kind").and_then(Json::as_str) == Some("shard_respawn"))
            .count();
        if deaths >= 1 && respawns >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "death/respawn never journaled: {deaths} deaths, {respawns} respawns"
        );
        thread::sleep(Duration::from_millis(20));
    }

    drop(exporter);
    if let Ok(owned) = Arc::try_unwrap(srv) {
        owned.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
