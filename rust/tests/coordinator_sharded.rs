//! Integration tests for the sharded coordinator: concurrent clients
//! across shards, queue saturation (`try_call` backpressure), graceful
//! shutdown draining, and per-shard metrics in the `Stats` snapshot.

use std::thread;
use std::time::Duration;

use anyhow::Result;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::coordinator::{Request, Response, Server, ServerConfig, SessionConfig};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::runtime::executor::TrainState;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

/// An engine that sleeps in the hot operations — makes queue saturation
/// and drain ordering deterministic to test.
struct SlowEngine {
    inner: NativeEngine,
    delay: Duration,
}

impl SlowEngine {
    fn new(nx: usize, n_c: usize, delay: Duration) -> Self {
        SlowEngine {
            inner: NativeEngine::new(nx, n_c),
            delay,
        }
    }
}

impl Engine for SlowEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        thread::sleep(self.delay);
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }

    fn infer(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
    ) -> Result<Vec<f32>> {
        thread::sleep(self.delay);
        self.inner.infer(s, mask, p, q, w_tilde)
    }

    fn name(&self) -> &'static str {
        "slow"
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        Some(Box::new(SlowEngine::new(
            self.inner.nx,
            self.inner.n_c,
            self.delay,
        )))
    }
}

#[test]
fn concurrent_clients_across_shards() {
    let ds = mini_dataset(21);
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 256,
            seed: 0xFEED,
            shards: 4,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(ds.train.len()))
        },
    );
    assert_eq!(srv.shards(), 4);

    // 4 client threads, each driving two sessions that land on the same
    // shard (k and k + 4) — full train-then-serve lifecycle per session
    thread::scope(|scope| {
        for k in 0..4u64 {
            let srv = &srv;
            let ds = &ds;
            scope.spawn(move || {
                for session in [k, k + 4] {
                    let mut trained = false;
                    for s in &ds.train {
                        if let Response::Trained { .. } = srv
                            .call(Request::Labelled {
                                session,
                                sample: s.clone(),
                            })
                            .unwrap()
                        {
                            trained = true;
                        }
                    }
                    assert!(trained, "session {session} never trained");
                    for s in &ds.test {
                        let r = srv
                            .call(Request::Infer {
                                session,
                                sample: s.clone(),
                            })
                            .unwrap();
                        assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
                    }
                }
            });
        }
    });

    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => {
            // 8 sessions × 10 test samples, aggregated across shards
            assert!(t.contains("counter inferences_total 80"), "{t}");
            assert!(t.contains("counter trainings_total 8"), "{t}");
            // every shard served exactly 2 sessions
            for shard in 0..4 {
                assert!(
                    t.contains(&format!("trainings_total{{shard=\"{shard}\"}} 2")),
                    "{t}"
                );
            }
        }
        other => panic!("{other:?}"),
    }
    srv.shutdown();
}

#[test]
fn try_call_sheds_load_when_shard_queue_saturated() {
    let ds = mini_dataset(22);
    // collect_target 1 → every labelled sample triggers a (slow) training
    let mut scfg = mini_session_config(1);
    scfg.retrain_after = Some(1);
    // keep the session buffer from capping out first — this test is about
    // the *queue* level of backpressure, not the buffer level
    scfg.buffer_cap = 10_000;
    let srv = Server::spawn(
        Box::new(SlowEngine::new(8, 2, Duration::from_millis(30))),
        ServerConfig {
            queue_cap: 1, // per-shard queue of 1
            seed: 1,
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        },
    );

    // keep submitting slow trainings; with a queue of one and a busy
    // shard, try_call must eventually refuse
    let mut accepted = Vec::new();
    let mut saturated = false;
    for _ in 0..200 {
        match srv
            .try_call(Request::Labelled {
                session: 0,
                sample: ds.train[0].clone(),
            })
            .unwrap()
        {
            Some(rx) => accepted.push(rx),
            None => {
                saturated = true;
                break;
            }
        }
    }
    assert!(saturated, "queue never saturated after 200 try_calls");
    assert!(!accepted.is_empty(), "nothing was accepted before saturation");
    // every accepted request still gets a real reply
    for rx in accepted {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("accepted request lost its reply");
        assert!(
            matches!(resp, Response::Trained { .. } | Response::Accepted { .. }),
            "{resp:?}"
        );
    }
    srv.shutdown();
}

#[test]
fn shutdown_drains_all_shards_without_lost_replies() {
    let ds = mini_dataset(23);
    let srv = Server::spawn(
        Box::new(SlowEngine::new(8, 2, Duration::from_millis(20))),
        ServerConfig {
            queue_cap: 16, // 8 per shard
            seed: 2,
            shards: 2,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(1))
        },
    );

    // queue slow trainings on both shards, then shut down immediately —
    // the drain protocol must answer every accepted request first
    let mut pending = Vec::new();
    for session in 0..6u64 {
        if let Some(rx) = srv
            .try_call(Request::Labelled {
                session,
                sample: ds.train[0].clone(),
            })
            .unwrap()
        {
            pending.push((session, rx));
        }
    }
    assert!(pending.len() >= 4, "expected most requests queued");
    srv.shutdown();
    for (session, rx) in pending {
        let resp = rx.recv().unwrap_or_else(|_| {
            panic!("session {session}: reply lost during shutdown")
        });
        assert!(matches!(resp, Response::Trained { .. }), "{resp:?}");
    }
}

#[test]
fn stats_exposes_per_shard_and_aggregate_metrics() {
    let ds = mini_dataset(24);
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 64,
            seed: 3,
            shards: 4,
            max_batch: 8,
            // never trains (collect target far above the feed count)
            ..ServerConfig::new(mini_session_config(50))
        },
    );
    // one labelled sample per shard
    for session in 0..4u64 {
        let r = srv
            .call(Request::Labelled {
                session,
                sample: ds.train[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Accepted { .. }), "{r:?}");
    }
    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => {
            assert!(t.contains("gauge shards_active 4"), "{t}");
            // the 4 labelled requests; Stats itself is answered inline by
            // the server handle and does not hit any shard
            assert!(t.contains("counter requests_total 4"), "{t}");
            for shard in 0..4 {
                assert!(
                    t.contains(&format!("requests_total{{shard=\"{shard}\"}} 1")),
                    "{t}"
                );
            }
        }
        other => panic!("{other:?}"),
    }
    srv.shutdown();
}

#[test]
fn streaming_session_adapts_to_drift_without_retrain() {
    // Serve-phase drift: after batch training, the label semantics flip
    // (class 0's signal starts meaning class 1 and vice versa — the
    // strongest concept drift a 2-class stream can exhibit). With
    // λ-forgetting enabled the session must (a) answer every labelled
    // sample with `Observed`, (b) never re-enter the batch pipeline, and
    // (c) recover post-drift accuracy purely through rank-1 updates.
    let ds = mini_dataset(26);
    let mut scfg = mini_session_config(ds.train.len());
    scfg.train.forgetting = Some(0.92);
    scfg.train.refactor_every = 16;
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 64,
            seed: 5,
            shards: 2,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        },
    );
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv
            .call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            trained = true;
        }
    }
    assert!(trained);

    let flip = |s: &Sample| {
        let mut s2 = s.clone();
        s2.label = 1 - s2.label;
        s2
    };
    // accuracy under the flipped labels BEFORE adaptation
    let accuracy_flipped = |srv: &Server| -> usize {
        ds.test
            .iter()
            .filter(|s| {
                matches!(
                    srv.call(Request::Infer { session: 1, sample: s.clone() }).unwrap(),
                    Response::Prediction { class, .. } if class == 1 - s.label
                )
            })
            .count()
    };
    let pre = accuracy_flipped(&srv);

    // drift stream: three passes of flipped labelled samples — every
    // response must be the streaming ack, never Trained/Rejected
    let mut observed = 0u64;
    for _ in 0..3 {
        for s in &ds.train {
            match srv
                .call(Request::Labelled {
                    session: 1,
                    sample: flip(s),
                })
                .unwrap()
            {
                Response::Observed { updates, .. } => {
                    observed += 1;
                    assert!(updates > 0);
                }
                other => panic!("expected Observed during drift stream, got {other:?}"),
            }
        }
    }
    assert_eq!(observed, 3 * ds.train.len() as u64);

    let post = accuracy_flipped(&srv);
    assert!(
        post >= 6 && post > pre,
        "post-drift accuracy did not recover: {pre}/10 -> {post}/10"
    );

    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => {
            // exactly the one batch training; all adaptation was online
            assert!(t.contains("counter trainings_total 1"), "{t}");
            assert!(
                t.contains(&format!("counter online_updates_total {observed}")),
                "{t}"
            );
        }
        other => panic!("{other:?}"),
    }
    srv.shutdown();
}

#[test]
fn bursty_load_batches_while_preserving_per_session_semantics() {
    /// NativeEngine wrapper that sleeps in `features` — the hot
    /// operation of the streaming Serve feed — so a request burst
    /// outpaces the drain and batches form deterministically. The
    /// default `features_into`/`features_batch_into` both route through
    /// `features`, so the drain stays slow whichever path it takes.
    struct SlowFeatureEngine(NativeEngine, Duration);
    impl Engine for SlowFeatureEngine {
        fn train_step(
            &self,
            s: &Sample,
            mask: &Mask,
            state: &mut TrainState,
            lr_res: f32,
            lr_out: f32,
        ) -> Result<f32> {
            self.0.train_step(s, mask, state, lr_res, lr_out)
        }
        fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
            thread::sleep(self.1);
            self.0.features(s, mask, p, q)
        }
        fn infer(
            &self,
            s: &Sample,
            mask: &Mask,
            p: f32,
            q: f32,
            w: &[f32],
        ) -> Result<Vec<f32>> {
            self.0.infer(s, mask, p, q, w)
        }
        fn name(&self) -> &'static str {
            "slow-features"
        }
        fn fork(&self) -> Option<Box<dyn Engine>> {
            Some(Box::new(SlowFeatureEngine(
                NativeEngine::new(self.0.nx, self.0.n_c),
                self.1,
            )))
        }
    }

    fn counter_value(stats: &str, name: &str) -> u64 {
        let prefix = format!("counter {name} ");
        stats
            .lines()
            .find_map(|l| l.strip_prefix(prefix.as_str()))
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0)
    }
    fn hist_count(stats: &str, name: &str) -> u64 {
        let prefix = format!("hist {name} count ");
        stats
            .lines()
            .find_map(|l| l.strip_prefix(prefix.as_str()))
            .and_then(|rest| rest.split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    }

    let ds = mini_dataset(27);
    // streaming Serve (PR 5 semantics): every burst feed must be
    // answered `Observed` — batching may not change that
    let mut scfg = mini_session_config(ds.train.len());
    scfg.train.window = Some(16);
    let srv = Server::spawn(
        Box::new(SlowFeatureEngine(
            NativeEngine::new(8, 2),
            Duration::from_millis(3),
        )),
        ServerConfig {
            queue_cap: 128,
            seed: 6,
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        },
    );

    // train two sessions synchronously (each call is its own size-1
    // drain cycle — no batching in this prefix)
    for session in 0..2u64 {
        let mut trained = false;
        for s in &ds.train {
            if let Response::Trained { .. } = srv
                .call(Request::Labelled {
                    session,
                    sample: s.clone(),
                })
                .unwrap()
            {
                trained = true;
            }
        }
        assert!(trained, "session {session} never trained");
    }

    // bursty multi-session load: enqueue 40 interleaved feeds faster
    // than the shard can drain them (each feed costs a ≥3 ms feature
    // extraction), then collect every reply in submission order
    let mut pending = Vec::new();
    for i in 0..20 {
        for session in 0..2u64 {
            let rx = srv
                .try_call(Request::Labelled {
                    session,
                    sample: ds.train[i % ds.train.len()].clone(),
                })
                .unwrap()
                .expect("queue_cap sized for the whole burst");
            pending.push((session, rx));
        }
    }
    // responses stay paired per session and ordered per session: the
    // fold count in `Observed` is the session accumulator's lifetime
    // total, so within one session it must advance by exactly 1 per
    // response, in submission order
    let mut last_updates = [None::<u64>, None::<u64>];
    for (session, rx) in pending {
        match rx.recv().unwrap() {
            Response::Observed { updates, window } => {
                assert!(window <= 16, "{window}");
                if let Some(prev) = last_updates[session as usize] {
                    assert_eq!(
                        updates,
                        prev + 1,
                        "session {session}: per-session ordering broken"
                    );
                }
                last_updates[session as usize] = Some(updates);
            }
            other => panic!("expected Observed during burst, got {other:?}"),
        }
    }

    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => {
            // Observed/Adapted semantics unchanged: 40 online folds, no
            // generation rolls, nothing rejected or retrained mid-burst
            assert_eq!(counter_value(&t, "online_updates_total"), 40, "{t}");
            assert_eq!(counter_value(&t, "refeaturize_total"), 0, "{t}");
            assert_eq!(counter_value(&t, "trainings_total"), 2, "{t}");
            // no mid-batch generation rolls → nothing to split
            assert_eq!(counter_value(&t, "batch_splits_total"), 0, "{t}");
            // the batch_size histogram records one sample per drain
            // cycle (size encoded as µs), labelled per shard
            assert!(t.contains("hist batch_size{shard=\"0\"} count "), "{t}");
            let requests = counter_value(&t, "requests_total");
            let cycles = hist_count(&t, "batch_size");
            assert_eq!(requests, 80, "{t}");
            // non-trivial batching: the 40 synchronous training calls
            // are 40 size-1 cycles, so the 40-request burst must have
            // drained in far fewer than 40 cycles (≥ 2 requests/batch
            // on average)
            assert!(
                cycles >= 45 && cycles <= 60,
                "drain cycles {cycles} for {requests} requests — burst never batched\n{t}"
            );
        }
        other => panic!("{other:?}"),
    }
    srv.shutdown();
}

#[test]
fn engine_without_fork_degrades_to_single_shard() {
    /// NativeEngine wrapper that refuses to fork (the default trait impl).
    struct Unforkable(NativeEngine);
    impl Engine for Unforkable {
        fn train_step(
            &self,
            s: &Sample,
            mask: &Mask,
            state: &mut TrainState,
            lr_res: f32,
            lr_out: f32,
        ) -> Result<f32> {
            self.0.train_step(s, mask, state, lr_res, lr_out)
        }
        fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
            self.0.features(s, mask, p, q)
        }
        fn infer(
            &self,
            s: &Sample,
            mask: &Mask,
            p: f32,
            q: f32,
            w: &[f32],
        ) -> Result<Vec<f32>> {
            self.0.infer(s, mask, p, q, w)
        }
        fn name(&self) -> &'static str {
            "unforkable"
        }
    }

    let ds = mini_dataset(25);
    let srv = Server::spawn(
        Box::new(Unforkable(NativeEngine::new(8, 2))),
        ServerConfig {
            queue_cap: 64,
            seed: 4,
            shards: 8,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(ds.train.len()))
        },
    );
    assert_eq!(srv.shards(), 1, "unforkable engine must fall back to 1 shard");
    // still fully functional
    for s in &ds.train {
        srv.call(Request::Labelled {
            session: 11,
            sample: s.clone(),
        })
        .unwrap();
    }
    let r = srv
        .call(Request::Infer {
            session: 11,
            sample: ds.test[0].clone(),
        })
        .unwrap();
    assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
    srv.shutdown();
}
