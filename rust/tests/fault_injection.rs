//! Deterministic fault-injection harness for the supervised coordinator
//! (DESIGN.md §15).
//!
//! Every fault in here is scheduled by [`FaultyEngine`] from a fixed
//! seed, so each scenario is exactly reproducible:
//!
//! * **panic isolation** — a 2-shard server under concurrent load with
//!   a 2% per-call panic rate answers every accepted request and keeps
//!   serving (no hangs, no lost replies);
//! * **typed transport errors** — a bounded [`Server::call_timeout`]
//!   comes back [`CallError::Timeout`] on a saturated queue instead of
//!   hanging, and rides the retry/backoff path (`queue_retries_total`)
//!   once the queue frees up;
//! * **shard supervision** — a [`ShardKill`](dfr_edge::coordinator::ShardKill)
//!   takes a whole shard thread down; the supervisor detects it,
//!   respawns a replica forked from the reserve template, and rehydrates
//!   the shard's sessions from the checkpoint directory;
//! * **durable checkpoints** — kill-then-restart and clean-shutdown-
//!   then-restart both resume **bitwise equal** to an uninterrupted
//!   reference run from the last checkpoint boundary;
//! * **non-finite quarantine** — injected NaN features/scores are
//!   quarantined (`nonfinite_quarantined_total`), surfaced as typed
//!   `Response::Error { kind: NonFinite }` on the inference path, and
//!   the session self-heals through the batch-fallback retrain;
//! * **bounded shutdown** — a shard wedged behind seconds of work is
//!   skipped at the drain deadline (`shutdown_drain_skipped_total`)
//!   instead of stalling `Server::shutdown`.

use std::fs;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::coordinator::{
    silence_injected_panics, CallError, CheckpointConfig, ErrorKind, FaultSpec, FaultyEngine,
    Request, Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::runtime::executor::TrainState;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

/// Streaming variant: labelled Serve samples fold into the sliding-
/// window online ridge (1 engine call each), giving the checkpoint
/// tests a mid-stream state worth restoring.
fn streaming_session_config(collect: usize) -> SessionConfig {
    let mut scfg = mini_session_config(collect);
    scfg.train.window = Some(16);
    scfg
}

fn server_config(
    session: SessionConfig,
    shards: usize,
    checkpoint: Option<CheckpointConfig>,
) -> ServerConfig {
    let mut cfg = ServerConfig {
        queue_cap: 64,
        seed: 0xFEED,
        shards,
        max_batch: 8,
        ..ServerConfig::new(session)
    };
    cfg.checkpoint = checkpoint;
    cfg
}

fn labelled(session: u64, s: &Sample) -> Request {
    Request::Labelled {
        session,
        sample: s.clone(),
    }
}

fn infer_req(session: u64, s: &Sample) -> Request {
    Request::Infer {
        session,
        sample: s.clone(),
    }
}

fn stats_text(srv: &Server) -> String {
    match srv.call(Request::Stats).expect("stats is answered inline") {
        Response::StatsText(text) => text,
        other => panic!("expected stats text, got {other:?}"),
    }
}

/// Value of the aggregate `counter <name> <value>` / `gauge <name>
/// <value>` line in a metrics snapshot (0 when the instrument never
/// registered). Level instruments moved from counters to typed gauges;
/// accepting both prefixes keeps this helper instrument-agnostic.
fn counter_total(stats: &str, name: &str) -> u64 {
    let counter = format!("counter {name} ");
    let gauge = format!("gauge {name} ");
    stats
        .lines()
        .find_map(|l| l.strip_prefix(&counter).or_else(|| l.strip_prefix(&gauge)))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Zero the only wall-clock field in any response so bitwise comparisons
/// across runs are meaningful.
fn normalize(mut resp: Response) -> Response {
    if let Response::Trained { train_seconds, .. } = &mut resp {
        *train_seconds = 0.0;
    }
    resp
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dfr-fi-{name}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// An engine whose inference path is slow (and exempt from the exact
/// scores-from-features shortcut, so batches cannot skip the sleep) —
/// makes queue saturation and drain wedging deterministic to provoke.
struct SlowInfer {
    inner: NativeEngine,
    delay: Duration,
}

impl SlowInfer {
    fn new(nx: usize, n_c: usize, delay: Duration) -> Self {
        SlowInfer {
            inner: NativeEngine::new(nx, n_c),
            delay,
        }
    }
}

impl Engine for SlowInfer {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }

    fn scores_from_features_exact(&self) -> bool {
        false
    }

    fn kernels(&self) -> dfr_edge::simd::Kernels {
        self.inner.kernels()
    }

    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w_tilde: &[f32]) -> Result<Vec<f32>> {
        thread::sleep(self.delay);
        self.inner.infer(s, mask, p, q, w_tilde)
    }

    fn name(&self) -> &'static str {
        "slow-infer"
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        Some(Box::new(SlowInfer::new(
            self.inner.nx,
            self.inner.n_c,
            self.delay,
        )))
    }
}

// ---------------------------------------------------------------------
// panic isolation

#[test]
fn panics_are_isolated_and_every_request_is_answered() {
    silence_injected_panics();
    let ds = mini_dataset(21);
    let spec = FaultSpec {
        seed: 0xFA01,
        p_panic: 0.02,
        ..FaultSpec::default()
    };
    let srv = Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        server_config(mini_session_config(ds.train.len()), 2, None),
    );

    // 4 concurrent clients, 8 sessions across 2 shards; with a 2%
    // per-call panic rate most training attempts die mid-pipeline, so
    // every session exercises the catch_unwind → Error → degraded →
    // recovery-retrain loop several times over
    thread::scope(|scope| {
        for k in 0..4u64 {
            let srv = &srv;
            let ds = &ds;
            scope.spawn(move || {
                for session in [k, k + 4] {
                    let mut trained = false;
                    for s in &ds.train {
                        for _ in 0..200 {
                            let resp = srv
                                .call_timeout(labelled(session, s), Duration::from_secs(30))
                                .expect("an accepted request must be answered, never lost");
                            match resp {
                                // isolated fault — the sample was not
                                // applied; retry it
                                Response::Error { .. } => continue,
                                Response::Trained { .. } => {
                                    trained = true;
                                    break;
                                }
                                _ => break,
                            }
                        }
                    }
                    assert!(
                        trained,
                        "session {session} must finish training despite 2% panics"
                    );
                    let mut served = false;
                    for _ in 0..200 {
                        match srv
                            .call_timeout(infer_req(session, &ds.test[0]), Duration::from_secs(30))
                            .expect("an accepted request must be answered, never lost")
                        {
                            Response::Prediction { scores, .. } => {
                                assert!(scores.iter().all(|x| x.is_finite()));
                                served = true;
                                break;
                            }
                            Response::Error { .. } => continue,
                            other => panic!("session {session}: unexpected {other:?}"),
                        }
                    }
                    assert!(served, "session {session} must serve despite 2% panics");
                }
            });
        }
    });

    let st = stats_text(&srv);
    assert!(
        counter_total(&st, "request_panics_total") + counter_total(&st, "plan_panics_total") > 0,
        "2% of hundreds of engine calls must have panicked and been isolated:\n{st}"
    );
    assert_eq!(counter_total(&st, "shards_active"), 2, "no shard may die from an isolatable panic:\n{st}");
    srv.shutdown();
}

// ---------------------------------------------------------------------
// typed transport errors instead of hangs

#[test]
fn call_timeout_is_typed_and_retries_a_saturated_queue() {
    let ds = mini_dataset(23);
    let srv = Server::spawn(
        Box::new(SlowInfer::new(8, 2, Duration::from_millis(300))),
        ServerConfig {
            queue_cap: 1,
            seed: 0xFEED,
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(mini_session_config(ds.train.len()))
        },
    );
    // train through the fast labelled path
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv.call(labelled(0, s)).unwrap() {
            trained = true;
        }
    }
    assert!(trained);

    // occupy the worker (~300 ms of inference) and the single queue slot
    let rx1 = srv
        .try_call(infer_req(0, &ds.test[0]))
        .unwrap()
        .expect("empty queue accepts");
    thread::sleep(Duration::from_millis(100)); // worker has dequeued rx1
    let rx2 = srv
        .try_call(infer_req(0, &ds.test[1]))
        .unwrap()
        .expect("freed slot accepts");

    // a bounded call on the saturated queue must come back typed — the
    // pre-supervision server would have blocked here forever
    let err = srv
        .call_timeout(infer_req(0, &ds.test[2]), Duration::from_millis(60))
        .unwrap_err();
    assert_eq!(err, CallError::Timeout { shard: 0 });

    // with a realistic deadline the same request rides retry/backoff
    // into the slot the worker frees up
    match srv
        .call_timeout(infer_req(0, &ds.test[2]), Duration::from_secs(30))
        .unwrap()
    {
        Response::Prediction { .. } => {}
        other => panic!("unexpected {other:?}"),
    }

    // no lost replies: everything accepted earlier was answered too
    assert!(matches!(rx1.recv().unwrap(), Response::Prediction { .. }));
    assert!(matches!(rx2.recv().unwrap(), Response::Prediction { .. }));
    let st = stats_text(&srv);
    assert!(
        counter_total(&st, "queue_retries_total") >= 1,
        "the saturated sends must have been counted:\n{st}"
    );
    srv.shutdown();
}

// ---------------------------------------------------------------------
// shard supervision: detect → respawn → rehydrate

#[test]
fn dead_shard_is_respawned_and_sessions_rehydrated() {
    silence_injected_panics();
    let ds = mini_dataset(29);
    let dir = tmp_dir("respawn");
    let spec = FaultSpec {
        seed: 1,
        kill_after: Some(5),
        kill_replica: Some(1), // shard 1's original engine, nobody else
        ..FaultSpec::default()
    };
    let srv = Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        server_config(
            mini_session_config(ds.train.len()),
            2,
            Some(CheckpointConfig {
                dir: dir.clone(),
                every: 1,
            }),
        ),
    );

    // session 1 lives on shard 1; collect feeds cost no engine calls, so
    // the kill (5th engine call) hits mid-training on the 20th feed —
    // after 19 checkpointed collects
    let mut died = false;
    let mut trained = false;
    for s in &ds.train {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match srv.call_timeout(labelled(1, s), Duration::from_millis(500)) {
                Ok(Response::Trained { .. }) => {
                    trained = true;
                    break;
                }
                Ok(_) => break,
                Err(_) => {
                    // the shard died under this request — keep retrying
                    // the same sample until the supervisor's replacement
                    // picks it up
                    died = true;
                    assert!(
                        Instant::now() < deadline,
                        "shard recovery exceeded the 30 s bound"
                    );
                }
            }
        }
    }
    assert!(died, "the kill schedule must have taken shard 1 down");
    assert!(
        trained,
        "the respawned shard must rehydrate the session and finish training"
    );

    // the rehydrated session serves
    let deadline = Instant::now() + Duration::from_secs(30);
    let scores = loop {
        match srv.call_timeout(infer_req(1, &ds.test[0]), Duration::from_millis(500)) {
            Ok(Response::Prediction { scores, .. }) => break scores,
            Ok(other) => panic!("unexpected {other:?}"),
            Err(_) => assert!(Instant::now() < deadline, "serving never recovered"),
        }
    };
    assert!(scores.iter().all(|x| x.is_finite()));

    // supervision is visible in the metrics: one death, one respawn,
    // and the active-shard gauge back at full strength
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = stats_text(&srv);
        if counter_total(&st, "shards_active") == 2 {
            assert!(counter_total(&st, "shard_deaths_total") >= 1, "{st}");
            assert!(counter_total(&st, "shard_respawns_total") >= 1, "{st}");
            assert!(
                counter_total(&st, "sessions_restored_total") >= 1,
                "the respawned shard must have rehydrated from the checkpoint:\n{st}"
            );
            break;
        }
        assert!(
            Instant::now() < deadline,
            "supervisor never restored 2 live shards:\n{st}"
        );
        thread::sleep(Duration::from_millis(20));
    }
    srv.shutdown();
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// durable checkpoints: restart equivalence

#[test]
fn clean_shutdown_checkpoint_then_restart_is_bitwise_equal() {
    let ds = mini_dataset(31);
    let dir = tmp_dir("restart-clean");
    let feed_at = |i: usize| &ds.train[i % ds.train.len()];
    let total = 30; // 19 collects + train + 10 streaming folds

    // uninterrupted reference
    let reference = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, None),
    );
    let ref_feeds: Vec<Response> = (0..total)
        .map(|i| normalize(reference.call(labelled(1, feed_at(i))).unwrap()))
        .collect();
    let ref_preds: Vec<Response> = (0..ds.test.len())
        .map(|i| reference.call(infer_req(1, &ds.test[i])).unwrap())
        .collect();
    reference.shutdown();

    // run A: stop mid-stream with a clean shutdown (final checkpoint)
    let ckpt = CheckpointConfig {
        dir: dir.clone(),
        every: 1,
    };
    let a = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, Some(ckpt.clone())),
    );
    for (i, want) in ref_feeds.iter().enumerate().take(25) {
        assert_eq!(&normalize(a.call(labelled(1, feed_at(i))).unwrap()), want, "feed {i}");
    }
    a.shutdown();

    // run B: restored from the final checkpoint, continues the tail —
    // every response must be bitwise equal to the uninterrupted run
    let b = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, Some(ckpt)),
    );
    let st = stats_text(&b);
    assert!(counter_total(&st, "sessions_restored_total") >= 1, "{st}");
    for (i, want) in ref_feeds.iter().enumerate().skip(25) {
        assert_eq!(
            &normalize(b.call(labelled(1, feed_at(i))).unwrap()),
            want,
            "restored feed {i} diverged from the uninterrupted run"
        );
    }
    for (i, want) in ref_preds.iter().enumerate() {
        assert_eq!(
            &b.call(infer_req(1, &ds.test[i])).unwrap(),
            want,
            "restored prediction {i} diverged from the uninterrupted run"
        );
    }
    b.shutdown();
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn kill_then_restart_resumes_at_the_last_checkpoint_boundary() {
    silence_injected_panics();
    let ds = mini_dataset(33);
    let dir = tmp_dir("restart-kill");
    let feed_at = |i: usize| &ds.train[i % ds.train.len()];
    let total = 20 + 160; // collect+train, then a long streamed tail

    // uninterrupted reference
    let reference = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, None),
    );
    let ref_feeds: Vec<Response> = (0..total)
        .map(|i| normalize(reference.call(labelled(1, feed_at(i))).unwrap()))
        .collect();
    let ref_preds: Vec<Response> = (0..ds.test.len())
        .map(|i| reference.call(infer_req(1, &ds.test[i])).unwrap())
        .collect();
    reference.shutdown();

    // run A: the kill-only schedule is bitwise transparent until engine
    // call 200 of shard 1's replica — training costs ~80 calls and each
    // streamed fold one, so the kill lands somewhere mid-stream; with
    // `every: 1` the last checkpoint is exactly the state after the
    // last answered feed
    let spec = FaultSpec {
        seed: 2,
        kill_after: Some(200),
        kill_replica: Some(1),
        ..FaultSpec::default()
    };
    let ckpt = CheckpointConfig {
        dir: dir.clone(),
        every: 1,
    };
    let a = Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        server_config(streaming_session_config(ds.train.len()), 2, Some(ckpt.clone())),
    );
    let mut failed_at = None;
    for (i, want) in ref_feeds.iter().enumerate() {
        match a.call(labelled(1, feed_at(i))) {
            Ok(resp) => assert_eq!(&normalize(resp), want, "feed {i} before the kill"),
            Err(_) => {
                failed_at = Some(i);
                break;
            }
        }
    }
    let k = failed_at.expect("the kill schedule must fire within the streamed tail");
    assert!(k >= 20, "the kill must land after training, not during collect");
    a.shutdown();

    // run B: a fresh process restores from disk; the client re-sends the
    // failed request and the whole remaining tail must be bitwise equal
    // to the uninterrupted run
    let b = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(streaming_session_config(ds.train.len()), 2, Some(ckpt)),
    );
    for (i, want) in ref_feeds.iter().enumerate().skip(k) {
        assert_eq!(
            &normalize(b.call(labelled(1, feed_at(i))).unwrap()),
            want,
            "feed {i} after kill-then-restart diverged from the uninterrupted run"
        );
    }
    for (i, want) in ref_preds.iter().enumerate() {
        assert_eq!(
            &b.call(infer_req(1, &ds.test[i])).unwrap(),
            want,
            "prediction {i} after kill-then-restart diverged"
        );
    }
    b.shutdown();
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_checkpoint_never_blocks_startup() {
    let ds = mini_dataset(37);
    let dir = tmp_dir("corrupt");
    fs::create_dir_all(&dir).unwrap();
    fs::write(dir.join("shard-0.ckpt"), b"definitely not a checkpoint").unwrap();

    let ckpt = CheckpointConfig {
        dir: dir.clone(),
        every: 4,
    };
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(mini_session_config(ds.train.len()), 2, Some(ckpt.clone())),
    );
    let st = stats_text(&srv);
    assert!(
        counter_total(&st, "checkpoint_restore_errors_total") >= 1,
        "the garbage archive must be counted, not fatal:\n{st}"
    );

    // cold-start serving works on the very shard whose archive is junk
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv.call(labelled(0, s)).unwrap() {
            trained = true;
        }
    }
    assert!(trained);
    assert!(matches!(
        srv.call(infer_req(0, &ds.test[0])).unwrap(),
        Response::Prediction { .. }
    ));
    srv.shutdown();

    // the clean shutdown replaced the junk with a valid archive: a
    // second restart restores the trained session and serves immediately
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        server_config(mini_session_config(ds.train.len()), 2, Some(ckpt)),
    );
    let st = stats_text(&srv);
    assert!(counter_total(&st, "sessions_restored_total") >= 1, "{st}");
    assert!(matches!(
        srv.call(infer_req(0, &ds.test[0])).unwrap(),
        Response::Prediction { .. }
    ));
    srv.shutdown();
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// non-finite quarantine

#[test]
fn nonfinite_streaming_features_are_quarantined_and_healed() {
    let ds = mini_dataset(41);
    let spec = FaultSpec {
        seed: 3,
        nan_once_at: Some(200), // past training (~80 calls), mid-stream
        ..FaultSpec::default()
    };
    let srv = Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        server_config(streaming_session_config(ds.train.len()), 1, None),
    );
    for s in &ds.train {
        srv.call(labelled(0, s)).unwrap();
    }
    // stream past engine call 200: exactly one fold's features come back
    // NaN and must be quarantined (never folded into the factor), not
    // crash and not reject
    for i in 0..160 {
        let resp = srv.call(labelled(0, &ds.train[i % 20])).unwrap();
        assert!(
            !matches!(resp, Response::Rejected(_)),
            "feed {i} wrongly rejected: {resp:?}"
        );
    }
    // the session self-heals to finite inference; a NaN that slipped
    // into a served model is caught at the score boundary and repaired
    // by the next labelled feed's recovery retrain
    let mut healed = false;
    for i in 0..10 {
        match srv.call(infer_req(0, &ds.test[0])).unwrap() {
            Response::Prediction { scores, .. } => {
                assert!(scores.iter().all(|x| x.is_finite()));
                healed = true;
                break;
            }
            Response::Error { .. } => {
                srv.call(labelled(0, &ds.train[i % 20])).unwrap();
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(healed, "session must recover to finite inference");
    let st = stats_text(&srv);
    assert!(
        counter_total(&st, "nonfinite_quarantined_total") >= 1,
        "the injected NaN must have been quarantined somewhere:\n{st}"
    );
    srv.shutdown();
}

#[test]
fn nonfinite_infer_scores_come_back_as_typed_errors() {
    let ds = mini_dataset(43);
    let spec = FaultSpec {
        seed: 5,
        nan_once_at: Some(200), // past training: lands on one inference
        ..FaultSpec::default()
    };
    let srv = Server::spawn(
        Box::new(FaultyEngine::new(Box::new(NativeEngine::new(8, 2)), spec)),
        server_config(mini_session_config(ds.train.len()), 1, None),
    );
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv.call(labelled(0, s)).unwrap() {
            trained = true;
        }
    }
    assert!(trained);

    // after training every engine call is one inference, so exactly one
    // of these gets the scheduled NaN scores — and must surface as a
    // typed NonFinite error, with every other answer finite
    let mut nonfinite = 0;
    let mut predictions = 0;
    for i in 0..160 {
        match srv.call(infer_req(0, &ds.test[i % ds.test.len()])).unwrap() {
            Response::Prediction { scores, .. } => {
                assert!(scores.iter().all(|x| x.is_finite()), "infer {i}");
                predictions += 1;
            }
            Response::Error {
                kind: ErrorKind::NonFinite,
                ..
            } => nonfinite += 1,
            other => panic!("infer {i}: unexpected {other:?}"),
        }
    }
    assert_eq!(nonfinite, 1, "the NaN schedule fires exactly once");
    assert_eq!(predictions, 159);
    let st = stats_text(&srv);
    assert!(counter_total(&st, "nonfinite_quarantined_total") >= 1, "{st}");
    srv.shutdown();
}

// ---------------------------------------------------------------------
// bounded shutdown

#[test]
fn shutdown_skips_a_wedged_shard_within_the_drain_deadline() {
    let ds = mini_dataset(47);
    let mut cfg = ServerConfig {
        queue_cap: 8,
        seed: 0xFEED,
        shards: 1,
        max_batch: 8,
        ..ServerConfig::new(mini_session_config(ds.train.len()))
    };
    cfg.drain_timeout = Duration::from_millis(100);
    let srv = Server::spawn(Box::new(SlowInfer::new(8, 2, Duration::from_secs(2))), cfg);
    for s in &ds.train {
        srv.call(labelled(0, s)).unwrap();
    }
    // wedge the only shard behind ~6 s of slow inference
    let pending: Vec<_> = (0..3)
        .map(|i| {
            srv.try_call(infer_req(0, &ds.test[i]))
                .unwrap()
                .expect("queue has room")
        })
        .collect();
    let metrics = srv.metrics.clone();
    let t0 = Instant::now();
    srv.shutdown();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(1500),
        "shutdown must skip the wedged shard at the 100 ms drain deadline, took {elapsed:?}"
    );
    assert!(
        metrics.counter("shutdown_drain_skipped_total").get() >= 1,
        "the skipped drain must be counted"
    );
    drop(pending);
}
