//! Batch ≡ per-call equivalence suite (DESIGN.md §14): the batched
//! multi-session forward pass (`BatchScratch::forward_batch_into`,
//! `Engine::features_batch_into`, the server's batched shard drain) must
//! be indistinguishable from per-call processing at every batch size.
//!
//! # Why the tolerance is exactly zero
//!
//! Rust's `f32` arithmetic is IEEE-754 with strictly specified results
//! per operation: no fast-math reassociation, no implicit FMA
//! contraction, no flush-to-zero. Equality of two computations therefore
//! reduces to equality of their *operation sequences*. The batched
//! kernel preserves the per-lane op sequence of `Reservoir::forward_into`
//! exactly:
//!
//! * masking — `Mask::apply` runs verbatim per lane into that lane's
//!   j-slice (same dot-product accumulation order);
//! * cascade — the recurrence `x(k)_n = p·f(j + x(k-1)_n) + q·x(k)_{n-1}`
//!   is evaluated node-by-node with lanes on the inner axis; each lane
//!   sees the identical scalar chain it would see alone;
//! * DPRR — each accumulator element receives exactly one `+= x_i·x_m`
//!   per step, in the same step order, followed by the same single
//!   `* (1/T)` normalization.
//!
//! Since every per-lane scalar op happens in the same order with the
//! same operands, batched output == per-call output **bitwise**, and the
//! suite asserts with `assert_eq!` — tolerance zero. The negative
//! control below perturbs one input by 1 ulp and demands a detected
//! difference, so the comparison is known to discriminate at the
//! smallest representable granularity.

use std::cell::Cell;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use dfr_edge::coordinator::engine::{
    scores_from_r_tilde_with, Engine, FeatureRequest, NativeEngine, ReservoirUpdate,
};
use dfr_edge::simd::{Kernels, SimdMode};
use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
use dfr_edge::coordinator::{Request, Response, Server, ServerConfig};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{BatchLane, BatchScratch, ForwardScratch, Nonlinearity, Reservoir};
use dfr_edge::quant::QuantEngine;
use dfr_edge::runtime::executor::TrainState;
use dfr_edge::util::prng::Pcg32;

/// The batch sizes every sweep covers: 1 (degenerate), 2 (minimum that
/// triggers the server's batched path), 7/8 (around the default
/// `max_batch`), 64 (deep batch, exceeds any blocking factor).
const BATCH_SIZES: [usize; 5] = [1, 2, 7, 8, 64];

/// One independent "session" worth of kernel input: its own random
/// mask, its own pinned (p, q), its own series.
struct LaneFixture {
    mask: Mask,
    p: f32,
    q: f32,
    u: Vec<f32>,
    t: usize,
}

fn lane_fixtures(n: usize, nx: usize, v: usize, seed: u64, ragged: bool) -> Vec<LaneFixture> {
    let mut rng = Pcg32::seed(seed);
    (0..n)
        .map(|i| {
            let mask = Mask::random(nx, v, &mut rng);
            // ragged mode: pending counts differ per lane (incl. t = 1)
            let t = if ragged { 1 + (i * 7) % 29 } else { 17 };
            let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
            LaneFixture {
                mask,
                p: 0.10 + 0.03 * (i % 5) as f32,
                q: 0.08 + 0.02 * ((i * 3) % 7) as f32,
                u,
                t,
            }
        })
        .collect()
}

/// The per-call reference: the exact path `NativeEngine::features_into`
/// takes, one lane at a time.
fn per_call_features(lane: &LaneFixture, f: Nonlinearity) -> Vec<f32> {
    let res = Reservoir {
        mask: lane.mask.clone(),
        p: lane.p,
        q: lane.q,
        f,
    };
    let mut sc = ForwardScratch::new(lane.mask.nx);
    res.forward_into(&lane.u, lane.t, &mut sc);
    let mut out = Vec::new();
    sc.r_tilde_into(&mut out);
    out
}

fn batched_features(lanes: &[LaneFixture], f: Nonlinearity, sc: &mut BatchScratch) -> Vec<Vec<f32>> {
    sc.forward_batch_into(f, lanes.len(), |l| BatchLane {
        u: &lanes[l].u,
        t: lanes[l].t,
        mask: &lanes[l].mask,
        p: lanes[l].p,
        q: lanes[l].q,
    });
    let mut outs = vec![Vec::new(); lanes.len()];
    for (l, out) in outs.iter_mut().enumerate() {
        sc.r_tilde_into(l, out);
    }
    outs
}

// ---------------------------------------------------------------------------
// kernel level
// ---------------------------------------------------------------------------

#[test]
fn kernel_matches_per_call_at_every_batch_size() {
    let (nx, v) = (6usize, 3usize);
    // one scratch reused across all sizes — exercises lane growth and
    // shrink between sweeps (grow-only buffers, stale-lane hygiene)
    let mut sc = BatchScratch::new();
    for &b in &BATCH_SIZES {
        for ragged in [false, true] {
            let lanes = lane_fixtures(b, nx, v, 0xBA7C + b as u64, ragged);
            let got = batched_features(&lanes, Nonlinearity::Tanh, &mut sc);
            for (l, lane) in lanes.iter().enumerate() {
                let want = per_call_features(lane, Nonlinearity::Tanh);
                // tolerance is ZERO — see the module doc for the
                // op-order-preservation derivation
                assert_eq!(
                    got[l], want,
                    "batch size {b} (ragged={ragged}), lane {l}: batched r̃ != per-call r̃"
                );
            }
        }
    }
}

#[test]
fn kernel_matches_on_dimension_edges() {
    // Nx around the DPRR kernel's 4-wide chunking (multiple, ±1) and
    // channel counts around the mask dot width — the remainder lanes of
    // every inner loop get crossed
    let mut sc = BatchScratch::new();
    for &nx in &[4usize, 5, 7, 8] {
        for &v in &[1usize, 3, 5] {
            let lanes = lane_fixtures(3, nx, v, 0xD1_0000 + (nx * 16 + v) as u64, true);
            for f in [
                Nonlinearity::Tanh,
                Nonlinearity::Linear { alpha: 0.9 },
            ] {
                let got = batched_features(&lanes, f, &mut sc);
                for (l, lane) in lanes.iter().enumerate() {
                    assert_eq!(
                        got[l],
                        per_call_features(lane, f),
                        "nx={nx} v={v} lane {l} ({f:?})"
                    );
                }
            }
        }
    }
}

#[test]
fn one_ulp_perturbation_is_detected() {
    // Negative control: the exact-equality assertions above are only
    // meaningful if they can actually fail. Flip the LAST BIT of one
    // input scalar in one lane and demand (a) that lane's features
    // change, (b) every other lane's features stay bitwise identical
    // (no cross-lane contamination).
    let (nx, v) = (6usize, 3usize);
    let mut lanes = lane_fixtures(4, nx, v, 0x1011, true);
    let mut sc = BatchScratch::new();
    let base = batched_features(&lanes, Nonlinearity::Tanh, &mut sc);

    let victim = 2usize;
    let idx = lanes[victim]
        .u
        .iter()
        .position(|&x| x != 0.0)
        .expect("a nonzero input sample");
    let x = lanes[victim].u[idx];
    lanes[victim].u[idx] = f32::from_bits(x.to_bits() ^ 1);
    assert_ne!(lanes[victim].u[idx], x, "ulp flip must change the value");

    let perturbed = batched_features(&lanes, Nonlinearity::Tanh, &mut sc);
    assert_ne!(
        perturbed[victim], base[victim],
        "a 1-ulp input perturbation went undetected — the equivalence \
         assertions would not discriminate"
    );
    for l in 0..lanes.len() {
        if l != victim {
            assert_eq!(perturbed[l], base[l], "lane {l} leaked across the batch");
        }
    }
}

// ---------------------------------------------------------------------------
// engine level
// ---------------------------------------------------------------------------

fn mixed_samples(lanes: &[LaneFixture]) -> Vec<Sample> {
    lanes
        .iter()
        .enumerate()
        .map(|(i, lane)| Sample {
            u: lane.u.clone(),
            t: lane.t,
            label: i % 2,
        })
        .collect()
}

#[test]
fn native_engine_batch_matches_per_call_across_sessions() {
    let (nx, n_c, v) = (6usize, 3usize, 3usize);
    let eng = NativeEngine::new(nx, n_c);
    assert!(eng.scores_from_features_exact());
    let s_dim = nx * nx + nx + 1;
    let mut rng = Pcg32::seed(0xE46);
    let w_tilde: Vec<f32> = (0..n_c * s_dim).map(|_| 0.01 * rng.normal()).collect();

    // empty batch is a no-op
    eng.features_batch_into(&[], &mut []).unwrap();

    for &b in &BATCH_SIZES {
        let lanes = lane_fixtures(b, nx, v, 0xE46000 + b as u64, true);
        let samples = mixed_samples(&lanes);
        let reqs: Vec<FeatureRequest<'_>> = lanes
            .iter()
            .zip(&samples)
            .map(|(lane, sample)| FeatureRequest {
                sample,
                mask: &lane.mask,
                p: lane.p,
                q: lane.q,
            })
            .collect();
        let mut outs = vec![Vec::new(); b];
        eng.features_batch_into(&reqs, &mut outs).unwrap();

        for (l, lane) in lanes.iter().enumerate() {
            let mut want = Vec::new();
            eng.features_into(&samples[l], &lane.mask, lane.p, lane.q, &mut want)
                .unwrap();
            assert_eq!(outs[l], want, "batch size {b}, lane {l}");

            // scoring batched features == per-call infer_into, bitwise
            // (the contract behind scores_from_features_exact; the dot
            // must run through the engine's own kernel table)
            let mut from_batch = Vec::new();
            scores_from_r_tilde_with(&w_tilde, &outs[l], &mut from_batch, &eng.kernels());
            let mut per_call = Vec::new();
            eng.infer_into(&samples[l], &lane.mask, lane.p, lane.q, &w_tilde, &mut per_call)
                .unwrap();
            assert_eq!(from_batch, per_call, "batch size {b}, lane {l}: scores");
        }
    }
}

#[test]
fn simd_pinned_engine_batch_matches_per_call_bitwise() {
    // The tentpole contract at engine level: an engine pinned to the
    // AVX2 table produces batched features bitwise equal to its own
    // per-call path (`features_into` runs the scalar `forward_into` —
    // kernel-independent by construction — so this pins vector against
    // scalar, not vector against itself). Skips gracefully where the
    // host has no AVX2+FMA.
    let k = match Kernels::try_select(SimdMode::Force) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("(simd engine equivalence skipped: {e})");
            return;
        }
    };
    let (nx, n_c, v) = (6usize, 3usize, 3usize);
    let eng = NativeEngine::with_kernels(nx, n_c, Nonlinearity::Tanh, k);
    let s_dim = nx * nx + nx + 1;
    let mut rng = Pcg32::seed(0x51AD);
    let w_tilde: Vec<f32> = (0..n_c * s_dim).map(|_| 0.01 * rng.normal()).collect();
    // {1, 2, 7, 8, 9, 64}: degenerate, minimal, around the 8-lane AVX2
    // width (full vector, one-short, one-over tail lane) and deep
    for &b in &[1usize, 2, 7, 8, 9, 64] {
        let lanes = lane_fixtures(b, nx, v, 0x51AD00 + b as u64, true);
        let samples = mixed_samples(&lanes);
        let reqs: Vec<FeatureRequest<'_>> = lanes
            .iter()
            .zip(&samples)
            .map(|(lane, sample)| FeatureRequest {
                sample,
                mask: &lane.mask,
                p: lane.p,
                q: lane.q,
            })
            .collect();
        let mut outs = vec![Vec::new(); b];
        eng.features_batch_into(&reqs, &mut outs).unwrap();
        for (l, lane) in lanes.iter().enumerate() {
            let mut want = Vec::new();
            eng.features_into(&samples[l], &lane.mask, lane.p, lane.q, &mut want)
                .unwrap();
            assert_eq!(outs[l], want, "simd batch size {b}, lane {l}");
            // scoring through the engine's table == its per-call infer
            let mut from_batch = Vec::new();
            scores_from_r_tilde_with(&w_tilde, &outs[l], &mut from_batch, &eng.kernels());
            let mut per_call = Vec::new();
            eng.infer_into(&samples[l], &lane.mask, lane.p, lane.q, &w_tilde, &mut per_call)
                .unwrap();
            assert_eq!(from_batch, per_call, "simd batch size {b}, lane {l}: scores");
        }
    }
}

#[test]
fn quant_engine_routes_batches_in_both_datapath_states() {
    let (nx, n_c, v) = (5usize, 2usize, 2usize);
    let eng = QuantEngine::new(nx, n_c);
    let lanes: Vec<LaneFixture> = {
        let mut rng = Pcg32::seed(0x9047);
        (0..4)
            .map(|i| {
                let mask = Mask::random(nx, v, &mut rng);
                let t = 9 + i;
                LaneFixture {
                    mask,
                    p: 0.2,
                    q: 0.1,
                    // modest amplitude keeps the fixed-point path in range
                    u: (0..t * v).map(|_| 0.25 * rng.normal()).collect(),
                    t,
                }
            })
            .collect()
    };
    let samples = mixed_samples(&lanes);
    let batch_vs_per_call = |eng: &QuantEngine| {
        let reqs: Vec<FeatureRequest<'_>> = lanes
            .iter()
            .zip(&samples)
            .map(|(lane, sample)| FeatureRequest {
                sample,
                mask: &lane.mask,
                p: lane.p,
                q: lane.q,
            })
            .collect();
        let mut outs = vec![Vec::new(); reqs.len()];
        eng.features_batch_into(&reqs, &mut outs).unwrap();
        for (l, lane) in lanes.iter().enumerate() {
            let mut want = Vec::new();
            eng.features_into(&samples[l], &lane.mask, lane.p, lane.q, &mut want)
                .unwrap();
            assert_eq!(outs[l], want, "lane {l}");
        }
        outs
    };

    // live fixed-point datapath: batched entry point loops per call, but
    // the contract (same entry, same results) holds; integer-MAC
    // inference means batched scoring must NOT be planned
    assert!(!eng.is_fallback());
    assert!(!eng.scores_from_features_exact());
    let fixed = batch_vs_per_call(&eng);

    // force the f32 fallback: p·L_f + |q| ≥ 1 violates the error budget
    eng.recalibrate(&ReservoirUpdate {
        p: 0.8,
        q: 0.5,
        n_v: v,
        t_max: 12,
        u_max: 1.5,
    })
    .unwrap();
    assert!(eng.is_fallback());
    assert!(eng.scores_from_features_exact());
    let fallen = batch_vs_per_call(&eng);
    // fallen-back serving is exactly the native batched kernel
    let native = NativeEngine::new(nx, n_c);
    for (l, lane) in lanes.iter().enumerate() {
        let mut want = Vec::new();
        native
            .features_into(&samples[l], &lane.mask, lane.p, lane.q, &mut want)
            .unwrap();
        assert_eq!(fallen[l], want, "lane {l}: fallback != native");
        // and the datapaths genuinely differ, so the exact-score gate
        // is load-bearing
        assert_ne!(fixed[l], fallen[l], "lane {l}: quant == f32?");
    }
}

// ---------------------------------------------------------------------------
// session level
// ---------------------------------------------------------------------------

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn streaming_config(train_len: usize) -> SessionConfig {
    let mut cfg = SessionConfig::new(2, 2, train_len);
    cfg.train.nx = 8;
    cfg.train.epochs = 3;
    cfg.train.res_decay_epochs = vec![2];
    cfg.train.out_decay_epochs = vec![2];
    cfg.train.window = Some(16);
    cfg
}

/// Drive two identically-seeded sessions through the same stream, one
/// via `feed_labelled` (per-call), one via the batched entry point with
/// features pre-extracted exactly as the server's planner would, and
/// demand bitwise-identical outcomes and served state at every step.
fn assert_twin_equivalence(cfg: SessionConfig, expect_adapted: bool) {
    let ds = mini_dataset(41);
    let eng = NativeEngine::new(8, 2);
    let mut a = Session::new(1, cfg.clone(), 0xBEEF);
    let mut b = Session::new(1, cfg, 0xBEEF);
    for s in &ds.train {
        let oa = a.feed_labelled(&eng, s.clone()).unwrap();
        let ob = b.feed_labelled(&eng, s.clone()).unwrap();
        assert_eq!(oa, ob);
    }
    assert!(a.streaming_serve() && b.streaming_serve());

    let mut feat = Vec::new();
    let mut adapted = 0u32;
    for (i, s) in ds.train.iter().cycle().take(40).enumerate() {
        let oa = a.feed_labelled(&eng, s.clone()).unwrap();
        // plan for B exactly as the server does: features at the served
        // (mask, gen_p, gen_q), re-extracted each "drain cycle" so a
        // generation roll on the previous feed is always re-planned
        let (p, q) = b.serving_params();
        eng.features_into(s, &b.mask, p, q, &mut feat).unwrap();
        let ob = b.feed_labelled_with_features(&eng, s.clone(), &feat).unwrap();
        assert_eq!(oa, ob, "step {i}");
        if matches!(oa, FeedOutcome::Adapted { .. }) {
            adapted += 1;
        }
        assert_eq!(a.generation(), b.generation(), "step {i}");
        assert_eq!(a.serving_params(), b.serving_params(), "step {i}");
        assert_eq!(
            a.solution().unwrap().w_tilde,
            b.solution().unwrap().w_tilde,
            "step {i}: served W̃ diverged"
        );
    }
    assert_eq!(
        adapted > 0,
        expect_adapted,
        "adaptation rolls: got {adapted}"
    );

    // inference parity: scoring pre-extracted features == per-call infer
    for s in &ds.test {
        let (pa, sa) = a.infer(&eng, s).unwrap();
        let (p, q) = b.serving_params();
        eng.features_into(s, &b.mask, p, q, &mut feat).unwrap();
        let (pb, sb) = b.infer_with_features(&eng, &feat).unwrap();
        assert_eq!((pa, sa), (pb, sb));
    }
}

#[test]
fn session_batched_entry_points_match_per_call_twin() {
    assert_twin_equivalence(streaming_config(mini_dataset(41).train.len()), false);
}

#[test]
fn session_batched_entry_points_match_per_call_twin_under_adaptation() {
    // every feed rolls the generation (drift eps ~ 0): the batched entry
    // point must reproduce per-call `Adapted` semantics exactly, with
    // features re-planned after each roll — the session-level face of
    // the server's mid-batch split
    let mut cfg = streaming_config(mini_dataset(41).train.len());
    cfg.adapt_reservoir = true;
    cfg.adapt_lr = 0.05;
    cfg.adapt_drift_eps = 1e-6;
    assert_twin_equivalence(cfg, true);
}

/// NativeEngine wrapper whose datapath generation the test can move —
/// stands in for a shared quantized engine flipping its fallback.
struct GenEngine {
    inner: NativeEngine,
    gen: Cell<u64>,
}

impl Engine for GenEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }
    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }
    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w: &[f32]) -> Result<Vec<f32>> {
        self.inner.infer(s, mask, p, q, w)
    }
    fn name(&self) -> &'static str {
        "gen"
    }
    fn kernels(&self) -> Kernels {
        self.inner.kernels()
    }
    fn generation(&self) -> u64 {
        self.gen.get()
    }
}

#[test]
#[should_panic(expected = "stale batched features")]
fn stale_features_after_datapath_roll_are_refused() {
    // The server re-validates PlanTags before every batched item; the
    // session's own assert is the last line of defense against
    // cross-generation feature mixing. Prove it actually fires.
    let ds = mini_dataset(41);
    let eng = GenEngine {
        inner: NativeEngine::new(8, 2),
        gen: Cell::new(0),
    };
    let mut sess = Session::new(1, streaming_config(ds.train.len()), 0xBEEF);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert!(sess.streaming_serve());
    let (p, q) = sess.serving_params();
    let mut feat = Vec::new();
    eng.features_into(&ds.train[0], &sess.mask, p, q, &mut feat).unwrap();
    // the shared datapath moves after planning — folding the stale
    // features must be refused, not silently mixed
    eng.gen.set(1);
    let _ = sess.feed_labelled_with_features(&eng, ds.train[0].clone(), &feat);
}

// ---------------------------------------------------------------------------
// server level: batched drain vs per-call drain, mid-batch rolls
// ---------------------------------------------------------------------------

/// NativeEngine wrapper that sleeps in `train_step` only: with reservoir
/// adaptation on, every streamed feed crosses it, keeping the shard busy
/// long enough for a burst to queue — drain batching becomes
/// deterministic. Feature extraction (batched and per-call) and
/// inference are the real native kernels.
struct SlowAdaptEngine {
    inner: NativeEngine,
    delay: Duration,
}

impl Engine for SlowAdaptEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        thread::sleep(self.delay);
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }
    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }
    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.inner.features_into(s, mask, p, q, out)
    }
    fn features_batch_into(
        &self,
        reqs: &[FeatureRequest<'_>],
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        self.inner.features_batch_into(reqs, outs)
    }
    fn scores_from_features_exact(&self) -> bool {
        self.inner.scores_from_features_exact()
    }
    fn kernels(&self) -> Kernels {
        self.inner.kernels()
    }
    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w: &[f32]) -> Result<Vec<f32>> {
        self.inner.infer(s, mask, p, q, w)
    }
    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        self.inner.infer_into(s, mask, p, q, w, scores)
    }
    fn name(&self) -> &'static str {
        "slow-adapt"
    }
    fn fork(&self) -> Option<Box<dyn Engine>> {
        Some(Box::new(SlowAdaptEngine {
            inner: NativeEngine::new(self.inner.nx, self.inner.n_c),
            delay: self.delay,
        }))
    }
}

fn adapt_server(max_batch: usize) -> Server {
    let ds = mini_dataset(41);
    let mut scfg = streaming_config(ds.train.len());
    scfg.adapt_reservoir = true;
    scfg.adapt_lr = 0.05;
    scfg.adapt_drift_eps = 1e-6; // every adapting feed rolls a generation
    Server::spawn(
        Box::new(SlowAdaptEngine {
            inner: NativeEngine::new(8, 2),
            delay: Duration::from_millis(2),
        }),
        ServerConfig {
            queue_cap: 256,
            seed: 0xFEED,
            shards: 1,
            max_batch,
            ..ServerConfig::new(scfg)
        },
    )
}

/// Response equality modulo wall-clock (`train_seconds` is timing, not
/// semantics).
fn normalize(r: Response) -> Response {
    match r {
        Response::Trained { p, q, beta, .. } => Response::Trained {
            p,
            q,
            beta,
            train_seconds: 0.0,
        },
        other => other,
    }
}

fn counter_value(stats: &str, name: &str) -> u64 {
    let prefix = format!("counter {name} ");
    stats
        .lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

#[test]
fn batched_drain_matches_per_call_server_and_splits_on_mid_batch_rolls() {
    let ds = mini_dataset(41);
    // identical workload against a batching server (max_batch = 8) and a
    // batching-disabled server (max_batch = 1); the response streams
    // must be identical
    let run = |max_batch: usize| -> (Vec<Response>, String) {
        let srv = adapt_server(max_batch);
        // train sessions 0 and 1 synchronously (deterministic prefix)
        for session in 0..2u64 {
            let mut trained = false;
            for s in &ds.train {
                if let Response::Trained { .. } = srv
                    .call(Request::Labelled {
                        session,
                        sample: s.clone(),
                    })
                    .unwrap()
                {
                    trained = true;
                }
            }
            assert!(trained, "session {session} never trained");
        }
        // burst: interleaved adapting feeds for both sessions, enqueued
        // faster than the shard drains (train_step sleeps 2 ms per feed)
        // so drain cycles contain several same-session feeds — the first
        // rolls the generation (Adapted), which must split the batch for
        // the later ones
        let mut pending = Vec::new();
        for i in 0..16 {
            for session in 0..2u64 {
                let rx = srv
                    .try_call(Request::Labelled {
                        session,
                        sample: ds.train[i % ds.train.len()].clone(),
                    })
                    .unwrap()
                    .expect("queue_cap sized for the whole burst");
                pending.push(rx);
            }
        }
        let mut responses: Vec<Response> = pending
            .into_iter()
            .map(|rx| normalize(rx.recv().unwrap()))
            .collect();
        // burst of inferences (exercises the batched Infer path on the
        // max_batch = 8 server)
        let mut pending = Vec::new();
        for s in &ds.test {
            for session in 0..2u64 {
                let rx = srv
                    .try_call(Request::Infer {
                        session,
                        sample: s.clone(),
                    })
                    .unwrap()
                    .expect("queue_cap sized for the whole burst");
                pending.push(rx);
            }
        }
        responses.extend(pending.into_iter().map(|rx| normalize(rx.recv().unwrap())));
        let stats = match srv.call(Request::Stats).unwrap() {
            Response::StatsText(t) => t,
            other => panic!("{other:?}"),
        };
        srv.shutdown();
        (responses, stats)
    };

    let (batched, batched_stats) = run(8);
    let (per_call, per_call_stats) = run(1);
    assert_eq!(
        batched.len(),
        per_call.len(),
        "response streams differ in length"
    );
    for (i, (a, b)) in batched.iter().zip(&per_call).enumerate() {
        assert_eq!(a, b, "response {i} diverged between max_batch=8 and 1");
    }
    // the adapting feeds really rolled generations through the batch...
    assert!(
        batched.iter().any(|r| matches!(r, Response::Adapted { .. })),
        "burst never adapted — the mid-batch-roll scenario was not exercised"
    );
    // ...and per-session generations stay strictly monotonic in order
    // (per-session response pairing/ordering survived batching; feeds
    // for sessions 0 and 1 alternate, so responses at even/odd indices
    // belong to fixed sessions)
    for parity in 0..2 {
        let mut last = 0u64;
        for r in batched[..32].iter().skip(parity).step_by(2) {
            if let Response::Adapted { generation, .. } = r {
                assert!(*generation > last, "generation went backwards");
                last = *generation;
            }
        }
    }
    // the batching server split batches on mid-batch rolls; the
    // per-call server never planned anything to split
    assert!(
        counter_value(&batched_stats, "batch_splits_total") >= 1,
        "no batch ever split despite per-feed generation rolls:\n{batched_stats}"
    );
    assert_eq!(counter_value(&per_call_stats, "batch_splits_total"), 0);
}
