//! Property tests: the zero-allocation / blocked hot paths introduced
//! for the §Perf work are numerically equivalent to the simple
//! per-sample reference paths, over random shapes.
//!
//! * `forward_into` (reused workspace) ≡ `forward` — bitwise, both for
//!   the modular reservoir and the Mackey–Glass DFR;
//! * `accumulate_block` (rank-k Gram) ≡ sequential `accumulate` within
//!   1e-5 relative (the blocked kernel reassociates f32 sums);
//! * β sweep with a shared workspace ≡ per-β cloned solves — bitwise,
//!   serial and parallel.

use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{ForwardScratch, MackeyGlassDfr, Nonlinearity, Reservoir};
use dfr_edge::linalg::ridge::{RidgeAccumulator, RidgeMethod, RidgeSolution, PAPER_BETAS};
use dfr_edge::util::proptest::{assert_close, run_prop, Config};

#[test]
fn forward_into_equals_forward_reservoir() {
    run_prop("forward_into == forward (modular)", Config::default(), |rng, size| {
        let nx = 1 + (size as usize % 12);
        let v = 1 + (size as usize % 4);
        let res = Reservoir {
            mask: Mask::random(nx, v, rng),
            p: rng.uniform_in(0.05, 0.4),
            q: rng.uniform_in(0.05, 0.4),
            f: if size % 2 == 0 {
                Nonlinearity::Linear { alpha: 1.0 }
            } else {
                Nonlinearity::Tanh
            },
        };
        // one scratch reused across several series of different lengths —
        // catches stale state between samples
        let mut scratch = ForwardScratch::new(nx);
        for round in 0..3u32 {
            let t = 1 + ((size + round) as usize * 5) % 37;
            let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
            let want = res.forward(&u, t);
            res.forward_into(&u, t, &mut scratch);
            if want.r_mat != scratch.r_mat() {
                return Err(format!("r_mat mismatch at nx={nx} t={t}"));
            }
            if want.x_t != scratch.x_t() || want.x_tm1 != scratch.x_tm1() {
                return Err(format!("state mismatch at nx={nx} t={t}"));
            }
            if want.j_t != scratch.j_t() || want.t_len != scratch.t_len() {
                return Err(format!("j/t mismatch at nx={nx} t={t}"));
            }
            let mut rt = Vec::new();
            scratch.r_tilde_into(&mut rt);
            if rt != want.r_tilde() {
                return Err(format!("r_tilde mismatch at nx={nx} t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn forward_into_equals_forward_mackey_glass() {
    run_prop("forward_into == forward (MG)", Config::default(), |rng, size| {
        let nx = 1 + (size as usize % 10);
        let v = 1 + (size as usize % 3);
        let dfr = MackeyGlassDfr {
            mask: Mask::random(nx, v, rng),
            gamma: rng.uniform_in(0.2, 0.8),
            eta: rng.uniform_in(0.5, 1.0),
            // exercise both the x*x fast path and the powf path
            p_exp: if size % 2 == 0 { 2.0 } else { 2.5 },
            theta: rng.uniform_in(0.1, 0.5),
        };
        let mut scratch = ForwardScratch::new(nx);
        for round in 0..2u32 {
            let t = 1 + ((size + round) as usize * 7) % 29;
            let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
            let want = dfr.forward(&u, t);
            dfr.forward_into(&u, t, &mut scratch);
            if want.r_mat != scratch.r_mat() || want.x_t != scratch.x_t() {
                return Err(format!("MG mismatch at nx={nx} t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn accumulate_block_equals_sequential() {
    run_prop("accumulate_block == accumulate", Config::default(), |rng, size| {
        let s = 2 + (size as usize % 23);
        let ny = 1 + (size as usize % 4);
        let n = 1 + (size as usize * 3) % 13;
        let rs: Vec<f32> = (0..n * s).map(|_| rng.normal()).collect();
        let labels: Vec<usize> = (0..n).map(|_| rng.below(ny as u32) as usize).collect();
        let mut seq = RidgeAccumulator::new(s, ny);
        for (r, &c) in rs.chunks_exact(s).zip(&labels) {
            seq.accumulate(r, c);
        }
        let mut blk = RidgeAccumulator::new(s, ny);
        blk.accumulate_block(&rs, &labels);
        if blk.count != seq.count {
            return Err(format!("count {} vs {}", blk.count, seq.count));
        }
        // A is a plain per-sample row add in both paths — exact
        if blk.a != seq.a {
            return Err("A mismatch".into());
        }
        // the blocked Gram reassociates sums: 1e-5 relative
        assert_close(&blk.b_packed, &seq.b_packed, 1e-5, 1e-5)
            .map_err(|e| format!("B (s={s} n={n}): {e}"))
    });
}

#[test]
fn beta_sweep_workspace_equals_per_beta_clone() {
    run_prop("sweep workspace == per-β clone", Config::default(), |rng, size| {
        let s = 3 + (size as usize % 12);
        let ny = 1 + (size as usize % 3);
        let n = s + 2; // enough samples that B is well-conditioned-ish
        let mut acc = RidgeAccumulator::new(s, ny);
        for i in 0..n {
            let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
            acc.accumulate(&r, i % ny);
        }
        let loss = |sol: &RidgeSolution| sol.w_tilde.iter().map(|w| w * w).sum::<f32>();

        // reference: the pre-workspace behavior — a fresh clone per β
        let mut ref_best: Option<(RidgeSolution, f32)> = None;
        for &beta in &PAPER_BETAS {
            let sol = acc.solve(beta, RidgeMethod::Cholesky1d);
            let raw = loss(&sol);
            let l = if raw.is_finite() { raw } else { f32::INFINITY };
            if ref_best.as_ref().map_or(true, |(_, b)| l < *b) {
                ref_best = Some((sol, l));
            }
        }
        let (ref_sol, ref_loss) = ref_best.unwrap();

        let (ws_sol, ws_loss) = acc.solve_best_beta(&PAPER_BETAS, RidgeMethod::Cholesky1d, loss);
        if ws_sol.beta != ref_sol.beta || ws_sol.w_tilde != ref_sol.w_tilde || ws_loss != ref_loss
        {
            return Err(format!("workspace sweep diverged (s={s} ny={ny})"));
        }

        let (par_sol, par_loss) =
            acc.solve_best_beta_parallel(&PAPER_BETAS, RidgeMethod::Cholesky1d, 4, loss);
        if par_sol.beta != ref_sol.beta || par_sol.w_tilde != ref_sol.w_tilde || par_loss != ref_loss
        {
            return Err(format!("parallel sweep diverged (s={s} ny={ny})"));
        }
        Ok(())
    });
}
