//! Online reservoir adaptation through the sharded coordinator
//! (DESIGN.md §13): an abruptly drifted labelled stream must produce
//! `Adapted` responses — the streaming truncated-BPTT optimizer rolls
//! the session onto new reservoir generations, re-featurizing and
//! reseeding the online ridge — and accuracy must recover **without a
//! single batch retrain** (`trainings_total` stays 1). Also covers the
//! quantized engine's recalibration wiring end-to-end.

use dfr_edge::coordinator::engine::Engine;
use dfr_edge::coordinator::{
    NativeEngine, Request, Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::quant::QuantEngine;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn adapt_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg.train.forgetting = Some(0.92);
    scfg.train.refactor_every = 16;
    scfg.adapt_reservoir = true;
    scfg.adapt_lr = 0.005;
    scfg.adapt_drift_eps = 2e-3;
    scfg
}

#[test]
fn drifted_stream_triggers_adapted_and_recovers_without_retrain() {
    // Same abrupt drift as the PR-3 streaming test — the label semantics
    // flip after batch training — but now the reservoir layer adapts
    // too: every labelled Serve sample drives a truncated-BPTT step on
    // the candidate (p, q), and crossing the drift threshold rolls a new
    // generation (recalibrate → re-featurize the ring → reseed).
    let ds = mini_dataset(26);
    let srv = Server::spawn(
        Box::new(NativeEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 64,
            seed: 5,
            shards: 2,
            max_batch: 8,
            ..ServerConfig::new(adapt_session_config(ds.train.len()))
        },
    );
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv
            .call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            trained = true;
        }
    }
    assert!(trained);

    let flip = |s: &Sample| {
        let mut s2 = s.clone();
        s2.label = 1 - s2.label;
        s2
    };
    let accuracy_flipped = |srv: &Server| -> usize {
        ds.test
            .iter()
            .filter(|s| {
                matches!(
                    srv.call(Request::Infer { session: 1, sample: s.clone() }).unwrap(),
                    Response::Prediction { class, .. } if class == 1 - s.label
                )
            })
            .count()
    };
    let pre = accuracy_flipped(&srv);

    // drift stream: three passes of flipped labelled samples. Every
    // response is a streaming ack — Observed or Adapted — never a batch
    // Trained and never Rejected.
    let mut observed = 0u64;
    let mut adapted = 0u64;
    let mut last_generation = 0u64;
    for _ in 0..3 {
        for s in &ds.train {
            match srv
                .call(Request::Labelled {
                    session: 1,
                    sample: flip(s),
                })
                .unwrap()
            {
                Response::Observed { updates, .. } => {
                    observed += 1;
                    assert!(updates > 0);
                }
                Response::Adapted {
                    generation,
                    p,
                    q,
                    updates,
                } => {
                    adapted += 1;
                    // the generation counter enforces no feature/factor
                    // mixing: every roll is strictly monotonic
                    assert!(
                        generation > last_generation,
                        "generation went {last_generation} -> {generation}"
                    );
                    last_generation = generation;
                    assert!(updates > 0, "reseed must refold the ring");
                    assert!(p > 0.0 && q > 0.0);
                }
                other => panic!("expected Observed/Adapted during drift, got {other:?}"),
            }
        }
    }
    let total = 3 * ds.train.len() as u64;
    assert_eq!(observed + adapted, total);
    assert!(
        adapted > 0,
        "the drifted stream never crossed the drift threshold"
    );
    assert!(last_generation >= 2, "first roll starts from generation 1");

    let post = accuracy_flipped(&srv);
    assert!(
        post >= 6 && post > pre,
        "post-drift accuracy did not recover: {pre}/10 -> {post}/10"
    );

    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => {
            // all adaptation was online — exactly the one batch training
            assert!(t.contains("counter trainings_total 1"), "{t}");
            assert!(
                t.contains(&format!("counter online_updates_total {total}")),
                "{t}"
            );
            // every drift sample drove a reservoir step; every Adapted
            // was one re-featurization
            assert!(
                t.contains(&format!("counter reservoir_updates_total {total}")),
                "{t}"
            );
            assert!(
                t.contains(&format!("counter refeaturize_total {adapted}")),
                "{t}"
            );
        }
        other => panic!("{other:?}"),
    }
    srv.shutdown();
}

#[test]
fn quant_engine_recalibrates_through_the_adaptation_loop() {
    // QuantEngine behind the server with adaptation on: generation rolls
    // must drive Engine::recalibrate (LUT rebuild + §12 budget re-run)
    // while the sane mini workload stays inside the Q4.12 budget — the
    // stream keeps serving quantized, and Adapted responses flow.
    let ds = mini_dataset(28);
    let mut scfg = adapt_session_config(ds.train.len());
    scfg.adapt_drift_eps = 1e-6; // roll on any movement
    let srv = Server::spawn(
        Box::new(QuantEngine::new(8, 2)),
        ServerConfig {
            queue_cap: 64,
            seed: 7,
            shards: 1,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        },
    );
    let mut trained = false;
    for s in &ds.train {
        if let Response::Trained { .. } = srv
            .call(Request::Labelled {
                session: 3,
                sample: s.clone(),
            })
            .unwrap()
        {
            trained = true;
        }
    }
    assert!(trained);
    let mut adapted = 0u64;
    for s in &ds.train {
        match srv
            .call(Request::Labelled {
                session: 3,
                sample: s.clone(),
            })
            .unwrap()
        {
            Response::Adapted { generation, .. } => {
                adapted += 1;
                assert!(generation >= 2);
            }
            Response::Observed { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(adapted > 0, "adaptation never rolled a generation");
    // inference still serves after recalibrations
    let r = srv
        .call(Request::Infer {
            session: 3,
            sample: ds.test[0].clone(),
        })
        .unwrap();
    assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
    srv.shutdown();
}

#[test]
fn session_level_quant_fallback_reseeds_coherently() {
    // Unit-level check of the engine/session generation contract with a
    // quantized datapath that flips to f32: after an out-of-budget
    // recalibration, the session's next labelled feed re-featurizes
    // through the NEW (fallen-back) datapath before folding — features
    // and factor stay generation-coherent across the switch.
    use dfr_edge::coordinator::engine::ReservoirUpdate;
    use dfr_edge::coordinator::session::{FeedOutcome, Session};
    use dfr_edge::dfr::reservoir::Nonlinearity;
    use dfr_edge::quant::{QFormat, QuantConfig};

    let ds = mini_dataset(29);
    let mut scfg = adapt_session_config(ds.train.len());
    scfg.adapt_reservoir = false; // this session only observes
    // Q6.10 (±32) holds the mini workload with wide headroom, so the
    // batch train's own recalibration stays in budget and the ONLY
    // fallback in this test is the injected out-of-budget one
    let eng = QuantEngine::with_config(
        8,
        2,
        Nonlinearity::Linear { alpha: 1.0 },
        QuantConfig::with_format(QFormat::q6_10()),
    );
    let mut sess = Session::new(9, scfg, 0xC0FE);
    for s in &ds.train {
        sess.feed_labelled(&eng, s.clone()).unwrap();
    }
    assert_eq!(sess.generation(), 1);
    assert!(!eng.is_fallback());

    // an out-of-budget recalibration (as another session's adaptation
    // would issue) flips the shared datapath to f32
    let r = eng
        .recalibrate(&ReservoirUpdate {
            p: 0.8,
            q: 0.5,
            n_v: 2,
            t_max: 12,
            u_max: 2.0,
        })
        .unwrap();
    assert!(r.fell_back);
    assert!(eng.is_fallback());

    // next feed: the engine generation moved → Adapted (reseed through
    // the f32 fallback), not a silent mixed-generation fold
    match sess.feed_labelled(&eng, ds.train[0].clone()).unwrap() {
        FeedOutcome::Adapted {
            generation,
            updates,
            ..
        } => {
            assert_eq!(generation, 2);
            assert!(updates > 0);
        }
        other => panic!("expected Adapted after datapath fallback, got {other:?}"),
    }
    // and the session keeps serving
    assert!(sess.infer(&eng, &ds.test[0]).is_ok());
}
