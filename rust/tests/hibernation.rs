//! Session hibernation end-to-end: a capacity-capped server must be
//! response-for-response **bitwise identical** to an unconstrained one
//! (park/rehydrate is invisible), including across an engine datapath
//! generation roll that lands while sessions are parked, across a full
//! process restart from the store, and under the idle clock.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::coordinator::{
    HibernateConfig, Request, Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::runtime::executor::TrainState;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

fn mini_dataset(seed: u64) -> Dataset {
    synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        seed,
    )
}

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

/// Fresh per-test store root under the OS temp dir.
fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dfr-hib-e2e-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Single-shard server so eviction order and batching are deterministic.
fn spawn_one_shard(
    engine: Box<dyn Engine>,
    scfg: SessionConfig,
    hibernate: Option<HibernateConfig>,
) -> Server {
    let mut cfg = ServerConfig {
        queue_cap: 64,
        seed: 0xFEED,
        shards: 1,
        max_batch: 8,
        ..ServerConfig::new(scfg)
    };
    cfg.hibernate = hibernate;
    Server::spawn(engine, cfg)
}

/// Response equality modulo wall-clock (`train_seconds` is timing, not
/// semantics) — everything else must match bitwise.
fn normalize(r: Response) -> Response {
    match r {
        Response::Trained { p, q, beta, .. } => Response::Trained {
            p,
            q,
            beta,
            train_seconds: 0.0,
        },
        other => other,
    }
}

/// Aggregate value of a counter or gauge in the `Stats` text (the
/// unlabelled line; labelled per-shard lines render as `name{shard="0"}`).
/// Level instruments (`resident_sessions`, `hibernated_sessions`) are
/// typed gauges; totals stay counters.
fn metric(stats: &str, name: &str) -> u64 {
    for line in stats.lines() {
        let mut it = line.split_whitespace();
        let kind = it.next();
        if (kind == Some("counter") || kind == Some("gauge")) && it.next() == Some(name) {
            if let Some(v) = it.next() {
                return v.parse().unwrap_or(0);
            }
        }
    }
    0
}

fn stats(srv: &Server) -> String {
    match srv.call(Request::Stats).unwrap() {
        Response::StatsText(t) => t,
        other => panic!("unexpected: {other:?}"),
    }
}

#[test]
fn capped_server_is_bitwise_identical_to_unconstrained() {
    let ds = mini_dataset(41);
    let dir = tmp_dir("pair");
    let sessions: Vec<u64> = (1..=6).collect();

    let mut hib = HibernateConfig::new(&dir);
    hib.max_resident = 2; // 6 live sessions → constant park/rehydrate churn
    hib.buckets = 4; // several sessions per bucket archive

    let plain = spawn_one_shard(
        Box::new(NativeEngine::new(8, 2)),
        mini_session_config(ds.train.len()),
        None,
    );
    let capped = spawn_one_shard(
        Box::new(NativeEngine::new(8, 2)),
        mini_session_config(ds.train.len()),
        Some(hib),
    );

    // identical interleaved traffic: train all six sessions round-robin,
    // then an inference sweep — every response pair must match
    let mut traffic: Vec<Request> = Vec::new();
    for s in &ds.train {
        for &sess in &sessions {
            traffic.push(Request::Labelled {
                session: sess,
                sample: s.clone(),
            });
        }
    }
    for s in ds.test.iter().take(5) {
        for &sess in &sessions {
            traffic.push(Request::Infer {
                session: sess,
                sample: s.clone(),
            });
        }
    }
    for req in traffic {
        let (sess, sample_req) = match &req {
            Request::Labelled { session, sample } => (
                *session,
                Request::Labelled {
                    session: *session,
                    sample: sample.clone(),
                },
            ),
            Request::Infer { session, sample } => (
                *session,
                Request::Infer {
                    session: *session,
                    sample: sample.clone(),
                },
            ),
            _ => unreachable!(),
        };
        let a = normalize(plain.call(sample_req).unwrap());
        let b = normalize(capped.call(req).unwrap());
        assert_eq!(a, b, "diverged on session {sess}");
    }

    // the cap actually bit: sessions were parked and brought back
    let st = stats(&capped);
    assert!(metric(&st, "sessions_hibernated_total") > 0, "{st}");
    assert!(metric(&st, "sessions_rehydrated_total") > 0, "{st}");
    assert!(metric(&st, "resident_sessions") <= 2, "{st}");
    assert_eq!(metric(&st, "hibernate_errors_total"), 0, "{st}");
    assert_eq!(metric(&st, "rehydrate_errors_total"), 0, "{st}");

    plain.shutdown();
    capped.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Engine whose datapath generation is driven by the test — lets a
/// generation roll land while sessions are hibernated.
struct RollingEngine {
    inner: NativeEngine,
    gen: Arc<AtomicU64>,
}

impl RollingEngine {
    fn new(gen: Arc<AtomicU64>) -> Self {
        RollingEngine {
            inner: NativeEngine::new(8, 2),
            gen,
        }
    }
}

impl Engine for RollingEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> anyhow::Result<f32> {
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> anyhow::Result<Vec<f32>> {
        self.inner.features(s, mask, p, q)
    }

    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.inner.features_into(s, mask, p, q, out)
    }

    fn infer(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        self.inner.infer(s, mask, p, q, w_tilde)
    }

    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
        scores: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.inner.infer_into(s, mask, p, q, w_tilde, scores)
    }

    fn scores_from_features_exact(&self) -> bool {
        true
    }

    fn kernels(&self) -> dfr_edge::simd::Kernels {
        self.inner.kernels()
    }

    fn name(&self) -> &'static str {
        "rolling"
    }

    fn generation(&self) -> u64 {
        self.gen.load(Ordering::SeqCst)
    }
}

#[test]
fn generation_roll_mid_hibernation_stays_bitwise_equal() {
    let ds = mini_dataset(42);
    let dir = tmp_dir("genroll");
    let sessions: Vec<u64> = (1..=3).collect();

    // streaming ridge on, so Serve-phase labelled samples carry online
    // state that a generation roll must reseed
    let mut scfg = mini_session_config(ds.train.len());
    scfg.train.window = Some(8);

    let mut hib = HibernateConfig::new(&dir);
    hib.max_resident = 1; // everything beyond the hottest session parks

    // both engines share one generation cell: a single bump rolls both
    // servers at the same request boundary
    let gen = Arc::new(AtomicU64::new(0));
    let plain = spawn_one_shard(Box::new(RollingEngine::new(Arc::clone(&gen))), scfg.clone(), None);
    let capped = spawn_one_shard(
        Box::new(RollingEngine::new(Arc::clone(&gen))),
        scfg,
        Some(hib),
    );

    let mut drive = |req: Request, req2: Request| {
        let a = normalize(plain.call(req).unwrap());
        let b = normalize(capped.call(req2).unwrap());
        assert_eq!(a, b);
    };
    let labelled = |sess: u64, s: &Sample| Request::Labelled {
        session: sess,
        sample: s.clone(),
    };
    let infer = |sess: u64, s: &Sample| Request::Infer {
        session: sess,
        sample: s.clone(),
    };

    // train all three to Serve
    for s in &ds.train {
        for &sess in &sessions {
            drive(labelled(sess, s), labelled(sess, s));
        }
    }
    // a few Serve-phase streaming updates
    for s in ds.train.iter().take(3) {
        for &sess in &sessions {
            drive(labelled(sess, s), labelled(sess, s));
        }
    }

    // both servers idle; with max_resident = 1 at least two sessions are
    // hibernated right now. Roll the shared datapath generation.
    gen.fetch_add(1, Ordering::SeqCst);

    // parked sessions rehydrate under the new generation — streaming
    // updates and inference must still agree response-for-response
    for s in ds.train.iter().skip(3).take(3) {
        for &sess in &sessions {
            drive(labelled(sess, s), labelled(sess, s));
        }
    }
    for s in ds.test.iter().take(4) {
        for &sess in &sessions {
            drive(infer(sess, s), infer(sess, s));
        }
    }

    let st = stats(&capped);
    assert!(metric(&st, "sessions_hibernated_total") > 0, "{st}");
    assert_eq!(metric(&st, "rehydrate_errors_total"), 0, "{st}");

    plain.shutdown();
    capped.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hibernated_sessions_survive_a_restart() {
    let ds = mini_dataset(43);
    let dir = tmp_dir("restart");
    let hib = HibernateConfig::new(&dir); // no cap: parking happens at shutdown

    let first = spawn_one_shard(
        Box::new(NativeEngine::new(8, 2)),
        mini_session_config(ds.train.len()),
        Some(hib.clone()),
    );
    for s in &ds.train {
        for sess in 1..=3u64 {
            first.call(Request::Labelled {
                session: sess,
                sample: s.clone(),
            })
            .unwrap();
        }
    }
    let mut before = Vec::new();
    for s in ds.test.iter().take(3) {
        for sess in 1..=3u64 {
            before.push(
                first
                    .call(Request::Infer {
                        session: sess,
                        sample: s.clone(),
                    })
                    .unwrap(),
            );
        }
    }
    // graceful shutdown parks every resident session into the store
    first.shutdown();

    // fresh process image: no checkpoint config, so the *only* way these
    // sessions come back is rehydration from the hibernation store
    let second = spawn_one_shard(
        Box::new(NativeEngine::new(8, 2)),
        mini_session_config(ds.train.len()),
        Some(hib),
    );
    let mut after = Vec::new();
    for s in ds.test.iter().take(3) {
        for sess in 1..=3u64 {
            after.push(
                second
                    .call(Request::Infer {
                        session: sess,
                        sample: s.clone(),
                    })
                    .unwrap(),
            );
        }
    }
    assert_eq!(before, after);
    for r in &after {
        assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
    }
    let st = stats(&second);
    assert!(metric(&st, "sessions_rehydrated_total") >= 3, "{st}");
    second.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn idle_clock_parks_quiet_sessions() {
    let ds = mini_dataset(44);
    let dir = tmp_dir("idle");
    let mut hib = HibernateConfig::new(&dir);
    hib.hibernate_after = Some(Duration::from_millis(50));

    let srv = spawn_one_shard(
        Box::new(NativeEngine::new(8, 2)),
        mini_session_config(ds.train.len()),
        Some(hib),
    );
    for s in &ds.train {
        for sess in [1u64, 2] {
            srv.call(Request::Labelled {
                session: sess,
                sample: s.clone(),
            })
            .unwrap();
        }
    }
    // go quiet: the idle sweep (every hibernate_after/2) must park both
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        thread::sleep(Duration::from_millis(100));
        let st = stats(&srv);
        if metric(&st, "sessions_hibernated_total") >= 2 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "idle sweep never parked the sessions: {st}"
        );
    }
    // next touch brings them back, fully functional
    for sess in [1u64, 2] {
        let r = srv
            .call(Request::Infer {
                session: sess,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
    }
    let st = stats(&srv);
    assert!(metric(&st, "sessions_rehydrated_total") >= 2, "{st}");
    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
