//! Quantized-vs-f32 equivalence under the analytic Q-format bound.
//!
//! The committed golden fixtures (`rust/artifacts/golden/*.npz`) pin the
//! f32 stack to the JAX reference; this suite pins the fixed-point stack
//! to the f32 one: on every fixture configuration the quantized forward
//! pass and the engine-level features/inference must agree with the f32
//! `NativeEngine` within the worst-case bound derived in
//! `quant::budget` (validated against an exact integer mirror in
//! `python/tests/quant_mirror.py` — observed margins 2–40×), with zero
//! saturations (the budget's validity condition).
//!
//! Q4.12 is checked on the fixtures whose dynamic range it holds;
//! `paper_nx30` (V=12 → masked inputs up to ~12.6) exceeds Q4.12's ±8
//! and is covered at Q6.10 — the same conclusion the width sweep
//! reaches, and exactly the failure mode the budget's `+∞` encodes.

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::data::dataset::Sample;
use dfr_edge::data::npz;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};
use dfr_edge::quant::{
    r_tilde_error_bound, score_error_bound, BudgetInputs, QArith, QFormat, QuantConfig,
    QuantEngine, QuantForwardScratch, QuantReservoir,
};
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::proptest::{run_prop, Config};

/// Fixture configurations of make_golden.py (p/q live in the npz too;
/// reading them keeps this in sync with regenerated fixtures).
const FIXTURES: &[(&str, &[QFormat])] = &[
    ("small", &[QFormat::q4_12(), QFormat::q6_10()]),
    ("padded", &[QFormat::q4_12(), QFormat::q6_10()]),
    // V=12 masked inputs overflow Q4.12's ±8 → Q6.10 only
    ("paper_nx30", &[QFormat::q6_10()]),
];

fn golden(name: &str) -> std::collections::BTreeMap<String, npz::Array> {
    let path = format!("artifacts/golden/{name}.npz");
    npz::read_npz(&path).unwrap_or_else(|e| panic!("golden fixture {path}: {e:#}"))
}

/// Regenerate the closed-form inputs exactly as make_golden.py does
/// (single definition next to the matching `Mask::golden`).
fn inputs(t: usize, v: usize) -> Vec<f32> {
    Mask::golden_inputs(t, v)
}

/// Budget inputs for one fixture workload: trajectory magnitudes from
/// the f32 reference (`forward_history`), LUT error from the built LUT.
fn budget_for(
    res: &Reservoir,
    u: &[f32],
    t: usize,
    v: usize,
    eps_f: f32,
) -> BudgetInputs {
    let h = res.forward_history(u, t);
    let x_max = h.xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let u_max = u.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let j_max = v as f32 * u_max;
    BudgetInputs {
        p: res.p,
        q: res.q,
        lf: res.f.lipschitz_bound(),
        eps_f,
        t,
        nx: res.nx(),
        v,
        x_max,
        u_max,
        f_max: res.f.abs_bound(x_max + j_max),
    }
}

#[test]
fn quant_forward_within_bound_on_golden_fixtures() {
    for &(name, formats) in FIXTURES {
        let g = golden(name);
        let t = g["length"].scalar().unwrap() as usize;
        let v = g["v"].scalar().unwrap() as usize;
        let nx = g["nx"].scalar().unwrap() as usize;
        let p = g["p"].scalar().unwrap();
        let q = g["q"].scalar().unwrap();
        let u = inputs(g["t"].scalar().unwrap() as usize, v);
        let u = &u[..t * v];
        let mask = Mask::golden(nx, v);
        let f = Nonlinearity::Linear { alpha: 1.0 };
        let res = Reservoir {
            mask: mask.clone(),
            p,
            q,
            f,
        };
        let fwd = res.forward(u, t);
        let mut rt_f32 = Vec::new();
        fwd.r_tilde_into(&mut rt_f32);

        for &fmt in formats {
            let arith = QArith::new(fmt);
            let mut qres = QuantReservoir::new(mask.clone(), f, arith, 6);
            qres.set_params(p, q);
            let mut qs = QuantForwardScratch::new(nx, v);
            qres.forward_into(u, t, &mut qs);
            assert_eq!(
                qs.saturations(),
                0,
                "{name}/{}: saturated — budget assumption violated",
                fmt.name()
            );
            let inp = budget_for(&res, u, t, v, qres.lut().max_err());
            let bound = r_tilde_error_bound(fmt, &inp);
            assert!(
                bound.is_finite() && bound < 0.5,
                "{name}/{}: vacuous bound {bound}",
                fmt.name()
            );
            let mut rt_q = Vec::new();
            qs.r_tilde_into(arith, &mut rt_q);
            assert_eq!(rt_q.len(), rt_f32.len());
            for (i, (a, b)) in rt_q.iter().zip(&rt_f32).enumerate() {
                assert!(
                    (a - b).abs() <= bound,
                    "{name}/{} elem {i}: quant {a} vs f32 {b} exceeds bound {bound}",
                    fmt.name()
                );
            }
        }
    }
}

#[test]
fn quant_engine_matches_native_within_bound_on_golden_fixtures() {
    for &(name, formats) in FIXTURES {
        let g = golden(name);
        let t = g["length"].scalar().unwrap() as usize;
        let v = g["v"].scalar().unwrap() as usize;
        let nx = g["nx"].scalar().unwrap() as usize;
        let c = g["c"].scalar().unwrap() as usize;
        let p = g["p"].scalar().unwrap();
        let q = g["q"].scalar().unwrap();
        let u = inputs(g["t"].scalar().unwrap() as usize, v);
        let sample = Sample {
            u: u[..t * v].to_vec(),
            t,
            label: 0,
        };
        let mask = Mask::golden(nx, v);
        let f = Nonlinearity::Linear { alpha: 1.0 };
        let res = Reservoir {
            mask: mask.clone(),
            p,
            q,
            f,
        };
        let native = NativeEngine::with_nonlinearity(nx, c, f);
        let feats_f32 = native.features(&sample, &mask, p, q).unwrap();
        let sdim = feats_f32.len();
        // a deterministic non-trivial output layer (same recipe as
        // make_golden.py's w, extended to the tilde column)
        let w_tilde: Vec<f32> = (0..c * sdim)
            .map(|i| 0.01 * (0.05 * i as f32).sin())
            .collect();
        let scores_f32 = native.infer(&sample, &mask, p, q, &w_tilde).unwrap();

        for &fmt in formats {
            let eng = QuantEngine::with_config(nx, c, f, QuantConfig::with_format(fmt));
            let feats_q = eng.features(&sample, &mask, p, q).unwrap();
            assert_eq!(eng.last_saturations(), 0, "{name}/{}", fmt.name());
            let inp = budget_for(&res, &sample.u, t, v, {
                // LUT error for this format (engine's internal LUT uses
                // the same construction)
                dfr_edge::quant::PwlLut::new(f, QArith::new(fmt), 6).max_err()
            });
            let r_bound = r_tilde_error_bound(fmt, &inp);
            assert!(r_bound.is_finite(), "{name}/{}", fmt.name());
            for (i, (a, b)) in feats_q.iter().zip(&feats_f32).enumerate() {
                assert!(
                    (a - b).abs() <= r_bound,
                    "{name}/{} feature {i}: {a} vs {b} (bound {r_bound})",
                    fmt.name()
                );
            }
            // inference: pre-softmax scores deviate by at most the MAC
            // bound; softmax is 1-Lipschitz per coordinate in the ∞ norm
            // up to the shared normalizer, so 2× covers the probabilities
            let r_max = feats_f32.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let w_max = w_tilde.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let s_bound = score_error_bound(fmt, sdim, w_max, r_max, r_bound);
            let scores_q = eng.infer(&sample, &mask, p, q, &w_tilde).unwrap();
            for (i, (a, b)) in scores_q.iter().zip(&scores_f32).enumerate() {
                assert!(
                    (a - b).abs() <= 2.0 * s_bound,
                    "{name}/{} score {i}: {a} vs {b} (2·bound {})",
                    fmt.name(),
                    2.0 * s_bound
                );
            }
        }
    }
}

#[test]
fn property_quant_forward_within_bound_random_workloads() {
    run_prop(
        "quant forward ≤ analytic bound",
        Config {
            cases: 48,
            max_size: 10,
            ..Default::default()
        },
        |rng, size| {
            let nx = 2 + size as usize; // 3..=12
            let v = 1 + (rng.below(3) as usize);
            let t = 5 + (rng.below(30) as usize);
            // contraction with margin (p + |q| ≤ 0.6): keeps the worst
            // state magnitude p·j_max/(1−(p+|q|)) ≤ 3.75, comfortably
            // inside Q4.12's ±8 — no saturation, finite bound
            let p = 0.05 + 0.45 * rng.uniform();
            let q = (0.6 - p) * rng.uniform() * if rng.below(2) == 0 { 1.0 } else { -1.0 };
            // inputs bounded so Q4.12's ±8 holds the V-channel add tree
            let u: Vec<f32> = (0..t * v)
                .map(|_| 2.0 * (rng.uniform() - 0.5))
                .collect();
            let mask = Mask::random(nx, v, rng);
            let f = Nonlinearity::Linear { alpha: 1.0 };
            let res = Reservoir {
                mask: mask.clone(),
                p,
                q,
                f,
            };
            let fmt = QFormat::q4_12();
            let arith = QArith::new(fmt);
            let mut qres = QuantReservoir::new(mask, f, arith, 6);
            qres.set_params(p, q);
            let mut qs = QuantForwardScratch::new(nx, v);
            qres.forward_into(&u, t, &mut qs);
            if qs.saturations() > 0 {
                return Err(format!(
                    "saturated ({} events) at p={p} q={q} v={v}",
                    qs.saturations()
                ));
            }
            let inp = budget_for(&res, &u, t, v, qres.lut().max_err());
            let bound = r_tilde_error_bound(fmt, &inp);
            if !bound.is_finite() {
                // range-check rejection is allowed (not a violation),
                // but saturation must then have been impossible anyway
                return Ok(());
            }
            let fwd = res.forward(&u, t);
            let mut rt_f32 = Vec::new();
            fwd.r_tilde_into(&mut rt_f32);
            let mut rt_q = Vec::new();
            qs.r_tilde_into(arith, &mut rt_q);
            for (i, (a, b)) in rt_q.iter().zip(&rt_f32).enumerate() {
                if (a - b).abs() > bound {
                    return Err(format!(
                        "elem {i}: quant {a} vs f32 {b} exceeds bound {bound} \
                         (p={p} q={q} nx={nx} v={v} t={t})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quant_engine_serves_through_the_sharded_coordinator() {
    use dfr_edge::coordinator::{Request, Response, Server, ServerConfig, SessionConfig};
    use dfr_edge::data::profiles::Profile;
    use dfr_edge::data::synth;

    let prof = Profile {
        name: "mini",
        n_v: 2,
        n_c: 2,
        train: 30,
        test: 10,
        t_min: 10,
        t_max: 14,
    };
    let ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        17,
    );
    let mut scfg = SessionConfig::new(2, 2, ds.train.len());
    scfg.train.nx = 8;
    scfg.train.epochs = 4;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    let cfg = ServerConfig {
        queue_cap: 64,
        seed: 0xFACE,
        shards: 2,
        max_batch: 8,
        ..ServerConfig::new(scfg)
    };
    // Q6.10 (±32): holds the standardized synthetic inputs' V=2 add
    // tree without front-end scaling, so this is the native server test
    // with only the engine swapped
    let eng = QuantEngine::with_config(
        8,
        2,
        Nonlinearity::Linear { alpha: 1.0 },
        QuantConfig::with_format(QFormat::q6_10()),
    );
    let srv = Server::spawn(Box::new(eng), cfg);
    assert_eq!(srv.shards(), 2, "quant engine must fork across shards");
    let mut last = None;
    for s in &ds.train {
        last = Some(
            srv.call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap(),
        );
    }
    assert!(matches!(last, Some(Response::Trained { .. })), "{last:?}");
    let mut correct = 0;
    for s in &ds.test {
        match srv
            .call(Request::Infer {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            Response::Prediction { class, scores } => {
                assert_eq!(scores.len(), 2);
                if class == s.label {
                    correct += 1;
                }
            }
            other => panic!("{other:?}"),
        }
    }
    assert!(correct >= 7, "quantized serving accuracy {correct}/10");
    srv.shutdown();
}

#[test]
fn formats_rank_by_resolution() {
    // a quick deterministic cross-format ordering on one workload
    let mut rng = Pcg32::seed(0x0F0F);
    let nx = 6;
    let v = 2;
    let t = 20;
    let u: Vec<f32> = (0..t * v).map(|_| 1.5 * (rng.uniform() - 0.5)).collect();
    let mask = Mask::golden(nx, v);
    let f = Nonlinearity::Linear { alpha: 1.0 };
    let res = Reservoir {
        mask: mask.clone(),
        p: 0.25,
        q: 0.2,
        f,
    };
    let fwd = res.forward(&u, t);
    let mut rt_f32 = Vec::new();
    fwd.r_tilde_into(&mut rt_f32);
    let mut devs = Vec::new();
    for fmt in [QFormat::q4_12(), QFormat::q6_10(), QFormat::q8_8()] {
        let arith = QArith::new(fmt);
        let mut qres = QuantReservoir::new(mask.clone(), f, arith, 6);
        qres.set_params(0.25, 0.2);
        let mut qs = QuantForwardScratch::new(nx, v);
        qres.forward_into(&u, t, &mut qs);
        let mut rt = Vec::new();
        qs.r_tilde_into(arith, &mut rt);
        let dev = rt
            .iter()
            .zip(&rt_f32)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        devs.push(dev);
    }
    assert!(
        devs[0] < devs[2],
        "Q4.12 ({}) must beat Q8.8 ({})",
        devs[0],
        devs[2]
    );
}
