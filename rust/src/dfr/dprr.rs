//! Dot-product reservoir representation (DPRR, Eqs. 27–28).
//!
//! Converts the variable-length state evolution into a fixed-size feature
//! matrix by accumulating rank-1 products of consecutive states:
//!
//! ```text
//! R[i][j]  = Σ_k x(k)_i · x(k-1)_j    (i, j < Nx)
//! R[i][Nx] = Σ_k x(k)_i               (the plain sum features)
//! ```
//!
//! `r = vec(R)` row-major gives the paper's index layout
//! `r_{(i-1)Nx+j}` / `r_{Nx²+i}` (with the sums interleaved as the last
//! column, exactly as the JAX model lays it out).

/// Streaming DPRR accumulator: O(Nx²) memory, one `push` per time step.
#[derive(Clone, Debug)]
pub struct DprrAccumulator {
    nx: usize,
    /// row-major Nx×(Nx+1)
    acc: Vec<f32>,
}

impl DprrAccumulator {
    pub fn new(nx: usize) -> Self {
        DprrAccumulator {
            nx,
            acc: vec![0.0; nx * (nx + 1)],
        }
    }

    /// Fold one step: `R += x(k) ⊗ [x(k-1), 1]`.
    ///
    /// Row-wise axpy with 4-wide lanes (the scalar zip left ~2× of SIMD
    /// throughput on the table — §Perf).
    #[inline]
    pub fn push(&mut self, x_k: &[f32], x_km1: &[f32]) {
        debug_assert_eq!(x_k.len(), self.nx);
        debug_assert_eq!(x_km1.len(), self.nx);
        let w = self.nx + 1;
        for (i, &xi) in x_k.iter().enumerate() {
            let row = &mut self.acc[i * w..(i + 1) * w];
            let (body, _) = row.split_at_mut(self.nx);
            let mut rc = body.chunks_exact_mut(4);
            let mut xc = x_km1.chunks_exact(4);
            for (r4, x4) in rc.by_ref().zip(xc.by_ref()) {
                r4[0] += xi * x4[0];
                r4[1] += xi * x4[1];
                r4[2] += xi * x4[2];
                r4[3] += xi * x4[3];
            }
            for (r, &xj) in rc.into_remainder().iter_mut().zip(xc.remainder()) {
                *r += xi * xj;
            }
            row[self.nx] += xi;
        }
    }

    pub fn reset(&mut self) {
        self.acc.fill(0.0);
    }

    pub fn matrix(&self) -> &[f32] {
        &self.acc
    }

    pub fn into_matrix(self) -> Vec<f32> {
        self.acc
    }
}

/// Feature count `N_r = Nx(Nx+1)` of the DPRR (before the tilde 1).
pub fn n_features(nx: usize) -> usize {
    nx * (nx + 1)
}

/// Ridge system size `s = Nx² + Nx + 1` (Eq. 20).
pub fn s_dim(nx: usize) -> usize {
    nx * nx + nx + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn single_push_is_outer_product() {
        let mut a = DprrAccumulator::new(2);
        a.push(&[2.0, 3.0], &[5.0, 7.0]);
        // rows: [x_i*xp_0, x_i*xp_1, x_i]
        assert_eq!(a.matrix(), &[10.0, 14.0, 2.0, 15.0, 21.0, 3.0]);
    }

    #[test]
    fn accumulates_over_steps() {
        let mut a = DprrAccumulator::new(1);
        a.push(&[1.0], &[0.0]);
        a.push(&[2.0], &[1.0]);
        a.push(&[3.0], &[2.0]);
        // pair: 1*0 + 2*1 + 3*2 = 8; sum: 6
        assert_eq!(a.matrix(), &[8.0, 6.0]);
    }

    #[test]
    fn matches_naive_double_loop() {
        let mut rng = Pcg32::seed(9);
        let nx = 7;
        let t = 25;
        let xs: Vec<Vec<f32>> = (0..t)
            .map(|_| (0..nx).map(|_| rng.normal()).collect())
            .collect();
        let mut a = DprrAccumulator::new(nx);
        let zero = vec![0.0f32; nx];
        for k in 0..t {
            let prev = if k == 0 { &zero } else { &xs[k - 1] };
            a.push(&xs[k], prev);
        }
        // naive Eqs. (27)-(28)
        for i in 0..nx {
            for j in 0..nx {
                let mut want = 0.0f32;
                for k in 0..t {
                    let prev = if k == 0 { 0.0 } else { xs[k - 1][j] };
                    want += xs[k][i] * prev;
                }
                let got = a.matrix()[i * (nx + 1) + j];
                assert!((got - want).abs() < 1e-4, "({i},{j}): {got} vs {want}");
            }
            let want: f32 = (0..t).map(|k| xs[k][i]).sum();
            let got = a.matrix()[i * (nx + 1) + nx];
            assert!((got - want).abs() < 1e-4);
        }
    }

    #[test]
    fn dims() {
        assert_eq!(n_features(30), 930);
        assert_eq!(s_dim(30), 931);
    }
}
