//! Reservoir layer: the modular DFR model (Eq. 14) and the conventional
//! Mackey–Glass digital DFR (Eqs. 8–9).
//!
//! The modular model decomposes the nonlinear element into a one-input
//! one-output function `f` plus two scalar parameters:
//!
//! ```text
//! x(k)_n = p · f(j(k)_n + x(k-1)_n) + q · x(k)_{n-1},   x(k)_0 ≡ x(k-1)_{Nx}
//! ```
//!
//! Forward processing is streaming: the full state history is never
//! stored (only `x(k-1)`, `x(k)` and the DPRR accumulator), matching the
//! paper's edge memory budget (§3.5). A history-recording variant exists
//! for the full-BPTT oracle.

use super::dprr::DprrAccumulator;
use super::mask::Mask;

/// `|x|^p` with an integer fast path: the paper's default exponent
/// p = 2 becomes a single multiply (`|x|² = x·x` exactly in IEEE
/// arithmetic) instead of a `powf` libm call — the Mackey–Glass step
/// evaluates this once per virtual node per time step.
#[inline(always)]
fn pow_abs(x: f32, p: f32) -> f32 {
    if p == 2.0 {
        x * x
    } else {
        x.abs().powf(p)
    }
}

/// The one-input one-output nonlinearity `f` of the modular DFR.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Nonlinearity {
    /// `f(x) = α·x` — used for all datasets in the paper's evaluation
    /// (§4, "as recommended in [11]").
    Linear { alpha: f32 },
    /// `f(x) = tanh(x)` — a common alternative the modular model admits.
    Tanh,
    /// `f(x) = η·x / (1 + |x|^p)` — Mackey–Glass-style saturating map
    /// (Eq. 3).
    MackeyGlass { eta: f32, p_exp: f32 },
}

impl Nonlinearity {
    #[inline(always)]
    pub fn eval(self, x: f32) -> f32 {
        match self {
            Nonlinearity::Linear { alpha } => alpha * x,
            Nonlinearity::Tanh => x.tanh(),
            Nonlinearity::MackeyGlass { eta, p_exp } => {
                eta * x / (1.0 + pow_abs(x, p_exp))
            }
        }
    }

    /// Upper bound on |f'| over the whole real line — the Lipschitz
    /// constant the quantized datapath's error budget propagates input
    /// error through (`quant::budget`).
    #[inline]
    pub fn lipschitz_bound(self) -> f32 {
        match self {
            Nonlinearity::Linear { alpha } => alpha.abs(),
            Nonlinearity::Tanh => 1.0,
            // |f'| = η|1 + (1−p)a|/(1+a)² with a = |x|^p ≥ 0 peaks at
            // η at a = 0 for p ≤ 2; η·(p−1) majorizes the tail beyond
            Nonlinearity::MackeyGlass { eta, p_exp } => {
                eta.abs() * 1.0f32.max(p_exp - 1.0)
            }
        }
    }

    /// Upper bound on |f(x)| over |x| ≤ `m` (error-budget input).
    #[inline]
    pub fn abs_bound(self, m: f32) -> f32 {
        match self {
            Nonlinearity::Linear { alpha } => alpha.abs() * m,
            Nonlinearity::Tanh => 1.0f32.min(m),
            // |x|/(1 + |x|^p) ≤ |x|
            Nonlinearity::MackeyGlass { eta, .. } => eta.abs() * m,
        }
    }

    /// Derivative f'(x) — needed by full BPTT (Eq. 30).
    #[inline(always)]
    pub fn deriv(self, x: f32) -> f32 {
        match self {
            Nonlinearity::Linear { alpha } => alpha,
            Nonlinearity::Tanh => {
                let t = x.tanh();
                1.0 - t * t
            }
            Nonlinearity::MackeyGlass { eta, p_exp } => {
                // d/dx [η x (1+|x|^p)^-1]
                let a = pow_abs(x, p_exp);
                let denom = 1.0 + a;
                eta * (1.0 + a - p_exp * a) / (denom * denom)
            }
        }
    }
}

/// Result of a forward pass — everything truncated BP and ridge need.
#[derive(Clone, Debug)]
pub struct Forward {
    /// DPRR matrix, row-major Nx×(Nx+1), **normalized by 1/T**; `vec(R)`
    /// is the feature vector r.
    ///
    /// The 1/T normalization is a diagonal rescaling of Eqs. (27)–(28)
    /// that makes the feature magnitude — and hence the meaning of the
    /// fixed β grid {1e-6..1} — independent of the series length
    /// (T spans 29..1918 across Table 4, i.e. raw-B magnitudes spanning
    /// ~4 000×, which f32 Cholesky cannot absorb). Documented deviation
    /// (DESIGN.md §10).
    pub r_mat: Vec<f32>,
    /// final reservoir state x(T)
    pub x_t: Vec<f32>,
    /// previous state x(T-1)
    pub x_tm1: Vec<f32>,
    /// last masked input j(T)
    pub j_t: Vec<f32>,
    /// series length T (the normalization factor; backprop needs it)
    pub t_len: usize,
}

impl Forward {
    /// r̃ = [vec(R), 1] — the ridge feature vector (Eq. 16).
    pub fn r_tilde(&self) -> Vec<f32> {
        let mut r = Vec::with_capacity(self.r_mat.len() + 1);
        r.extend_from_slice(&self.r_mat);
        r.push(1.0);
        r
    }

    /// r̃ into a caller-owned buffer; retains `out`'s capacity, so the
    /// steady state performs no heap allocation.
    pub fn r_tilde_into(&self, out: &mut Vec<f32>) {
        self.as_view().r_tilde_into(out);
    }

    /// Borrowed view — what the backward pass reads.
    pub fn as_view(&self) -> ForwardRef<'_> {
        ForwardRef {
            r_mat: &self.r_mat,
            x_t: &self.x_t,
            x_tm1: &self.x_tm1,
            j_t: &self.j_t,
            t_len: self.t_len,
        }
    }
}

/// Borrowed view of a forward result, with the same field contract as
/// [`Forward`]. Produced by [`Forward::as_view`] (owned result) or
/// [`ForwardScratch::as_forward_ref`] (workspace, allocation-free) —
/// lets `truncated_grads` run without an owned `Forward` snapshot.
#[derive(Clone, Copy, Debug)]
pub struct ForwardRef<'a> {
    /// DPRR matrix, row-major Nx×(Nx+1), normalized by 1/T.
    pub r_mat: &'a [f32],
    pub x_t: &'a [f32],
    pub x_tm1: &'a [f32],
    pub j_t: &'a [f32],
    pub t_len: usize,
}

impl ForwardRef<'_> {
    /// r̃ = [vec(R), 1] into a caller-owned buffer (capacity reused; no
    /// heap allocation once `out` has been sized) — the single
    /// definition behind `Forward::r_tilde_into` and
    /// `ForwardScratch::r_tilde_into`.
    pub fn r_tilde_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.r_mat.len() + 1);
        out.extend_from_slice(self.r_mat);
        out.push(1.0);
    }
}

/// Reusable forward-pass workspace: every buffer a streaming forward
/// touches — x(k), x(k-1), j(k), the DPRR accumulator and the normalized
/// DPRR matrix — allocated once and reused across samples. A steady-state
/// `forward_into` performs **zero heap allocations** (DESIGN.md §9;
/// asserted by `tests/zero_alloc.rs` through the engine layer).
#[derive(Clone, Debug)]
pub struct ForwardScratch {
    nx: usize,
    x: Vec<f32>,
    x_prev: Vec<f32>,
    j: Vec<f32>,
    acc: DprrAccumulator,
    r_mat: Vec<f32>,
    t_len: usize,
}

impl ForwardScratch {
    pub fn new(nx: usize) -> Self {
        ForwardScratch {
            nx,
            x: vec![0.0; nx],
            x_prev: vec![0.0; nx],
            j: vec![0.0; nx],
            acc: DprrAccumulator::new(nx),
            r_mat: vec![0.0; nx * (nx + 1)],
            t_len: 0,
        }
    }

    /// Re-size for a different reservoir dimension; allocates only on
    /// change, a no-op in steady state.
    pub fn ensure(&mut self, nx: usize) {
        if self.nx != nx {
            *self = ForwardScratch::new(nx);
        }
    }

    pub fn nx(&self) -> usize {
        self.nx
    }

    /// Normalized DPRR matrix of the last `forward_into`.
    pub fn r_mat(&self) -> &[f32] {
        &self.r_mat
    }

    pub fn x_t(&self) -> &[f32] {
        &self.x
    }

    pub fn x_tm1(&self) -> &[f32] {
        &self.x_prev
    }

    pub fn j_t(&self) -> &[f32] {
        &self.j
    }

    pub fn t_len(&self) -> usize {
        self.t_len
    }

    /// r̃ = [vec(R), 1] into a caller-owned buffer (capacity reused).
    pub fn r_tilde_into(&self, out: &mut Vec<f32>) {
        self.as_forward_ref().r_tilde_into(out);
    }

    /// Borrowed view with the [`Forward`] field contract (allocation-free).
    pub fn as_forward_ref(&self) -> ForwardRef<'_> {
        ForwardRef {
            r_mat: &self.r_mat,
            x_t: &self.x,
            x_tm1: &self.x_prev,
            j_t: &self.j,
            t_len: self.t_len,
        }
    }

    /// Consume the workspace into an owned [`Forward`] (moves, no copy) —
    /// the compatibility path behind the allocating `forward` wrappers.
    pub fn into_forward(self) -> Forward {
        Forward {
            r_mat: self.r_mat,
            x_t: self.x,
            x_tm1: self.x_prev,
            j_t: self.j,
            t_len: self.t_len,
        }
    }
}

/// A configured modular-DFR reservoir (mask + parameters + nonlinearity).
#[derive(Clone, Debug)]
pub struct Reservoir {
    pub mask: Mask,
    pub p: f32,
    pub q: f32,
    pub f: Nonlinearity,
}

impl Reservoir {
    pub fn nx(&self) -> usize {
        self.mask.nx
    }

    /// One time step (Eq. 14) in place: `x` is x(k-1) on entry, x(k) on
    /// exit. `j` must already hold j(k).
    #[inline]
    pub fn step(&self, x: &mut [f32], j: &[f32]) {
        let nx = x.len();
        let mut prev_node = x[nx - 1]; // wrap: x(k)_0 = x(k-1)_{Nx}
        for n in 0..nx {
            let xn = self.p * self.f.eval(j[n] + x[n]) + self.q * prev_node;
            prev_node = xn;
            x[n] = xn;
        }
    }

    /// Streaming forward pass over a series `u` (row-major T×V).
    ///
    /// O(Nx²) memory total (the DPRR accumulator), independent of T.
    /// Thin wrapper over [`forward_into`](Self::forward_into) — hot
    /// callers hold a [`ForwardScratch`] and skip the allocations.
    pub fn forward(&self, u: &[f32], t: usize) -> Forward {
        let mut scratch = ForwardScratch::new(self.nx());
        self.forward_into(u, t, &mut scratch);
        scratch.into_forward()
    }

    /// Allocation-free streaming forward: identical recurrence and
    /// op order as [`forward`](Self::forward) (results are bitwise
    /// equal), writing into a caller-owned reusable workspace.
    pub fn forward_into(&self, u: &[f32], t: usize, s: &mut ForwardScratch) {
        let nx = self.nx();
        let v = self.mask.v;
        assert_eq!(u.len(), t * v, "series shape mismatch");
        s.ensure(nx);
        s.x.fill(0.0);
        s.x_prev.fill(0.0);
        s.j.fill(0.0);
        s.acc.reset();
        for k in 0..t {
            s.x_prev.copy_from_slice(&s.x);
            self.mask.apply(&u[k * v..(k + 1) * v], &mut s.j);
            self.step(&mut s.x, &s.j);
            s.acc.push(&s.x, &s.x_prev);
        }
        let inv_t = 1.0 / t.max(1) as f32;
        for (r, &a) in s.r_mat.iter_mut().zip(s.acc.matrix()) {
            *r = a * inv_t;
        }
        s.t_len = t;
    }

    /// Forward pass that records the whole state and input history —
    /// required by the full-BPTT oracle (Eqs. 29–32). Memory O(T·Nx),
    /// exactly the cost §3.5's truncation eliminates.
    pub fn forward_history(&self, u: &[f32], t: usize) -> History {
        let nx = self.nx();
        let v = self.mask.v;
        assert_eq!(u.len(), t * v);
        let mut x = vec![0.0f32; nx];
        let mut xs = Vec::with_capacity(t * nx);
        let mut js = Vec::with_capacity(t * nx);
        let mut j = vec![0.0f32; nx];
        let mut acc = DprrAccumulator::new(nx);
        let mut x_prev = vec![0.0f32; nx];
        for k in 0..t {
            x_prev.copy_from_slice(&x);
            self.mask.apply(&u[k * v..(k + 1) * v], &mut j);
            self.step(&mut x, &j);
            js.extend_from_slice(&j);
            xs.extend_from_slice(&x);
            acc.push(&x, &x_prev);
        }
        let mut r_mat = acc.into_matrix();
        let inv_t = 1.0 / t.max(1) as f32;
        for r in r_mat.iter_mut() {
            *r *= inv_t;
        }
        History { nx, t, xs, js, r_mat }
    }
}

/// Full state/input history (full-BPTT oracle only).
#[derive(Clone, Debug)]
pub struct History {
    pub nx: usize,
    pub t: usize,
    /// xs[k*nx + n] = x(k+1)_{n+1}
    pub xs: Vec<f32>,
    /// js[k*nx + n] = j(k+1)_{n+1}
    pub js: Vec<f32>,
    pub r_mat: Vec<f32>,
}

impl History {
    /// x(k)_n with 1-based k (x(0) = 0).
    #[inline]
    pub fn x(&self, k: usize, n: usize) -> f32 {
        if k == 0 {
            0.0
        } else {
            self.xs[(k - 1) * self.nx + n]
        }
    }

    #[inline]
    pub fn j(&self, k: usize, n: usize) -> f32 {
        self.js[(k - 1) * self.nx + n]
    }

    pub fn state(&self, k: usize) -> &[f32] {
        &self.xs[(k - 1) * self.nx..k * self.nx]
    }
}

/// The conventional fully-digital Mackey–Glass DFR (Eqs. 8–9) — the
/// baseline architecture the modular model replaces. Exposed for the
/// design-space comparisons in `benches/` and the examples.
#[derive(Clone, Debug)]
pub struct MackeyGlassDfr {
    pub mask: Mask,
    pub gamma: f32,
    pub eta: f32,
    pub p_exp: f32,
    /// virtual-node interval θ (Nx·θ = τ)
    pub theta: f32,
}

impl MackeyGlassDfr {
    /// The virtual-node decay `e = exp(−θ)` — constant over a series, so
    /// the forward loop hoists it instead of recomputing per step.
    #[inline]
    pub fn decay(&self) -> f32 {
        (-self.theta).exp()
    }

    /// One time step of Eqs. (8)–(9) in place.
    pub fn step(&self, x: &mut [f32], j: &[f32]) {
        let e = self.decay();
        self.step_with_decay(x, j, e, 1.0 - e);
    }

    /// Eqs. (8)–(9) with the decay `e = exp(−θ)` (and `1 − e`) supplied
    /// by the caller — the forward loop computes them once per series
    /// rather than once per time step.
    #[inline]
    pub fn step_with_decay(&self, x: &mut [f32], j: &[f32], e: f32, one_e: f32) {
        let nx = x.len();
        let mut cascade = x[nx - 1];
        for n in 0..nx {
            let arg = x[n] + self.gamma * j[n];
            let f = self.eta * arg / (1.0 + pow_abs(arg, self.p_exp));
            let xn = cascade * e + one_e * f;
            cascade = xn;
            x[n] = xn;
        }
    }

    /// Streaming forward with DPRR — same output contract as
    /// [`Reservoir::forward`] so both plug into the same output layer.
    pub fn forward(&self, u: &[f32], t: usize) -> Forward {
        let mut scratch = ForwardScratch::new(self.mask.nx);
        self.forward_into(u, t, &mut scratch);
        scratch.into_forward()
    }

    /// Allocation-free streaming forward into a reusable workspace —
    /// same contract as [`Reservoir::forward_into`], with the per-step
    /// `exp(−θ)` hoisted out of the time loop.
    pub fn forward_into(&self, u: &[f32], t: usize, s: &mut ForwardScratch) {
        let nx = self.mask.nx;
        let v = self.mask.v;
        assert_eq!(u.len(), t * v);
        s.ensure(nx);
        s.x.fill(0.0);
        s.x_prev.fill(0.0);
        s.j.fill(0.0);
        s.acc.reset();
        let e = self.decay();
        let one_e = 1.0 - e;
        for k in 0..t {
            s.x_prev.copy_from_slice(&s.x);
            self.mask.apply(&u[k * v..(k + 1) * v], &mut s.j);
            self.step_with_decay(&mut s.x, &s.j, e, one_e);
            s.acc.push(&s.x, &s.x_prev);
        }
        let inv_t = 1.0 / t.max(1) as f32;
        for (r, &a) in s.r_mat.iter_mut().zip(s.acc.matrix()) {
            *r = a * inv_t;
        }
        s.t_len = t;
    }
}

/// One lane of a batched forward pass: a series plus the per-session
/// configuration it must run under. Lanes carry their **own** mask and
/// serving parameters `(p, q)` because the coordinator batches requests
/// across sessions, and every session owns a distinct random mask and a
/// distinct pinned `(gen_p, gen_q)` (DESIGN.md §13). Only `Nx` (state
/// layout) and the nonlinearity `f` must be uniform across a batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchLane<'a> {
    /// input series, row-major t × v
    pub u: &'a [f32],
    /// series length T (may differ per lane — ragged batches are fine)
    pub t: usize,
    /// the lane's mask (defines v; `mask.nx` must match across lanes)
    pub mask: &'a Mask,
    pub p: f32,
    pub q: f32,
}

/// Reusable workspace for the batched forward pass: many series advance
/// through the virtual-node recurrence together, so the sequential
/// cascade loop runs once per (step, node) over the whole batch instead
/// of once per call.
///
/// Layout (DESIGN.md §14/§18): everything the sweep *computes over* is
/// **node-major** (`x[n·b + l]`, lanes contiguous — including the `jt`
/// staging copy of the masked inputs and the raw DPRR accumulators), so
/// every inner loop over lanes is a unit-stride sweep an 8-wide SIMD
/// kernel can load directly; the lane-facing buffers (`j`, `r_mat`,
/// `x_out`, `x_prev_out`) are **lane-major** so each lane's results are
/// contiguous slices that plug straight into the existing
/// [`ForwardRef`] consumers.
///
/// Equivalence contract: per lane, the kernel executes the *identical*
/// per-scalar operation sequence as [`Reservoir::forward_into`] — the
/// mask dot product is `Mask::apply` itself, the recurrence is the same
/// mul/add chain, and each DPRR element receives exactly one
/// `acc += x_i·x'_m` per step (the per-call 4-wide chunking in
/// `DprrAccumulator::push` does not change per-element math; the
/// node-major accumulator layout relocates elements but not their
/// per-element op order, and the `j → jt` staging is bitwise copies).
/// Rust f32 arithmetic is deterministic (no fast-math, no auto-FMA), so
/// batched results are **bitwise equal** to per-call results at every
/// batch size, including ragged batches (`tests/batch_equivalence.rs`) —
/// and the same holds under the AVX2 kernel table, whose lane kernels
/// preserve per-lane op order exactly (`crate::simd`,
/// `tests/simd_equivalence.rs`).
///
/// Buffers are grow-only: after warm-up at the largest (nx, lanes) seen,
/// a steady-state `forward_batch_into` performs zero heap allocations
/// (`tests/zero_alloc.rs`).
#[derive(Clone, Debug, Default)]
pub struct BatchScratch {
    nx: usize,
    /// lane capacity (grow-only high-water mark)
    cap: usize,
    /// active lane count of the last `forward_batch_into`
    lanes: usize,
    /// x(k), node-major `[n·b + l]` during the sweep
    x: Vec<f32>,
    /// x(k-1), node-major
    x_prev: Vec<f32>,
    /// masked inputs j(k), lane-major `[l·nx + n]` — each lane's slice is
    /// exactly the `j_out` buffer `Mask::apply` writes in the per-call path
    j: Vec<f32>,
    /// node-major staging copy of `j` (`[n·b + l]`) — what the lane
    /// kernels actually read; filled by bitwise scatter after masking
    jt: Vec<f32>,
    /// per-lane cascade register (the scalar `prev_node` of `step`)
    cascade: Vec<f32>,
    /// raw DPRR accumulators, node-major `[(i·(nx+1)+m)·b + l]` so the
    /// per-element lane loop is unit-stride; de-interleaved into the
    /// lane-major `r_mat` at normalization time
    acc: Vec<f32>,
    /// per-lane activity mask for ragged steps (`!0` = lane still
    /// running at step k, `0` = frozen), the blend predicate of the
    /// SIMD kernels; empty-slice convention = all lanes active
    active: Vec<u32>,
    /// normalized DPRR matrices, lane-major
    r_mat: Vec<f32>,
    /// final states x(T), transposed to lane-major after the sweep
    x_out: Vec<f32>,
    /// states x(T-1), lane-major
    x_prev_out: Vec<f32>,
    t_lens: Vec<usize>,
    ps: Vec<f32>,
    qs: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow buffers for (`nx`, `lanes`); allocation only when a new
    /// high-water mark is reached (or nx changes), a no-op in steady state.
    pub fn ensure(&mut self, nx: usize, lanes: usize) {
        if self.nx != nx {
            self.nx = nx;
            self.cap = 0;
            self.x.clear();
            self.x_prev.clear();
            self.j.clear();
            self.jt.clear();
            self.acc.clear();
            self.r_mat.clear();
            self.x_out.clear();
            self.x_prev_out.clear();
        }
        if lanes > self.cap {
            self.cap = lanes;
            let nf = nx * (nx + 1);
            self.x.resize(nx * lanes, 0.0);
            self.x_prev.resize(nx * lanes, 0.0);
            self.j.resize(nx * lanes, 0.0);
            self.jt.resize(nx * lanes, 0.0);
            self.cascade.resize(lanes, 0.0);
            self.acc.resize(nf * lanes, 0.0);
            self.active.resize(lanes, 0);
            self.r_mat.resize(nf * lanes, 0.0);
            self.x_out.resize(nx * lanes, 0.0);
            self.x_prev_out.resize(nx * lanes, 0.0);
            self.t_lens.reserve(lanes);
            self.ps.reserve(lanes);
            self.qs.reserve(lanes);
        }
    }

    pub fn nx(&self) -> usize {
        self.nx
    }

    /// Active lane count of the last `forward_batch_into`.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Normalized DPRR matrix of lane `l` (row-major Nx×(Nx+1), 1/T
    /// normalized — same contract as [`ForwardScratch::r_mat`]).
    pub fn r_mat(&self, l: usize) -> &[f32] {
        let nf = self.nx * (self.nx + 1);
        &self.r_mat[l * nf..(l + 1) * nf]
    }

    pub fn t_len(&self, l: usize) -> usize {
        self.t_lens[l]
    }

    /// Lane `l` as a [`ForwardRef`] — drop-in for every per-call
    /// consumer (r̃ extraction, truncated BPTT).
    pub fn lane(&self, l: usize) -> ForwardRef<'_> {
        assert!(l < self.lanes, "lane {l} out of range ({} active)", self.lanes);
        let nx = self.nx;
        ForwardRef {
            r_mat: self.r_mat(l),
            x_t: &self.x_out[l * nx..(l + 1) * nx],
            x_tm1: &self.x_prev_out[l * nx..(l + 1) * nx],
            j_t: &self.j[l * nx..(l + 1) * nx],
            t_len: self.t_lens[l],
        }
    }

    /// r̃ = [vec(R), 1] of lane `l` into a caller-owned buffer.
    pub fn r_tilde_into(&self, l: usize, out: &mut Vec<f32>) {
        self.lane(l).r_tilde_into(out);
    }

    /// Batched streaming forward over `n_lanes` lanes supplied by
    /// `lane_fn` (called repeatedly; must be cheap and pure).
    ///
    /// All lanes share the state dimension `Nx` and the nonlinearity
    /// `f`; mask, series length and `(p, q)` are per-lane. Ragged
    /// batches run every lane for its own T: a lane whose series is
    /// exhausted is skipped (its state, masked input and accumulator
    /// freeze at their final values), so its outputs are bitwise those
    /// of a per-call `forward_into` at length `t`.
    pub fn forward_batch_into<'a>(
        &mut self,
        f: Nonlinearity,
        n_lanes: usize,
        lane_fn: impl Fn(usize) -> BatchLane<'a>,
    ) {
        let kernels = crate::simd::global_kernels();
        self.forward_batch_into_with(f, n_lanes, lane_fn, &kernels);
    }

    /// [`forward_batch_into`](Self::forward_batch_into) with an explicit
    /// kernel table — the dispatch seam of the SIMD layer
    /// (`crate::simd`). Per-lane results are **bitwise identical** under
    /// every table: the lane kernels (`cascade_row`, `dprr_row`,
    /// `dprr_bias`) are required to preserve each lane's scalar op order
    /// exactly (`tests/simd_equivalence.rs` pins this at batch sizes
    /// {1, 2, 7, 8, 9, 64} including ragged mixes).
    pub fn forward_batch_into_with<'a>(
        &mut self,
        f: Nonlinearity,
        n_lanes: usize,
        lane_fn: impl Fn(usize) -> BatchLane<'a>,
        kernels: &crate::simd::Kernels,
    ) {
        self.lanes = n_lanes;
        if n_lanes == 0 {
            return;
        }
        let nx = lane_fn(0).mask.nx;
        assert!(nx > 0, "empty reservoir");
        self.t_lens.clear();
        self.ps.clear();
        self.qs.clear();
        let (mut t_max, mut t_min) = (0usize, usize::MAX);
        for l in 0..n_lanes {
            let lane = lane_fn(l);
            assert_eq!(lane.mask.nx, nx, "batch lanes must share Nx (lane {l})");
            assert_eq!(
                lane.u.len(),
                lane.t * lane.mask.v,
                "series shape mismatch (lane {l})"
            );
            self.t_lens.push(lane.t);
            self.ps.push(lane.p);
            self.qs.push(lane.q);
            t_max = t_max.max(lane.t);
            t_min = t_min.min(lane.t);
        }
        self.ensure(nx, n_lanes);
        let b = n_lanes;
        let nw = nx + 1;
        let nf = nx * nw;
        let x = &mut self.x[..nx * b];
        let x_prev = &mut self.x_prev[..nx * b];
        let j = &mut self.j[..nx * b];
        let jt = &mut self.jt[..nx * b];
        let cascade = &mut self.cascade[..b];
        let acc = &mut self.acc[..nf * b];
        let active = &mut self.active[..b];
        x.fill(0.0);
        x_prev.fill(0.0);
        j.fill(0.0);
        jt.fill(0.0);
        acc.fill(0.0);
        for k in 0..t_max {
            let all_active = k < t_min;
            // Ragged steps carry a per-lane blend mask (!0 = running, 0
            // = frozen); the uniform fast path passes the empty slice.
            if !all_active {
                for l in 0..b {
                    active[l] = if k < self.t_lens[l] { u32::MAX } else { 0 };
                }
            }
            let act: &[u32] = if all_active { &[] } else { &active[..] };
            // x(k-1) ← x(k); guarded per lane when ragged so an
            // exhausted lane keeps its own final x(T-1).
            if all_active {
                x_prev.copy_from_slice(x);
            } else {
                for n in 0..nx {
                    let row = n * b;
                    for l in 0..b {
                        if k < self.t_lens[l] {
                            x_prev[row + l] = x[row + l];
                        }
                    }
                }
            }
            // Masking: the per-call `Mask::apply` verbatim, once per
            // active lane, into the lane's contiguous j slice — then a
            // bitwise scatter into the node-major staging buffer the
            // lane kernels read (unit stride over lanes).
            for l in 0..b {
                if k < self.t_lens[l] {
                    let lane = lane_fn(l);
                    let v = lane.mask.v;
                    lane.mask
                        .apply(&lane.u[k * v..(k + 1) * v], &mut j[l * nx..(l + 1) * nx]);
                    for n in 0..nx {
                        jt[n * b + l] = j[l * nx + n];
                    }
                }
            }
            // Cascade seed: x(k)_0 ≡ x(k-1)_{Nx}, read before node 0
            // overwrites anything (node Nx-1 is written last).
            let last_row = (nx - 1) * b;
            for l in 0..b {
                cascade[l] = x[last_row + l];
            }
            // Virtual-node recurrence, node-outer / lane-inner: the
            // sequential dependence runs once per step over the whole
            // batch. Per lane the kernel executes exactly
            // `Reservoir::step`'s `p·f(j+x) + q·prev` chain (scalar
            // table: the literal loop; AVX2 table: 8 lanes per
            // instruction, frozen lanes blended back, scalar tail).
            for n in 0..nx {
                let row = n * b;
                (kernels.cascade_row)(
                    f,
                    &self.ps[..b],
                    &self.qs[..b],
                    &mut x[row..row + b],
                    &jt[row..row + b],
                    cascade,
                    act,
                );
            }
            // DPRR accumulate per active lane: one `+= x_i·x'_m` (and
            // one `+= x_i` into the bias column) per element per step —
            // per-element identical to `DprrAccumulator::push`. The
            // accumulator is node-major, so each (i, m) element is a
            // unit-stride lane row for the kernel.
            for i in 0..nx {
                let xi = &x[i * b..(i + 1) * b];
                for m in 0..nx {
                    let arow = (i * nw + m) * b;
                    (kernels.dprr_row)(
                        &mut acc[arow..arow + b],
                        xi,
                        &x_prev[m * b..(m + 1) * b],
                        act,
                    );
                }
                let arow = (i * nw + nx) * b;
                (kernels.dprr_bias)(&mut acc[arow..arow + b], xi, act);
            }
        }
        // Normalize by each lane's own 1/T and de-interleave out to
        // lane-major — bitwise copies and one scalar multiply per
        // element, exactly as before, so equality is preserved.
        for l in 0..b {
            let inv_t = 1.0 / self.t_lens[l].max(1) as f32;
            let dst = &mut self.r_mat[l * nf..(l + 1) * nf];
            for (e, r) in dst.iter_mut().enumerate() {
                *r = acc[e * b + l] * inv_t;
            }
            for n in 0..nx {
                self.x_out[l * nx + n] = x[n * b + l];
                self.x_prev_out[l * nx + n] = x_prev[n * b + l];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn toy_reservoir(nx: usize, v: usize, p: f32, q: f32) -> Reservoir {
        Reservoir {
            mask: Mask::golden(nx, v),
            p,
            q,
            f: Nonlinearity::Linear { alpha: 1.0 },
        }
    }

    #[test]
    fn step_matches_recurrence_by_hand() {
        let r = toy_reservoir(3, 1, 0.5, 0.25);
        let mut x = vec![0.1, 0.2, 0.4];
        let j = vec![1.0, -1.0, 1.0];
        r.step(&mut x, &j);
        // x1 = 0.5*(1.0+0.1) + 0.25*0.4 = 0.65
        assert!((x[0] - 0.65).abs() < 1e-6);
        // x2 = 0.5*(-1.0+0.2) + 0.25*0.65
        assert!((x[1] - (-0.4 + 0.1625)).abs() < 1e-6);
        // x3 = 0.5*(1.0+0.4) + 0.25*x2
        assert!((x[2] - (0.7 + 0.25 * x[1])).abs() < 1e-6);
    }

    #[test]
    fn forward_state_independent_of_history_storage() {
        let r = toy_reservoir(5, 2, 0.3, 0.2);
        let mut rng = Pcg32::seed(1);
        let t = 17;
        let u: Vec<f32> = (0..t * 2).map(|_| rng.normal()).collect();
        let f = r.forward(&u, t);
        let h = r.forward_history(&u, t);
        assert_eq!(f.x_t, h.state(t));
        assert_eq!(f.r_mat, h.r_mat);
    }

    #[test]
    fn r_tilde_appends_one() {
        let r = toy_reservoir(2, 1, 0.3, 0.2);
        let f = r.forward(&[1.0, -1.0, 0.5], 3);
        let rt = f.r_tilde();
        assert_eq!(rt.len(), 2 * 3 + 1);
        assert_eq!(*rt.last().unwrap(), 1.0);
    }

    #[test]
    fn forward_into_matches_forward_and_reuses_scratch() {
        let r = toy_reservoir(6, 3, 0.3, 0.2);
        let mut rng = Pcg32::seed(11);
        let mut scratch = ForwardScratch::new(6);
        // two different series through ONE scratch — catches stale state
        for t in [13usize, 7] {
            let u: Vec<f32> = (0..t * 3).map(|_| rng.normal()).collect();
            let f = r.forward(&u, t);
            r.forward_into(&u, t, &mut scratch);
            assert_eq!(f.r_mat, scratch.r_mat());
            assert_eq!(f.x_t, scratch.x_t());
            assert_eq!(f.x_tm1, scratch.x_tm1());
            assert_eq!(f.j_t, scratch.j_t());
            assert_eq!(f.t_len, scratch.t_len());
            let mut rt = Vec::new();
            scratch.r_tilde_into(&mut rt);
            assert_eq!(rt, f.r_tilde());
        }
    }

    #[test]
    fn scratch_ensure_resizes_on_dim_change() {
        let mut s = ForwardScratch::new(4);
        s.ensure(9);
        assert_eq!(s.nx(), 9);
        assert_eq!(s.r_mat().len(), 9 * 10);
        let r = toy_reservoir(9, 2, 0.2, 0.1);
        // forward_into itself ensures, so a wrongly-sized scratch is fine
        let mut s2 = ForwardScratch::new(3);
        let u = vec![0.5f32; 10 * 2];
        r.forward_into(&u, 10, &mut s2);
        assert_eq!(s2.nx(), 9);
    }

    #[test]
    fn batched_forward_bitwise_matches_per_call_uniform() {
        let nx = 6;
        let v = 3;
        let t = 19;
        let mut rng = Pcg32::seed(21);
        // distinct mask and (p, q) per lane — the cross-session case
        let configs: Vec<(Mask, f32, f32)> = (0..5)
            .map(|i| {
                (
                    Mask::random(nx, v, &mut rng),
                    0.25 + 0.05 * i as f32,
                    0.30 - 0.03 * i as f32,
                )
            })
            .collect();
        let series: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..t * v).map(|_| rng.normal()).collect())
            .collect();
        let f = Nonlinearity::Tanh;
        let mut batch = BatchScratch::new();
        batch.forward_batch_into(f, 5, |l| BatchLane {
            u: &series[l],
            t,
            mask: &configs[l].0,
            p: configs[l].1,
            q: configs[l].2,
        });
        let mut scratch = ForwardScratch::new(nx);
        for l in 0..5 {
            let res = Reservoir {
                mask: configs[l].0.clone(),
                p: configs[l].1,
                q: configs[l].2,
                f,
            };
            res.forward_into(&series[l], t, &mut scratch);
            // bitwise equality: identical per-lane op sequence
            assert_eq!(scratch.r_mat(), batch.r_mat(l), "lane {l} r_mat");
            let lane = batch.lane(l);
            assert_eq!(scratch.x_t(), lane.x_t, "lane {l} x_t");
            assert_eq!(scratch.x_tm1(), lane.x_tm1, "lane {l} x_tm1");
            assert_eq!(scratch.j_t(), lane.j_t, "lane {l} j_t");
            assert_eq!(scratch.t_len(), lane.t_len);
        }
    }

    #[test]
    fn batched_forward_ragged_lengths_and_scratch_reuse() {
        let nx = 5;
        let v = 2;
        let mut rng = Pcg32::seed(22);
        let mask = Mask::golden(nx, v);
        let f = Nonlinearity::Linear { alpha: 0.9 };
        let ts = [11usize, 1, 7, 0, 23];
        let series: Vec<Vec<f32>> = ts
            .iter()
            .map(|&t| (0..t * v).map(|_| rng.normal()).collect())
            .collect();
        let mut batch = BatchScratch::new();
        // warm at a LARGER lane count first, then shrink — exercises the
        // grow-only capacity path with stale data in the tail lanes
        batch.forward_batch_into(f, 5, |l| BatchLane {
            u: &series[l],
            t: ts[l],
            mask: &mask,
            p: 0.4,
            q: 0.3,
        });
        batch.forward_batch_into(f, 3, |l| BatchLane {
            u: &series[l],
            t: ts[l],
            mask: &mask,
            p: 0.4,
            q: 0.3,
        });
        assert_eq!(batch.lanes(), 3);
        let res = Reservoir { mask: mask.clone(), p: 0.4, q: 0.3, f };
        let mut scratch = ForwardScratch::new(nx);
        for l in 0..3 {
            res.forward_into(&series[l], ts[l], &mut scratch);
            assert_eq!(scratch.r_mat(), batch.r_mat(l), "ragged lane {l}");
            assert_eq!(scratch.x_t(), batch.lane(l).x_t);
            assert_eq!(scratch.x_tm1(), batch.lane(l).x_tm1);
            assert_eq!(scratch.t_len(), batch.t_len(l));
        }
        let mut rt_b = Vec::new();
        let mut rt_s = Vec::new();
        batch.r_tilde_into(0, &mut rt_b);
        res.forward_into(&series[0], ts[0], &mut scratch);
        scratch.r_tilde_into(&mut rt_s);
        assert_eq!(rt_b, rt_s);
    }

    #[test]
    fn mackey_glass_integer_exponent_fast_path() {
        let f2 = Nonlinearity::MackeyGlass { eta: 0.9, p_exp: 2.0 };
        for x in [-2.5f32, -0.7, 0.0, 0.3, 1.9] {
            // the fast path computes |x|² as x·x — exact by definition
            assert_eq!(f2.eval(x), 0.9 * x / (1.0 + x * x), "eval({x})");
            // and stays within rounding of the generic powf form
            let powf_form = 0.9 * x / (1.0 + x.abs().powf(2.0));
            assert!(
                (f2.eval(x) - powf_form).abs() <= 1e-6 * powf_form.abs().max(1.0),
                "eval({x}): {} vs powf form {powf_form}",
                f2.eval(x)
            );
        }
    }

    #[test]
    fn nonlinearity_derivs_match_finite_difference() {
        let fs = [
            Nonlinearity::Linear { alpha: 0.8 },
            Nonlinearity::Tanh,
            // integer fast path and the powf path
            Nonlinearity::MackeyGlass {
                eta: 0.9,
                p_exp: 2.0,
            },
            Nonlinearity::MackeyGlass {
                eta: 0.7,
                p_exp: 2.5,
            },
        ];
        for f in fs {
            for x in [-1.5f32, -0.3, 0.2, 1.1] {
                let h = 1e-3;
                let fd = (f.eval(x + h) - f.eval(x - h)) / (2.0 * h);
                let an = f.deriv(x);
                assert!(
                    (fd - an).abs() < 5e-3,
                    "{f:?} at {x}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn stability_region_bounded_state() {
        // |q| < 1 with small p keeps the linear reservoir bounded
        let r = toy_reservoir(10, 2, 0.1, 0.5);
        let mut rng = Pcg32::seed(2);
        let t = 500;
        let u: Vec<f32> = (0..t * 2).map(|_| rng.normal()).collect();
        let f = r.forward(&u, t);
        assert!(f.x_t.iter().all(|x| x.abs() < 100.0 && x.is_finite()));
    }

    #[test]
    fn mackey_glass_dfr_bounded_and_nonlinear() {
        let d = MackeyGlassDfr {
            mask: Mask::golden(8, 2),
            gamma: 0.5,
            eta: 0.9,
            p_exp: 2.0,
            theta: 0.2,
        };
        let mut rng = Pcg32::seed(3);
        let t = 100;
        let u: Vec<f32> = (0..t * 2).map(|_| rng.normal()).collect();
        let f = d.forward(&u, t);
        assert!(f.x_t.iter().all(|x| x.is_finite() && x.abs() < 10.0));
        // doubling the input must NOT double the state (nonlinearity)
        let u2: Vec<f32> = u.iter().map(|x| 2.0 * x).collect();
        let f2 = d.forward(&u2, t);
        let lin_err: f32 = f
            .x_t
            .iter()
            .zip(&f2.x_t)
            .map(|(a, b)| (2.0 * a - b).abs())
            .sum();
        assert!(lin_err > 1e-3, "Mackey-Glass DFR behaved linearly");
    }
}
