//! Backpropagation through output layer → DPRR layer → reservoir layer
//! (paper §3.2–3.5).
//!
//! Two variants:
//!
//! * [`truncated_grads`] — the paper's contribution (Eqs. 33–36): only
//!   the last time step's contribution to `r` is differentiated, so just
//!   `x(T-1)`, `x(T)` and `j(T)` are stored. This is what runs online.
//! * [`full_bptt_grads`] — the oracle (Eqs. 29–32, plus the feedback-loop
//!   wrap term the paper elides): exact gradients from the recorded
//!   history, used to validate the truncation and quantify what it
//!   discards. Memory O(T·Nx) — the cost Table 7 eliminates.
//!
//! Plus the Table 7 memory accounting ([`memory_words_naive`] /
//! [`memory_words_truncated`], verified against all 12 printed rows).

use super::reservoir::{Forward, ForwardRef, History, Nonlinearity};

/// Output layer parameters during the SGD phase: `y = softmax(W r + b)`.
#[derive(Clone, Debug)]
pub struct OutputLayer {
    /// row-major ny × Nx(Nx+1)
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub ny: usize,
    pub nr: usize,
}

impl OutputLayer {
    /// Zero-initialised, as in the paper's protocol (§4.1).
    pub fn zeros(ny: usize, nx: usize) -> Self {
        let nr = nx * (nx + 1);
        OutputLayer {
            w: vec![0.0; ny * nr],
            b: vec![0.0; ny],
            ny,
            nr,
        }
    }

    /// Class probabilities for a feature vector r (Eq. 13 + softmax).
    pub fn probs(&self, r: &[f32]) -> Vec<f32> {
        let mut z = Vec::new();
        self.probs_into(r, &mut z);
        z
    }

    /// [`probs`](Self::probs) into a caller-owned buffer — the BPTT
    /// inner loop's forward through the output layer without a `Vec`
    /// allocation per step (capacity is reused once sized).
    pub fn probs_into(&self, r: &[f32], z: &mut Vec<f32>) {
        debug_assert_eq!(r.len(), self.nr);
        z.clear();
        z.reserve(self.ny);
        for i in 0..self.ny {
            let row = &self.w[i * self.nr..(i + 1) * self.nr];
            z.push(row.iter().zip(r).map(|(w, r)| w * r).sum::<f32>() + self.b[i]);
        }
        softmax_inplace(z);
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax_inplace(z: &mut [f32]) {
    let m = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in z.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in z.iter_mut() {
        *v /= sum;
    }
}

/// Cross-entropy loss (Eq. 24) for a one-hot target class.
pub fn cross_entropy(y: &[f32], class: usize) -> f32 {
    -(y[class] + 1e-12).ln()
}

/// Gradients produced by one backward pass.
#[derive(Clone, Debug)]
pub struct Grads {
    pub loss: f32,
    pub dp: f32,
    pub dq: f32,
    /// same layout as `OutputLayer::w`
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

/// Reusable workspace of the truncated backward pass: the output `Grads`
/// plus every intermediate the Eqs. 25–26, 33–36 pipeline materializes
/// (softmax/δz, dR, bpv, dx). Sized on first use, then steady-state
/// [`truncated_grads_scratch`] performs **zero heap allocations** —
/// asserted through the streaming trainer in `tests/zero_alloc.rs`.
#[derive(Clone, Debug, Default)]
pub struct GradScratch {
    grads: Grads,
    /// probs y, reused in place as dz = y − e
    y: Vec<f32>,
    /// dL/dR, row-major Nx×(Nx+1)
    dr: Vec<f32>,
    bpv: Vec<f32>,
    dx: Vec<f32>,
}

impl Default for Grads {
    fn default() -> Self {
        Grads {
            loss: 0.0,
            dp: 0.0,
            dq: 0.0,
            dw: Vec::new(),
            db: Vec::new(),
        }
    }
}

impl GradScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// The gradients of the last [`truncated_grads_scratch`] call.
    pub fn grads(&self) -> &Grads {
        &self.grads
    }

    pub fn into_grads(self) -> Grads {
        self.grads
    }
}

/// Truncated backpropagation (Eqs. 25–26, 33–36) from a streaming
/// [`Forward`] result — the online training kernel.
///
/// Mirrors `python/compile/model.py::truncated_grads` exactly (same
/// association order), so the golden tests compare bitwise-close.
pub fn truncated_grads(
    fwd: &Forward,
    class: usize,
    p: f32,
    q: f32,
    f: Nonlinearity,
    out: &OutputLayer,
) -> Grads {
    truncated_grads_ref(fwd.as_view(), class, p, q, f, out)
}

/// [`truncated_grads`] over a borrowed [`ForwardRef`] — the same math
/// without requiring an owned `Forward` snapshot, so engines can
/// backpropagate straight out of a reusable
/// [`ForwardScratch`](super::reservoir::ForwardScratch).
pub fn truncated_grads_ref(
    fwd: ForwardRef<'_>,
    class: usize,
    p: f32,
    q: f32,
    f: Nonlinearity,
    out: &OutputLayer,
) -> Grads {
    let mut sc = GradScratch::new();
    truncated_grads_scratch(fwd, class, p, q, f, out, &mut sc);
    sc.into_grads()
}

/// The truncated backward pass into a caller-owned [`GradScratch`] — the
/// per-sample gradient kernel of the streaming trainer
/// ([`dfr::optim`](super::optim)) and `NativeEngine::train_step`. Bit-
/// identical to [`truncated_grads_ref`] (which wraps it); after the
/// first call has sized the workspace it allocates nothing.
pub fn truncated_grads_scratch(
    fwd: ForwardRef<'_>,
    class: usize,
    // p is part of the formula set's signature for symmetry with
    // full_bptt_grads (Eq. 35 uses f and the stored forward values only)
    _p: f32,
    q: f32,
    f: Nonlinearity,
    out: &OutputLayer,
    sc: &mut GradScratch,
) {
    let nx = fwd.x_t.len();
    let nr = out.nr;
    debug_assert_eq!(fwd.r_mat.len(), nr);

    // forward through the output layer
    out.probs_into(fwd.r_mat, &mut sc.y);
    let loss = cross_entropy(&sc.y, class);

    // Eq. (25): dL/dz = y - e (in place over the probs buffer)
    let dz = &mut sc.y;
    dz[class] -= 1.0;

    // Eq. (26): db, dW = dz ⊗ r, dr = Wᵀ dz
    sc.grads.db.clear();
    sc.grads.db.extend_from_slice(dz);
    sc.grads.dw.resize(out.ny * nr, 0.0);
    for (i, &d) in dz.iter().enumerate() {
        let row = &mut sc.grads.dw[i * nr..(i + 1) * nr];
        for (w, &r) in row.iter_mut().zip(fwd.r_mat) {
            *w = d * r;
        }
    }
    sc.dr.clear();
    sc.dr.resize(nr, 0.0); // laid out as dR[n][j], row-major Nx×(Nx+1)
    for (i, &d) in dz.iter().enumerate() {
        let row = &out.w[i * nr..(i + 1) * nr];
        for (g, &w) in sc.dr.iter_mut().zip(row) {
            *g += w * d;
        }
    }

    // Eq. (33): bpv_n = Σ_j x(T-1)_j dR[n][j] + dR[n][Nx], scaled by the
    // DPRR 1/T normalization (∂R_norm/∂(x(T)·) carries the 1/T factor)
    let w1 = nx + 1;
    let inv_t = 1.0 / fwd.t_len.max(1) as f32;
    sc.bpv.clear();
    sc.bpv.extend((0..nx).map(|n| {
        let row = &sc.dr[n * w1..(n + 1) * w1];
        (row[..nx]
            .iter()
            .zip(fwd.x_tm1)
            .map(|(g, x)| g * x)
            .sum::<f32>()
            + row[nx])
            * inv_t
    }));

    // Eq. (34): dx_n = bpv_n + q·dx_{n+1}, reverse over n
    sc.dx.clear();
    sc.dx.resize(nx, 0.0);
    let mut carry = 0.0f32;
    for n in (0..nx).rev() {
        carry = sc.bpv[n] + q * carry;
        sc.dx[n] = carry;
    }

    // Eq. (35): dp = Σ_n f(j(T)_n + x(T-1)_n) dx_n
    let dp = (0..nx)
        .map(|n| f.eval(fwd.j_t[n] + fwd.x_tm1[n]) * sc.dx[n])
        .sum();

    // Eq. (36): dq = Σ_n x(T)_{n-1} dx_n, with x(T)_0 = x(T-1)_{Nx}
    let dq = (0..nx)
        .map(|n| {
            let prev = if n == 0 {
                fwd.x_tm1[nx - 1]
            } else {
                fwd.x_t[n - 1]
            };
            prev * sc.dx[n]
        })
        .sum();

    sc.grads.loss = loss;
    sc.grads.dp = dp;
    sc.grads.dq = dq;
}

/// Full backpropagation-through-time (Eqs. 29–32) from a recorded
/// [`History`] — the exact-gradient oracle.
///
/// Includes the feedback-loop wrap term (`x(k)_{Nx}` feeds `x(k+1)_1`
/// through q) that the paper's Eq. 30 elides; finite-difference tests
/// confirm exactness.
pub fn full_bptt_grads(
    hist: &History,
    class: usize,
    p: f32,
    q: f32,
    f: Nonlinearity,
    out: &OutputLayer,
) -> Grads {
    let nx = hist.nx;
    let t = hist.t;
    let nr = out.nr;
    let w1 = nx + 1;

    let y = out.probs(&hist.r_mat);
    let loss = cross_entropy(&y, class);
    let mut dz = y;
    dz[class] -= 1.0;

    let db = dz.clone();
    let mut dw = vec![0.0f32; out.ny * nr];
    for (i, &d) in dz.iter().enumerate() {
        let row = &mut dw[i * nr..(i + 1) * nr];
        for (w, &r) in row.iter_mut().zip(&hist.r_mat) {
            *w = d * r;
        }
    }
    let mut dr = vec![0.0f32; nr];
    for (i, &d) in dz.iter().enumerate() {
        let row = &out.w[i * nr..(i + 1) * nr];
        for (g, &w) in dr.iter_mut().zip(row) {
            *g += w * d;
        }
    }

    let mut dp = 0.0f32;
    let mut dq = 0.0f32;
    // dL/dx(k+1): the row for the time step above the current one
    let mut dx_next = vec![0.0f32; nx];
    let mut dx = vec![0.0f32; nx];
    let inv_t = 1.0 / t.max(1) as f32; // DPRR 1/T normalization

    for k in (1..=t).rev() {
        // Eq. (29): bpv over both product roots + the sum feature
        for n in 0..nx {
            let mut b = dr[n * w1 + nx]; // dL/dr_{Nx²+n}
            for j in 0..nx {
                b += hist.x(k - 1, j) * dr[n * w1 + j];
            }
            if k < t {
                for i in 0..nx {
                    b += hist.x(k + 1, i) * dr[i * w1 + n];
                }
            }
            dx[n] = b * inv_t;
        }
        // Eq. (30) + wrap: reverse over n within the step
        for n in (0..nx).rev() {
            let mut v = dx[n];
            if n + 1 < nx {
                v += q * dx[n + 1];
            } else if k < t {
                // wrap: x(k)_{Nx} = x(k+1)_0 feeds x(k+1)_1 through q
                v += q * dx_next[0];
            }
            if k < t {
                // f' evaluated at the argument used to compute x(k+1)_n
                v += p * f.deriv(hist.j(k + 1, n) + hist.x(k, n)) * dx_next[n];
            }
            dx[n] = v;
        }
        // Eqs. (31)-(32): accumulate parameter grads for this k
        for n in 0..nx {
            dp += f.eval(hist.j(k, n) + hist.x(k - 1, n)) * dx[n];
            let prev = if n == 0 {
                hist.x(k - 1, nx - 1)
            } else {
                hist.x(k, n - 1)
            };
            dq += prev * dx[n];
        }
        std::mem::swap(&mut dx_next, &mut dx);
    }

    Grads {
        loss,
        dp,
        dq,
        dw,
        db,
    }
}

// ---------------------------------------------------------------------------
// Table 7 memory accounting
// ---------------------------------------------------------------------------

/// Words stored by naive (non-truncated) backpropagation: the full state
/// history `T·Nx`, the reservoir representation `Nx(Nx+1)`, and the
/// output weights `N_y·Nx(Nx+1) + N_y` (verified against every row of
/// Table 7 with T = T_max).
pub fn memory_words_naive(t: usize, nx: usize, ny: usize) -> usize {
    t * nx + nx * (nx + 1) + ny * nx * (nx + 1) + ny
}

/// Words stored with the §3.5 truncation: only `x(T-1)` and `x(T)`
/// survive of the history.
pub fn memory_words_truncated(nx: usize, ny: usize) -> usize {
    2 * nx + nx * (nx + 1) + ny * nx * (nx + 1) + ny
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfr::mask::Mask;
    use crate::dfr::reservoir::Reservoir;
    use crate::util::prng::Pcg32;

    fn setup(nx: usize, v: usize, t: usize, seed: u64) -> (Reservoir, Vec<f32>, OutputLayer) {
        let mut rng = Pcg32::seed(seed);
        let res = Reservoir {
            mask: Mask::random(nx, v, &mut rng),
            p: 0.25,
            q: 0.2,
            f: Nonlinearity::Linear { alpha: 1.0 },
        };
        let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
        let ny = 3;
        let mut out = OutputLayer::zeros(ny, nx);
        for w in out.w.iter_mut() {
            *w = 0.05 * rng.normal();
        }
        (res, u, out)
    }

    #[test]
    fn softmax_normalises() {
        let mut z = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut z);
        let s: f32 = z.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(z[2] > z[1] && z[1] > z[0]);
    }

    #[test]
    fn full_bptt_matches_finite_difference() {
        let (res, u, out) = setup(4, 2, 6, 50);
        let t = 6;
        let class = 1;
        let hist = res.forward_history(&u, t);
        let g = full_bptt_grads(&hist, class, res.p, res.q, res.f, &out);

        let loss_at = |p: f32, q: f32| {
            let mut r2 = res.clone();
            r2.p = p;
            r2.q = q;
            let fw = r2.forward(&u, t);
            cross_entropy(&out.probs(&fw.r_mat), class)
        };
        let h = 1e-3;
        let fd_p = (loss_at(res.p + h, res.q) - loss_at(res.p - h, res.q)) / (2.0 * h);
        let fd_q = (loss_at(res.p, res.q + h) - loss_at(res.p, res.q - h)) / (2.0 * h);
        assert!(
            (g.dp - fd_p).abs() < 2e-2 * fd_p.abs().max(1.0),
            "dp {} vs fd {}",
            g.dp,
            fd_p
        );
        assert!(
            (g.dq - fd_q).abs() < 2e-2 * fd_q.abs().max(1.0),
            "dq {} vs fd {}",
            g.dq,
            fd_q
        );
    }

    #[test]
    fn full_bptt_fd_nonlinear_f() {
        let mut rng = Pcg32::seed(51);
        let res = Reservoir {
            mask: Mask::random(3, 2, &mut rng),
            p: 0.4,
            q: 0.3,
            f: Nonlinearity::Tanh,
        };
        let t = 5;
        let u: Vec<f32> = (0..t * 2).map(|_| rng.normal()).collect();
        let mut out = OutputLayer::zeros(2, 3);
        for w in out.w.iter_mut() {
            *w = 0.1 * rng.normal();
        }
        let hist = res.forward_history(&u, t);
        let g = full_bptt_grads(&hist, 0, res.p, res.q, res.f, &out);
        let loss_at = |p: f32, q: f32| {
            let mut r2 = res.clone();
            r2.p = p;
            r2.q = q;
            cross_entropy(&out.probs(&r2.forward(&u, t).r_mat), 0)
        };
        let h = 1e-3;
        let fd_p = (loss_at(res.p + h, res.q) - loss_at(res.p - h, res.q)) / (2.0 * h);
        let fd_q = (loss_at(res.p, res.q + h) - loss_at(res.p, res.q - h)) / (2.0 * h);
        assert!((g.dp - fd_p).abs() < 3e-2 * fd_p.abs().max(1.0), "{} vs {}", g.dp, fd_p);
        assert!((g.dq - fd_q).abs() < 3e-2 * fd_q.abs().max(1.0), "{} vs {}", g.dq, fd_q);
    }

    #[test]
    fn truncated_equals_full_on_single_step_series() {
        // with T = 1 the truncation discards nothing
        let (res, u, out) = setup(5, 2, 1, 52);
        let fw = res.forward(&u, 1);
        let hist = res.forward_history(&u, 1);
        let gt = truncated_grads(&fw, 0, res.p, res.q, res.f, &out);
        let gf = full_bptt_grads(&hist, 0, res.p, res.q, res.f, &out);
        assert!((gt.dp - gf.dp).abs() < 1e-5);
        assert!((gt.dq - gf.dq).abs() < 1e-5);
        assert_eq!(gt.loss, gf.loss);
    }

    #[test]
    fn output_grads_match_finite_difference() {
        let (res, u, out) = setup(4, 2, 8, 53);
        let fw = res.forward(&u, 8);
        let g = truncated_grads(&fw, 2, res.p, res.q, res.f, &out);
        // db via fd
        let h = 1e-3;
        for i in 0..out.ny {
            let mut o2 = out.clone();
            o2.b[i] += h;
            let lp = cross_entropy(&o2.probs(&fw.r_mat), 2);
            o2.b[i] -= 2.0 * h;
            let lm = cross_entropy(&o2.probs(&fw.r_mat), 2);
            let fd = (lp - lm) / (2.0 * h);
            assert!((g.db[i] - fd).abs() < 1e-3, "db[{i}] {} vs {}", g.db[i], fd);
        }
        // a few dW entries
        for &idx in &[0usize, 7, 33] {
            let mut o2 = out.clone();
            o2.w[idx] += h;
            let lp = cross_entropy(&o2.probs(&fw.r_mat), 2);
            o2.w[idx] -= 2.0 * h;
            let lm = cross_entropy(&o2.probs(&fw.r_mat), 2);
            let fd = (lp - lm) / (2.0 * h);
            assert!(
                (g.dw[idx] - fd).abs() < 2e-2 * fd.abs().max(1.0),
                "dw[{idx}] {} vs {}",
                g.dw[idx],
                fd
            );
        }
    }

    #[test]
    fn table7_memory_words_exact() {
        // every row of Table 7, with T = T_max and Nx = 30
        let rows: &[(&str, usize, usize, usize, usize)] = &[
            ("arab", 93, 10, 13_030, 10_300),
            ("aus", 136, 95, 93_455, 89_435),
            ("char", 205, 20, 25_700, 19_610),
            ("cmu", 580, 2, 20_192, 2_852),
            ("ecg", 152, 2, 7_352, 2_852),
            ("jpvow", 29, 9, 10_179, 9_369),
            ("kick", 841, 2, 28_022, 2_852),
            ("lib", 45, 15, 16_245, 14_955),
            ("net", 994, 13, 42_853, 13_093),
            ("uwav", 315, 8, 17_828, 8_438),
            ("waf", 198, 2, 8_732, 2_852),
            ("walk", 1918, 2, 60_332, 2_852),
        ];
        for &(name, t, ny, naive, simplified) in rows {
            assert_eq!(memory_words_naive(t, 30, ny), naive, "{name} naive");
            assert_eq!(memory_words_truncated(30, ny), simplified, "{name} simplified");
        }
    }
}
