//! The paper's online training protocol (§4.1) in pure Rust.
//!
//! Phase 1 — reservoir-parameter optimization: stochastic gradient
//! descent with the truncated backpropagation (Eqs. 33–36), 25 epochs,
//! initial `[p, q] = [0.01, 0.01]`, output layer zero-initialised.
//! Learning rate starts at 1 and is multiplied by 0.1 at epochs
//! {5, 10, 15, 20} for the reservoir parameters and {10, 15, 20} for the
//! output-layer parameters.
//!
//! Phase 2 — output-layer finalization: Ridge regression over
//! β ∈ {1e-6, 1e-4, 1e-2, 1}, keeping the β with the lowest loss L.
//!
//! This module is the software reference; the coordinator drives the same
//! protocol through the PJRT `train_step` artifacts.

use super::backprop::{cross_entropy, OutputLayer};
use super::mask::Mask;
use super::optim::{OptimConfig, StreamingBpTrainer};
use super::reservoir::{Forward, ForwardScratch, Nonlinearity, Reservoir};
use crate::data::dataset::{accuracy, Dataset, Sample};
use crate::linalg::ridge::{
    OnlineRidge, OnlineRidgeConfig, RidgeAccumulator, RidgeMethod, RidgeSolution, PAPER_BETAS,
};
use crate::util::prng::Pcg32;

/// Hyper-protocol of §4.1 (all defaults are the paper's).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub nx: usize,
    pub epochs: usize,
    pub p_init: f32,
    pub q_init: f32,
    pub lr_init: f32,
    /// epochs at which the reservoir LR is multiplied by 0.1
    pub res_decay_epochs: Vec<usize>,
    /// epochs at which the output LR is multiplied by 0.1
    pub out_decay_epochs: Vec<usize>,
    pub f: Nonlinearity,
    pub betas: Vec<f32>,
    pub ridge_method: RidgeMethod,
    pub seed: u64,
    /// clamp |dp|,|dq| per step; `None` follows the paper exactly.
    /// (f32 + synthetic data can spike early gradients; the default is a
    /// wide clamp that never binds near convergence.)
    pub grad_clip: Option<f32>,
    /// project (p, q) into the paper's own §4.1 search ranges after each
    /// update (p ∈ [10^-3.75, 10^-0.25], q ∈ [10^-2.75, 10^-0.25]).
    /// Those ranges were "determined to cover the optimal parameters for
    /// all the datasets"; projecting into them keeps the linear reservoir
    /// inside its stability region (p+q < 1), which lr=1 SGD can
    /// otherwise overshoot in f32. Documented deviation (DESIGN.md §10).
    pub project_to_search_range: bool,
    /// worker threads for the ridge phase (feature extraction and the
    /// independent per-β solves). 1 = fully serial. Results are
    /// identical at any thread count: extraction preserves sample order
    /// and the β sweep's selection rule is order-stable. Keep at 1 when
    /// the caller is already parallel (e.g. inside a grid-search sweep)
    /// to avoid oversubscription.
    pub threads: usize,
    /// Serve-phase streaming ridge: exponential forgetting factor
    /// λ ∈ (0, 1) for the incremental output-layer updates. `None`
    /// keeps every sample at full weight. Enabling either this or
    /// [`window`](Self::window) switches the session's Serve phase from
    /// buffer-and-retrain to per-sample O(s²) rank-1 Cholesky updates
    /// (`linalg::OnlineRidge`).
    pub forgetting: Option<f32>,
    /// Serve-phase streaming ridge: sliding window — each labelled
    /// sample past this count downdates the oldest one back out of the
    /// factor. Takes precedence over [`forgetting`](Self::forgetting)
    /// when both are set (the two are mutually exclusive in the
    /// accumulator).
    pub window: Option<usize>,
    /// drift bound for the incremental factor: fully re-factorize from
    /// the exact Gram shadow every K updates (0 = only when a downdate
    /// loses positive definiteness).
    pub refactor_every: usize,
    /// SGD plateau patience: stop the BP phase after this many
    /// consecutive epochs without a mean-loss improvement of more than
    /// [`plateau_min_delta`](Self::plateau_min_delta). `None` (default)
    /// runs the paper's fixed epoch count. Applied identically by the
    /// batch `sgd_phase`, the streaming trainer (`dfr::optim`), and the
    /// coordinator's engine-driven batch train (`Session::train`).
    pub plateau_patience: Option<usize>,
    /// minimum improvement that resets the plateau counter
    pub plateau_min_delta: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            nx: super::NX_PAPER,
            epochs: 25,
            // paper §4.1 uses init 0.01 and lr 1.0; on the synthetic
            // stand-ins that combination diverges in f32 (lr=1 SGD
            // overshoots the p+q<1 stability boundary), so the defaults
            // are init 0.1 / lr 0.1 — same protocol, same decay schedule.
            // Documented deviation (DESIGN.md §10); the paper's exact
            // values remain reachable via the config.
            p_init: 0.1,
            q_init: 0.1,
            lr_init: 0.1,
            res_decay_epochs: vec![5, 10, 15, 20],
            out_decay_epochs: vec![10, 15, 20],
            f: Nonlinearity::Linear { alpha: 1.0 },
            betas: PAPER_BETAS.to_vec(),
            ridge_method: RidgeMethod::Cholesky1d,
            seed: 0xD0_5E1,
            grad_clip: Some(1.0),
            project_to_search_range: true,
            threads: 1,
            forgetting: None,
            window: None,
            refactor_every: 64,
            plateau_patience: None,
            plateau_min_delta: 0.0,
        }
    }
}

impl From<&TrainConfig> for OptimConfig {
    fn from(cfg: &TrainConfig) -> Self {
        OptimConfig {
            epochs: cfg.epochs,
            lr_init: cfg.lr_init,
            res_decay_epochs: cfg.res_decay_epochs.clone(),
            out_decay_epochs: cfg.out_decay_epochs.clone(),
            grad_clip: cfg.grad_clip,
            project_to_search_range: cfg.project_to_search_range,
            plateau_patience: cfg.plateau_patience,
            plateau_min_delta: cfg.plateau_min_delta,
        }
    }
}

/// A trained DFR: reservoir parameters plus the ridge output layer.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub reservoir: Reservoir,
    pub solution: RidgeSolution,
    /// SGD loss per epoch (mean over samples) — the Fig. 7 trace
    pub epoch_losses: Vec<f32>,
    /// wall-clock seconds spent in the SGD phase
    pub bp_seconds: f64,
    /// wall-clock seconds spent in the ridge phase
    pub ridge_seconds: f64,
}

impl TrainedModel {
    pub fn predict(&self, sample: &Sample) -> usize {
        let fwd = self.reservoir.forward(&sample.u, sample.t);
        self.solution.predict_class(&fwd.r_tilde())
    }

    pub fn test_accuracy(&self, ds: &Dataset) -> f64 {
        let preds: Vec<usize> = ds.test.iter().map(|s| self.predict(s)).collect();
        accuracy(&preds, &ds.test)
    }
}

/// Run the full §4.1 protocol on a dataset.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> TrainedModel {
    let mut rng = Pcg32::new(cfg.seed, 0x7EA1);
    let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);
    train_with_mask(ds, cfg, mask, &mut rng)
}

/// Protocol with a caller-fixed mask (the coordinator shares one mask
/// between the Rust reference and the PJRT artifacts).
pub fn train_with_mask(
    ds: &Dataset,
    cfg: &TrainConfig,
    mask: Mask,
    rng: &mut Pcg32,
) -> TrainedModel {
    let sw = crate::util::timer::Stopwatch::start();
    let (reservoir, _out, epoch_losses) = sgd_phase(ds, cfg, mask, rng);
    let bp_seconds = sw.elapsed_secs();

    let sw = crate::util::timer::Stopwatch::start();
    let solution = ridge_phase(ds, &reservoir, cfg);
    let ridge_seconds = sw.elapsed_secs();

    TrainedModel {
        reservoir,
        solution,
        epoch_losses,
        bp_seconds,
        ridge_seconds,
    }
}

/// Phase 1: truncated-BP SGD over (p, q, W, b).
///
/// A thin epoch loop over [`StreamingBpTrainer`] — the per-sample update
/// lives in `dfr::optim`, so the batch Train phase and the Serve-phase
/// streaming adaptation (`coordinator::Session`) run the identical core
/// (shuffle order is the only thing this wrapper adds; the equivalence
/// is pinned bit-for-bit in `tests/streaming_bp_equivalence.rs`).
pub fn sgd_phase(
    ds: &Dataset,
    cfg: &TrainConfig,
    mask: Mask,
    rng: &mut Pcg32,
) -> (Reservoir, OutputLayer, Vec<f32>) {
    let mut trainer =
        StreamingBpTrainer::new(mask, cfg.f, cfg.p_init, cfg.q_init, ds.n_c, OptimConfig::from(cfg));
    let mut order: Vec<usize> = (0..ds.train.len()).collect();
    while !trainer.stopped() {
        trainer.begin_epoch();
        rng.shuffle(&mut order);
        for &i in &order {
            trainer.step(&ds.train[i]);
        }
        trainer.end_epoch();
    }
    trainer.finish()
}

/// Phase 2: ridge regression with β selection by training loss (Eq. 24
/// evaluated with softmax over the ridge scores).
pub fn ridge_phase(ds: &Dataset, reservoir: &Reservoir, cfg: &TrainConfig) -> RidgeSolution {
    // forward features once, reuse across β. Extraction is read-only per
    // sample and order-preserving, so the serial and parallel paths
    // produce identical feature lists; the serial path additionally
    // reuses one ForwardScratch across all samples (no per-sample state
    // allocations).
    let feats: Vec<(Vec<f32>, usize)> = if cfg.threads > 1 {
        crate::util::scoped_pool::scoped_map(&ds.train, cfg.threads, |s| {
            (reservoir.forward(&s.u, s.t).r_tilde(), s.label)
        })
    } else {
        let mut scratch = ForwardScratch::new(reservoir.nx());
        ds.train
            .iter()
            .map(|s| {
                reservoir.forward_into(&s.u, s.t, &mut scratch);
                let mut r = Vec::new();
                scratch.r_tilde_into(&mut r);
                (r, s.label)
            })
            .collect()
    };
    ridge_phase_from_features(&feats, ds.n_c, cfg)
}

/// Ridge phase over precomputed features (shared with the coordinator,
/// whose features come from the PJRT `features` artifact).
///
/// β is selected by loss L on a held-out fifth of the training features
/// (training-loss selection provably picks the overfit β whenever
/// Train < s makes B rank-deficient — every other Table 4 dataset), then
/// the final solve uses all features with the chosen β. Documented
/// deviation from the paper's ambiguous "lowest loss" (DESIGN.md §10).
pub fn ridge_phase_from_features(
    feats: &[(Vec<f32>, usize)],
    n_c: usize,
    cfg: &TrainConfig,
) -> RidgeSolution {
    let s = feats.first().map(|(r, _)| r.len()).unwrap_or(1);
    let n = feats.len();
    // hold out the TAIL fifth: under round-robin labels a contiguous
    // block covers every class once n_held ≥ n_c, whereas a strided
    // split aliases whenever the stride divides the class count (e.g.
    // stride 5 over LIB's 15 classes holds out only classes {0,5,10})
    let n_held = (n / 5).clamp(1.min(n), n);
    let split = n - n_held;

    let held: Vec<&(Vec<f32>, usize)> = feats[split..].iter().collect();
    let mut fit_acc = RidgeAccumulator::new(s, n_c);
    accumulate_blocked(&mut fit_acc, &feats[..split]);
    if fit_acc.count == 0 {
        accumulate_blocked(&mut fit_acc, feats);
    }
    // Selection metric: held-out error count first (argmax prediction is
    // what deployment uses), cross-entropy as tie-break. Betas iterate
    // from LARGEST down so ties resolve toward stronger regularization —
    // with Train ≪ s the small-β f32 factorizations can interpolate the
    // held-out split while being numerically meaningless.
    let mut betas_desc = cfg.betas.clone();
    betas_desc.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let score = |sol: &RidgeSolution| {
        let mut errors = 0u32;
        let mut ce = 0.0f32;
        for (r, label) in &held {
            if sol.predict_class(r) != *label {
                errors += 1;
            }
            let mut z = sol.predict(r);
            super::backprop::softmax_inplace(&mut z);
            ce += cross_entropy(&z, *label);
        }
        errors as f32 * 1e3 + ce.min(999.0)
    };
    // the per-β solves are independent; both paths share one scratch
    // triangle per worker instead of cloning B₀ per β, and apply the
    // same order-stable selection rule
    let (sel, _) = if cfg.threads > 1 {
        fit_acc.solve_best_beta_parallel(&betas_desc, cfg.ridge_method, cfg.threads, &score)
    } else {
        fit_acc.solve_best_beta(&betas_desc, cfg.ridge_method, &score)
    };

    // the deployed layer is the selection-consistent fit-split solution
    sel
}

/// Seed the Serve-phase streaming accumulator from the batch-training
/// features, at the β the batch sweep selected. Returns `None` unless
/// the config enables streaming (`forgetting` or `window`).
///
/// Window mode folds only the **last** `window` training samples, so
/// the maintained system slides cleanly over the subsequent labelled
/// stream (older training samples are gone, not merely unevictable);
/// λ mode folds every sample in arrival order, giving the training set
/// the same geometric down-weighting it would have received live. The
/// first streamed update therefore re-solves against this seeded system
/// rather than the batch hold-out fit — a deliberate, documented
/// handoff discontinuity (DESIGN.md §11).
pub fn online_ridge_from_features(
    feats: &[(Vec<f32>, usize)],
    n_c: usize,
    cfg: &TrainConfig,
    beta: f32,
) -> Option<OnlineRidge> {
    // Some(0) would trip the accumulator's `window ≥ 1` assert on a
    // shard thread; treat it as "no window" like the other clamps below
    let window = cfg.window.filter(|&w| w > 0);
    if cfg.forgetting.is_none() && window.is_none() {
        return None;
    }
    let s = feats.first().map(|(r, _)| r.len())?;
    let lambda = if window.is_some() {
        1.0 // window takes precedence; the accumulator forbids both
    } else {
        // the accumulator asserts λ ∈ (0, 1]; clamp misconfigurations
        // rather than panic a shard thread
        cfg.forgetting.unwrap_or(1.0).clamp(1e-6, 1.0)
    };
    let mut online = OnlineRidge::new(
        s,
        n_c,
        OnlineRidgeConfig {
            // βI seeds the factor, so it must be strictly positive
            beta: beta.max(1e-6),
            lambda,
            window,
            refactor_every: cfg.refactor_every,
        },
    );
    let start = window.map_or(0, |w| feats.len().saturating_sub(w));
    for (r, label) in &feats[start..] {
        online.fold(r, *label);
    }
    online.solve_now();
    Some(online)
}

/// Gram-block size for the streamed accumulation: 32 feature vectors of
/// s = 931 floats stage ~119 KB (fits L2) while the packed triangle is
/// swept once per block instead of once per sample (DESIGN.md §9).
const GRAM_BLOCK: usize = 32;

/// Stream features into the accumulator through the rank-k blocked
/// kernel: stage up to [`GRAM_BLOCK`] r̃ vectors contiguously, then fold
/// them in one pass over the packed triangle. The staging copy is O(B·s)
/// against the O(B·s²/2) Gram MACs it unlocks.
fn accumulate_blocked(acc: &mut RidgeAccumulator, feats: &[(Vec<f32>, usize)]) {
    let mut block: Vec<f32> = Vec::with_capacity(GRAM_BLOCK * acc.s);
    let mut labels: Vec<usize> = Vec::with_capacity(GRAM_BLOCK);
    for (r, label) in feats {
        block.extend_from_slice(r);
        labels.push(*label);
        if labels.len() == GRAM_BLOCK {
            acc.accumulate_block(&block, &labels);
            block.clear();
            labels.clear();
        }
    }
    if !labels.is_empty() {
        acc.accumulate_block(&block, &labels);
    }
}

/// Evaluate reservoir parameters (p, q) by ridge-training an output
/// layer and scoring test accuracy — the inner loop of grid search.
pub fn evaluate_params(
    ds: &Dataset,
    mask: &Mask,
    p: f32,
    q: f32,
    cfg: &TrainConfig,
) -> (f64, RidgeSolution) {
    let res = Reservoir {
        mask: mask.clone(),
        p,
        q,
        f: cfg.f,
    };
    let sol = ridge_phase(ds, &res, cfg);
    let preds: Vec<usize> = ds
        .test
        .iter()
        .map(|s| {
            let fwd = res.forward(&s.u, s.t);
            sol.predict_class(&fwd.r_tilde())
        })
        .collect();
    (accuracy(&preds, &ds.test), sol)
}

/// Forward helper shared by examples/benches: features for one sample.
pub fn sample_features(res: &Reservoir, s: &Sample) -> Forward {
    res.forward(&s.u, s.t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    /// Small synthetic problem solvable in test time.
    fn small_ds() -> Dataset {
        let prof = Profile {
            name: "mini",
            n_v: 3,
            n_c: 3,
            train: 60,
            test: 30,
            t_min: 20,
            t_max: 30,
        };
        synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.12,
                ar: 0.4,
            },
            7,
        )
    }

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            nx: 10,
            epochs: 8,
            res_decay_epochs: vec![3, 5],
            out_decay_epochs: vec![4, 6],
            ..Default::default()
        }
    }

    #[test]
    fn sgd_loss_decreases() {
        let ds = small_ds();
        let cfg = small_cfg();
        let mut rng = Pcg32::seed(1);
        let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);
        let (_, _, losses) = sgd_phase(&ds, &cfg, mask, &mut rng);
        assert!(losses.len() == cfg.epochs);
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "{losses:?}"
        );
    }

    #[test]
    fn full_protocol_beats_chance() {
        let ds = small_ds();
        let model = train(&ds, &small_cfg());
        let acc = model.test_accuracy(&ds);
        assert!(acc > 0.55, "accuracy {acc} not better than chance 0.33");
        assert!(model.bp_seconds > 0.0);
        assert!(PAPER_BETAS.contains(&model.solution.beta));
    }

    #[test]
    fn parameters_move_from_init() {
        let ds = small_ds();
        let model = train(&ds, &small_cfg());
        assert!(
            (model.reservoir.p - 0.01).abs() > 1e-4
                || (model.reservoir.q - 0.01).abs() > 1e-4,
            "p,q never moved: {} {}",
            model.reservoir.p,
            model.reservoir.q
        );
    }

    #[test]
    fn evaluate_params_consistent_with_train() {
        let ds = small_ds();
        let cfg = small_cfg();
        let mut rng = Pcg32::seed(2);
        let mask = Mask::random(cfg.nx, ds.n_v, &mut rng);
        let (acc, _) = evaluate_params(&ds, &mask, 0.2, 0.2, &cfg);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn deterministic_training() {
        let ds = small_ds();
        let a = train(&ds, &small_cfg());
        let b = train(&ds, &small_cfg());
        assert_eq!(a.reservoir.p, b.reservoir.p);
        assert_eq!(a.reservoir.q, b.reservoir.q);
        assert_eq!(a.epoch_losses, b.epoch_losses);
    }

    #[test]
    fn online_seeding_respects_config() {
        use crate::util::prng::Pcg32;
        let mut rng = Pcg32::seed(77);
        let s = 7;
        let n_c = 2;
        let feats: Vec<(Vec<f32>, usize)> = (0..12)
            .map(|i| ((0..s).map(|_| rng.normal()).collect(), i % n_c))
            .collect();

        // streaming disabled → no accumulator
        let cfg = small_cfg();
        assert!(online_ridge_from_features(&feats, n_c, &cfg, 0.1).is_none());

        // window mode folds only the tail `window` samples
        let cfg = TrainConfig {
            window: Some(5),
            ..small_cfg()
        };
        let online = online_ridge_from_features(&feats, n_c, &cfg, 0.1).unwrap();
        assert_eq!(online.updates(), 5);
        assert_eq!(online.window_len(), 5);

        // λ mode folds everything
        let cfg = TrainConfig {
            forgetting: Some(0.95),
            ..small_cfg()
        };
        let online = online_ridge_from_features(&feats, n_c, &cfg, 0.1).unwrap();
        assert_eq!(online.updates(), 12);

        // both set → window wins (no panic from the exclusivity assert)
        let cfg = TrainConfig {
            forgetting: Some(0.9),
            window: Some(4),
            ..small_cfg()
        };
        let online = online_ridge_from_features(&feats, n_c, &cfg, 0.1).unwrap();
        assert_eq!(online.window_len(), 4);

        // empty features → None rather than a panic
        let cfg = TrainConfig {
            window: Some(4),
            ..small_cfg()
        };
        assert!(online_ridge_from_features(&[], n_c, &cfg, 0.1).is_none());
    }
}
