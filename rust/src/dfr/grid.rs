//! Grid search over (p, q, β) — the conventional offline optimization the
//! paper's backpropagation replaces (Table 5, Figs. 7–8).
//!
//! The search space follows §4.1: p ∈ [10^-3.75, 10^-0.25],
//! q ∈ [10^-2.75, 10^-0.25], divided *equidistantly* (in the exponent,
//! since the ranges are specified as powers of ten) into `divs` points
//! per axis; β swept over the same four values as the proposed method.
//! The paper increases `divs` from 1 until grid-search accuracy matches
//! backpropagation — [`search_until_match`] reproduces that protocol.

use super::mask::Mask;
use super::train::{evaluate_params, TrainConfig};
use crate::data::dataset::Dataset;
use crate::util::scoped_pool::scoped_map;

/// §4.1 exponent ranges.
pub const P_EXP_RANGE: (f32, f32) = (-3.75, -0.25);
pub const Q_EXP_RANGE: (f32, f32) = (-2.75, -0.25);

/// Project (p, q) into the §4.1 search ranges — the single clamp shared
/// by the batch SGD phase, the streaming trainer, and the Serve-loop
/// adaptation step (they must project identically or the bit-for-bit
/// streaming/batch equivalence breaks).
#[inline]
pub fn project_to_search_range(p: &mut f32, q: &mut f32) {
    let (plo, phi) = P_EXP_RANGE;
    let (qlo, qhi) = Q_EXP_RANGE;
    *p = p.clamp(10f32.powf(plo), 10f32.powf(phi));
    *q = q.clamp(10f32.powf(qlo), 10f32.powf(qhi));
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub p: f32,
    pub q: f32,
    pub accuracy: f64,
    pub beta: f32,
}

/// Result of a full grid sweep at a given division count.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub divs: usize,
    pub points: Vec<GridPoint>,
    pub best: GridPoint,
    pub seconds: f64,
}

/// Grid coordinates for `divs` divisions of an exponent range:
/// equidistant inclusive of the endpoints (divs = 1 → midpoint).
pub fn grid_coords(range: (f32, f32), divs: usize) -> Vec<f32> {
    let (lo, hi) = range;
    if divs <= 1 {
        return vec![10f32.powf((lo + hi) / 2.0)];
    }
    (0..divs)
        .map(|i| {
            let e = lo + (hi - lo) * i as f32 / (divs - 1) as f32;
            10f32.powf(e)
        })
        .collect()
}

/// Exhaustive sweep at `divs` divisions per axis (divs² ridge trainings),
/// parallelised across `threads` workers.
pub fn search(
    ds: &Dataset,
    mask: &Mask,
    cfg: &TrainConfig,
    divs: usize,
    threads: usize,
) -> GridResult {
    let sw = crate::util::timer::Stopwatch::start();
    let ps = grid_coords(P_EXP_RANGE, divs);
    let qs = grid_coords(Q_EXP_RANGE, divs);
    let mut jobs = Vec::with_capacity(ps.len() * qs.len());
    for &p in &ps {
        for &q in &qs {
            jobs.push((p, q));
        }
    }
    // evaluate_params is read-only over ds/mask/cfg — scoped workers
    // borrow them directly (no Arc, no dataset clone per sweep)
    let points = scoped_map(&jobs, threads, |&(p, q)| {
        let (acc, sol) = evaluate_params(ds, mask, p, q, cfg);
        GridPoint {
            p,
            q,
            accuracy: acc,
            beta: sol.beta,
        }
    });
    let best = points
        .iter()
        .cloned()
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
        .expect("non-empty grid");
    GridResult {
        divs,
        points,
        best,
        seconds: sw.elapsed_secs(),
    }
}

/// The paper's stopping protocol: increase `divs` from 1 until the best
/// grid accuracy reaches `target_acc` (the backpropagation accuracy), or
/// `max_divs` is hit. Returns every sweep, cumulative time included —
/// exactly the data behind Table 5's "gs divs"/"gs time" columns and
/// Fig. 7's trace.
pub fn search_until_match(
    ds: &Dataset,
    mask: &Mask,
    cfg: &TrainConfig,
    target_acc: f64,
    max_divs: usize,
    threads: usize,
) -> Vec<GridResult> {
    let mut sweeps = Vec::new();
    for divs in 1..=max_divs {
        let r = search(ds, mask, cfg, divs, threads);
        let done = r.best.accuracy >= target_acc;
        sweeps.push(r);
        if done {
            break;
        }
    }
    sweeps
}

/// Recursive refinement (the Fig. 8 alternative): subdivide the best cell
/// of a coarse sweep. Returns (level-1 result, level-2 result) so the
/// bench can show the failure mode the paper illustrates (level 2 locks
/// onto a suboptimal basin when the coarse grid misses the global one).
pub fn recursive_refine(
    ds: &Dataset,
    mask: &Mask,
    cfg: &TrainConfig,
    coarse_divs: usize,
    threads: usize,
) -> (GridResult, GridResult) {
    let level1 = search(ds, mask, cfg, coarse_divs, threads);
    // subdivide around the best coarse point: a window one coarse cell
    // wide, searched at the same division count
    let (p_lo, p_hi) = P_EXP_RANGE;
    let (q_lo, q_hi) = Q_EXP_RANGE;
    let cell_p = (p_hi - p_lo) / coarse_divs.max(1) as f32;
    let cell_q = (q_hi - q_lo) / coarse_divs.max(1) as f32;
    let bp = level1.best.p.log10();
    let bq = level1.best.q.log10();
    let sub_p = (bp - cell_p / 2.0, bp + cell_p / 2.0);
    let sub_q = (bq - cell_q / 2.0, bq + cell_q / 2.0);

    let sw = crate::util::timer::Stopwatch::start();
    let ps = grid_coords(sub_p, coarse_divs);
    let qs = grid_coords(sub_q, coarse_divs);
    let mut jobs = Vec::new();
    for &p in &ps {
        for &q in &qs {
            jobs.push((p, q));
        }
    }
    let points = scoped_map(&jobs, threads, |&(p, q)| {
        let (acc, sol) = evaluate_params(ds, mask, p, q, cfg);
        GridPoint {
            p,
            q,
            accuracy: acc,
            beta: sol.beta,
        }
    });
    let best = points
        .iter()
        .cloned()
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
        .unwrap();
    let level2 = GridResult {
        divs: coarse_divs,
        points,
        best,
        seconds: sw.elapsed_secs(),
    };
    (level1, level2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::Profile;
    use crate::data::synth;
    use crate::util::prng::Pcg32;

    fn tiny() -> (Dataset, Mask, TrainConfig) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 24,
            test: 16,
            t_min: 15,
            t_max: 20,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.15,
                ar: 0.3,
            },
            11,
        );
        let cfg = TrainConfig {
            nx: 8,
            betas: vec![1e-4, 1e-2],
            ..Default::default()
        };
        let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(3));
        (ds, mask, cfg)
    }

    #[test]
    fn coords_midpoint_and_endpoints() {
        let c1 = grid_coords((-2.0, -1.0), 1);
        assert_eq!(c1.len(), 1);
        assert!((c1[0] - 10f32.powf(-1.5)).abs() < 1e-6);
        let c3 = grid_coords((-2.0, -1.0), 3);
        assert_eq!(c3.len(), 3);
        assert!((c3[0] - 0.01).abs() < 1e-6);
        assert!((c3[2] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn search_evaluates_divs_squared_points() {
        let (ds, mask, cfg) = tiny();
        let r = search(&ds, &mask, &cfg, 3, 4);
        assert_eq!(r.points.len(), 9);
        assert!(r.best.accuracy >= r.points[0].accuracy);
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn until_match_stops_when_target_met() {
        let (ds, mask, cfg) = tiny();
        // target 0 accuracy → stops after the very first sweep
        let sweeps = search_until_match(&ds, &mask, &cfg, 0.0, 5, 2);
        assert_eq!(sweeps.len(), 1);
        assert_eq!(sweeps[0].divs, 1);
    }

    #[test]
    fn until_match_caps_at_max_divs() {
        let (ds, mask, cfg) = tiny();
        let sweeps = search_until_match(&ds, &mask, &cfg, 1.01, 3, 2);
        assert_eq!(sweeps.len(), 3);
    }

    #[test]
    fn recursive_refine_produces_two_levels() {
        let (ds, mask, cfg) = tiny();
        let (l1, l2) = recursive_refine(&ds, &mask, &cfg, 2, 2);
        assert_eq!(l1.points.len(), 4);
        assert_eq!(l2.points.len(), 4);
    }
}
