//! Pure-Rust DFR stack — the software reference implementation.
//!
//! Mirrors the L2 JAX model bit-for-bit in structure (same equations,
//! same truncation) and serves three roles:
//!
//! 1. the **SW-only baseline** the paper compares its FPGA against
//!    (Table 9) — timed through `fpga::sw_model` and the benches;
//! 2. the **grid-search baseline** (Table 5, Figs. 7–8), which would be
//!    prohibitively slow through per-sample PJRT round-trips;
//! 3. the **golden cross-check** against `python/tests/make_golden.py`
//!    (the same closed-form inputs must give the same forward/backward
//!    numbers in both languages).
//!
//! Modules: [`mask`] (input masking, Fig. 2), [`reservoir`] (modular DFR
//! Eq. 14 and the conventional Mackey–Glass digital DFR Eqs. 8–9),
//! [`dprr`] (Eqs. 27–28), [`backprop`] (full BPTT Eqs. 29–32 and the
//! truncated Eqs. 33–36 + Table 7 memory accounting), [`optim`] (the
//! per-sample truncated-BPTT SGD trainer the batch and streaming paths
//! share), [`train`] (the paper's §4.1 SGD protocol + ridge
//! finalization), [`grid`] (the 3-D grid-search baseline).

pub mod backprop;
pub mod dprr;
pub mod grid;
pub mod mask;
pub mod optim;
pub mod reservoir;
pub mod train;

pub use reservoir::{BatchLane, BatchScratch, ForwardScratch, Nonlinearity, Reservoir};

/// Reservoir size used throughout the paper's evaluation (§4: "The
/// reservoir size Nx was set to 30").
pub const NX_PAPER: usize = 30;
