//! Input masking (paper Fig. 2 / §2.2).
//!
//! The digital DFR multiplies each input sample by a mask that varies per
//! virtual node: `j(k) = M u(k)` with `M ∈ R^{Nx×V}` whose entries are
//! drawn from ±1 (pseudo-random bit sequence, the paper's standard
//! choice). The mask is fixed at deployment and shared between training
//! and inference — it is part of the artifact inputs on the JAX path and
//! of [`super::Reservoir`] on the Rust path.

use crate::util::prng::Pcg32;

/// A fixed ±1 mask matrix, row-major `Nx×V`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub nx: usize,
    pub v: usize,
    pub m: Vec<f32>,
}

impl Mask {
    /// Pseudo-random binary mask (the paper's default, after [3]).
    pub fn random(nx: usize, v: usize, rng: &mut Pcg32) -> Self {
        let m = (0..nx * v).map(|_| rng.sign()).collect();
        Mask { nx, v, m }
    }

    /// Deterministic parity mask — mirrors
    /// `python/tests/make_golden.py::inputs` so cross-language golden
    /// tests regenerate identical inputs.
    pub fn golden(nx: usize, v: usize) -> Self {
        let mut m = Vec::with_capacity(nx * v);
        for n in 0..nx {
            for vv in 0..v {
                m.push(if (7 * n + 3 * vv) % 2 == 0 { 1.0 } else { -1.0 });
            }
        }
        Mask { nx, v, m }
    }

    /// The closed-form input series paired with [`golden`](Self::golden)
    /// — mirrors `python/tests/make_golden.py::inputs` (computed in f64
    /// then cast, exactly as numpy does), so every cross-language golden
    /// suite regenerates identical data from ONE definition.
    pub fn golden_inputs(t: usize, v: usize) -> Vec<f32> {
        let mut u = Vec::with_capacity(t * v);
        for k in 1..=t {
            for vv in 1..=v {
                let x = (0.1f64 * k as f64 * vv as f64).sin() + 0.05 * (0.3f64 * k as f64).cos();
                u.push(x as f32);
            }
        }
        u
    }

    /// Apply the mask: `j = M u` for one time step (`u` has V entries,
    /// result has Nx entries).
    pub fn apply(&self, u_t: &[f32], j_out: &mut [f32]) {
        debug_assert_eq!(u_t.len(), self.v);
        debug_assert_eq!(j_out.len(), self.nx);
        for (n, j) in j_out.iter_mut().enumerate() {
            let row = &self.m[n * self.v..(n + 1) * self.v];
            *j = row.iter().zip(u_t).map(|(m, u)| m * u).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_mask_is_pm_one() {
        let mut rng = Pcg32::seed(7);
        let m = Mask::random(30, 12, &mut rng);
        assert_eq!(m.m.len(), 360);
        assert!(m.m.iter().all(|&x| x == 1.0 || x == -1.0));
        // roughly balanced
        let pos = m.m.iter().filter(|&&x| x > 0.0).count();
        assert!((120..=240).contains(&pos));
    }

    #[test]
    fn golden_mask_matches_python_formula() {
        let m = Mask::golden(3, 4);
        // (7n+3v) % 2 == 0 → +1
        let expect = [
            1.0, -1.0, 1.0, -1.0, // n=0: 0,3,6,9
            -1.0, 1.0, -1.0, 1.0, // n=1: 7,10,13,16
            1.0, -1.0, 1.0, -1.0, // n=2: 14,17,20,23
        ];
        assert_eq!(m.m, expect);
    }

    #[test]
    fn apply_is_matvec() {
        let m = Mask {
            nx: 2,
            v: 3,
            m: vec![1.0, -1.0, 1.0, -1.0, 1.0, 1.0],
        };
        let mut j = [0.0f32; 2];
        m.apply(&[1.0, 2.0, 3.0], &mut j);
        assert_eq!(j, [2.0, 4.0]);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Mask::random(8, 2, &mut Pcg32::seed(5));
        let b = Mask::random(8, 2, &mut Pcg32::seed(5));
        assert_eq!(a, b);
    }
}
