//! Streaming reservoir-parameter optimization — the §4.1 truncated-BPTT
//! SGD core, extracted out of the batch `sgd_phase` into a per-sample
//! trainer that any caller can drive one labelled sample at a time.
//!
//! [`StreamingBpTrainer`] owns the reservoir, the SGD output layer, the
//! learning-rate schedule and all per-sample workspaces
//! ([`ForwardScratch`] + [`GradScratch`]), so its steady-state
//! [`step`](StreamingBpTrainer::step) performs **zero heap allocations**
//! (asserted by the counting allocator in `tests/zero_alloc.rs`).
//!
//! Two drivers exist:
//!
//! * `dfr::train::sgd_phase` — the batch Train-phase protocol is now a
//!   thin epoch loop over this trainer (shuffle → [`begin_epoch`] →
//!   [`step`]× → [`end_epoch`]), so the streaming and batch trajectories
//!   are bit-for-bit identical **by construction**
//!   (`tests/streaming_bp_equivalence.rs` pins this);
//! * `coordinator::Session` — labelled Serve samples drive the same
//!   per-sample update through `Engine::train_step` (which shares the
//!   [`GradScratch`] kernel), realizing the paper's *online* training
//!   loop without leaving the serve path (DESIGN.md §13).
//!
//! [`begin_epoch`]: StreamingBpTrainer::begin_epoch
//! [`end_epoch`]: StreamingBpTrainer::end_epoch

use super::backprop::{truncated_grads_scratch, GradScratch, OutputLayer};
use super::mask::Mask;
use super::reservoir::{ForwardScratch, Nonlinearity, Reservoir};
use crate::data::dataset::Sample;

/// Optimizer knobs of the truncated-BPTT SGD core. Derived from
/// `TrainConfig` via `From<&TrainConfig>` (same defaults, same decay
/// schedule); the plateau fields add optional early stopping that both
/// the batch and streaming drivers apply identically.
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// epoch budget (the trainer itself never loops — drivers consult
    /// [`StreamingBpTrainer::stopped`] against this)
    pub epochs: usize,
    pub lr_init: f32,
    /// epochs at which the reservoir LR is multiplied by 0.1
    pub res_decay_epochs: Vec<usize>,
    /// epochs at which the output LR is multiplied by 0.1
    pub out_decay_epochs: Vec<usize>,
    /// clamp |dp|,|dq| per step (`None` follows the paper exactly)
    pub grad_clip: Option<f32>,
    /// project (p, q) into the §4.1 search ranges after each update
    pub project_to_search_range: bool,
    /// plateau patience: stop after this many consecutive epochs whose
    /// mean loss failed to improve the best by more than
    /// [`plateau_min_delta`](Self::plateau_min_delta). `None` (the
    /// default) runs the full epoch budget — the paper's fixed 25.
    pub plateau_patience: Option<usize>,
    /// minimum mean-loss improvement that resets the patience counter
    pub plateau_min_delta: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            epochs: 25,
            lr_init: 0.1,
            res_decay_epochs: vec![5, 10, 15, 20],
            out_decay_epochs: vec![10, 15, 20],
            grad_clip: Some(1.0),
            project_to_search_range: true,
            plateau_patience: None,
            plateau_min_delta: 0.0,
        }
    }
}

/// Per-sample truncated-BPTT SGD over (p, q, W, b) — see module docs.
pub struct StreamingBpTrainer {
    res: Reservoir,
    out: OutputLayer,
    cfg: OptimConfig,
    lr_res: f32,
    lr_out: f32,
    /// epochs begun so far (the decay schedule's index)
    epoch: usize,
    fwd: ForwardScratch,
    gsc: GradScratch,
    loss_sum: f64,
    seen: usize,
    epoch_losses: Vec<f32>,
    best_loss: f32,
    since_best: usize,
    plateaued: bool,
    steps: u64,
}

impl StreamingBpTrainer {
    /// Fresh trainer at the protocol's initial state: `(p, q)` at the
    /// init values, output layer zero-initialised, LR at `lr_init`.
    pub fn new(
        mask: Mask,
        f: Nonlinearity,
        p_init: f32,
        q_init: f32,
        n_c: usize,
        cfg: OptimConfig,
    ) -> Self {
        let nx = mask.nx;
        StreamingBpTrainer {
            res: Reservoir {
                mask,
                p: p_init,
                q: q_init,
                f,
            },
            out: OutputLayer::zeros(n_c, nx),
            lr_res: cfg.lr_init,
            lr_out: cfg.lr_init,
            cfg,
            epoch: 0,
            fwd: ForwardScratch::new(nx),
            gsc: GradScratch::new(),
            loss_sum: 0.0,
            seen: 0,
            epoch_losses: Vec::new(),
            best_loss: f32::INFINITY,
            since_best: 0,
            plateaued: false,
            steps: 0,
        }
    }

    pub fn reservoir(&self) -> &Reservoir {
        &self.res
    }

    pub fn output(&self) -> &OutputLayer {
        &self.out
    }

    /// Current (p, q).
    pub fn params(&self) -> (f32, f32) {
        (self.res.p, self.res.q)
    }

    /// Mean SGD loss per completed epoch — the Fig. 7 trace.
    pub fn epoch_losses(&self) -> &[f32] {
        &self.epoch_losses
    }

    /// Total per-sample steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Whether the epoch budget is exhausted or the plateau patience
    /// tripped — drivers stop their epoch loop here.
    pub fn stopped(&self) -> bool {
        self.plateaued || self.epoch >= self.cfg.epochs
    }

    /// Start the next epoch: apply the LR decay schedule for the epoch
    /// index about to run and reset the epoch-loss accumulator.
    pub fn begin_epoch(&mut self) {
        if self.cfg.res_decay_epochs.contains(&self.epoch) {
            self.lr_res *= 0.1;
        }
        if self.cfg.out_decay_epochs.contains(&self.epoch) {
            self.lr_out *= 0.1;
        }
        self.loss_sum = 0.0;
        self.seen = 0;
    }

    /// One per-sample update: forward through the reservoir, truncated
    /// backward (Eqs. 33–36), clipped SGD step on (p, q), SGD step on
    /// (W, b), optional projection into the search ranges. Returns the
    /// sample loss. Zero heap allocations once the workspaces are sized.
    pub fn step(&mut self, s: &Sample) -> f32 {
        self.res.forward_into(&s.u, s.t, &mut self.fwd);
        truncated_grads_scratch(
            self.fwd.as_forward_ref(),
            s.label,
            self.res.p,
            self.res.q,
            self.res.f,
            &self.out,
            &mut self.gsc,
        );
        let g = self.gsc.grads();
        self.loss_sum += f64::from(g.loss);
        self.seen += 1;
        self.steps += 1;
        let (mut dp, mut dq) = (g.dp, g.dq);
        if let Some(c) = self.cfg.grad_clip {
            dp = dp.clamp(-c, c);
            dq = dq.clamp(-c, c);
        }
        if dp.is_finite() && dq.is_finite() {
            self.res.p -= self.lr_res * dp;
            self.res.q -= self.lr_res * dq;
        }
        if self.cfg.project_to_search_range {
            super::grid::project_to_search_range(&mut self.res.p, &mut self.res.q);
        }
        if g.loss.is_finite() {
            for (w, d) in self.out.w.iter_mut().zip(&g.dw) {
                *w -= self.lr_out * d;
            }
            for (b, d) in self.out.b.iter_mut().zip(&g.db) {
                *b -= self.lr_out * d;
            }
        }
        g.loss
    }

    /// Close the epoch: record its mean loss, advance the schedule, and
    /// run the plateau check. Returns the mean loss.
    pub fn end_epoch(&mut self) -> f32 {
        let mean = (self.loss_sum / self.seen.max(1) as f64) as f32;
        self.epoch_losses.push(mean);
        self.epoch += 1;
        if let Some(patience) = self.cfg.plateau_patience {
            if mean < self.best_loss - self.cfg.plateau_min_delta {
                self.best_loss = mean;
                self.since_best = 0;
            } else {
                self.since_best += 1;
                if self.since_best >= patience {
                    self.plateaued = true;
                }
            }
        }
        mean
    }

    /// Tear down into the trained pieces (reservoir, output layer, the
    /// per-epoch loss trace) — what `sgd_phase` returns.
    pub fn finish(self) -> (Reservoir, OutputLayer, Vec<f32>) {
        (self.res, self.out, self.epoch_losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn sample(t: usize, v: usize, rng: &mut Pcg32, label: usize) -> Sample {
        Sample {
            u: (0..t * v).map(|_| rng.normal()).collect(),
            t,
            label,
        }
    }

    fn trainer(cfg: OptimConfig) -> StreamingBpTrainer {
        let mut rng = Pcg32::seed(0x0971);
        let mask = Mask::random(6, 2, &mut rng);
        StreamingBpTrainer::new(mask, Nonlinearity::Linear { alpha: 1.0 }, 0.1, 0.1, 3, cfg)
    }

    #[test]
    fn step_moves_parameters_and_reports_loss() {
        let mut tr = trainer(OptimConfig::default());
        let mut rng = Pcg32::seed(1);
        let s = sample(12, 2, &mut rng, 1);
        tr.begin_epoch();
        let l1 = tr.step(&s);
        assert!(l1.is_finite() && l1 > 0.0);
        assert!(tr.output().w.iter().any(|&w| w != 0.0));
        let before = tr.params();
        tr.step(&s);
        assert_ne!(tr.params(), before, "second step must move (p, q)");
        assert_eq!(tr.steps(), 2);
    }

    #[test]
    fn lr_decay_schedule_applies_at_epoch_starts() {
        let cfg = OptimConfig {
            epochs: 4,
            res_decay_epochs: vec![1],
            out_decay_epochs: vec![2],
            ..Default::default()
        };
        let mut tr = trainer(cfg);
        tr.begin_epoch(); // epoch 0: no decay
        assert_eq!(tr.lr_res, 0.1);
        tr.end_epoch();
        tr.begin_epoch(); // epoch 1: reservoir decays
        assert!((tr.lr_res - 0.01).abs() < 1e-6);
        assert_eq!(tr.lr_out, 0.1);
        tr.end_epoch();
        tr.begin_epoch(); // epoch 2: output decays
        assert!((tr.lr_out - 0.01).abs() < 1e-6);
        tr.end_epoch();
    }

    #[test]
    fn plateau_patience_stops_early() {
        // min_delta so large no epoch ever counts as an improvement
        // after the first: the trainer must stop after exactly
        // 1 + patience epochs
        let cfg = OptimConfig {
            epochs: 50,
            plateau_patience: Some(3),
            plateau_min_delta: 1e9,
            ..Default::default()
        };
        let mut tr = trainer(cfg);
        let mut rng = Pcg32::seed(2);
        let s = sample(10, 2, &mut rng, 0);
        let mut ran = 0;
        while !tr.stopped() {
            tr.begin_epoch();
            tr.step(&s);
            tr.end_epoch();
            ran += 1;
            assert!(ran <= 50, "never stopped");
        }
        assert_eq!(ran, 4, "1 improving epoch + 3 patience");
        assert_eq!(tr.epoch_losses().len(), 4);
    }

    #[test]
    fn epoch_budget_stops_without_patience() {
        let cfg = OptimConfig {
            epochs: 2,
            ..Default::default()
        };
        let mut tr = trainer(cfg);
        let mut rng = Pcg32::seed(3);
        let s = sample(8, 2, &mut rng, 2);
        while !tr.stopped() {
            tr.begin_epoch();
            tr.step(&s);
            tr.end_epoch();
        }
        assert_eq!(tr.epoch_losses().len(), 2);
    }
}
