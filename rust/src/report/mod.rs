//! Report generators: render measured results and the paper's reference
//! tables as markdown/CSV into `results/`.

use crate::fpga::design::{sw_report, DesignConfig, DesignReport, SystemModel};
use crate::fpga::schedule::ShapeParams;
use crate::util::bench::markdown_table;

/// Table 12: qualitative comparison with existing FPGA DFR systems.
pub fn table12_markdown() -> String {
    let rows: Vec<Vec<String>> = crate::baselines::published::TABLE12
        .iter()
        .map(|(m, tr, imp, v, c)| {
            vec![
                m.to_string(),
                tr.to_string(),
                imp.to_string(),
                v.to_string(),
                c.to_string(),
            ]
        })
        .collect();
    markdown_table(
        &["method", "training/inference on HW", "implementation", "#V", "#C"],
        &rows,
    )
}

/// Render a Table 9-style HW/SW comparison for a workload.
pub fn table9_markdown(
    shape: ShapeParams,
    n_train: u64,
    epochs: u64,
    n_betas: u64,
    n_test: u64,
) -> String {
    let hw = SystemModel::new(shape, DesignConfig::Standard).report(n_train, epochs, n_betas, n_test);
    let sw = sw_report(&shape, n_train, epochs, n_betas, n_test);
    let rows = vec![
        row3("LUT", "-", &format!("{} ({:.1}%)", hw.resources.lut, 100.0 * hw.resources.utilization(&hw.budget).lut)),
        row3("LUTRAM", "-", &format!("{} ({:.1}%)", hw.resources.lutram, 100.0 * hw.resources.utilization(&hw.budget).lutram)),
        row3("FF", "-", &format!("{} ({:.1}%)", hw.resources.ff, 100.0 * hw.resources.utilization(&hw.budget).ff)),
        row3("BRAM", "-", &format!("{:.1} ({:.1}%)", hw.resources.bram36, 100.0 * hw.resources.utilization(&hw.budget).bram36)),
        row3("DSP", "-", &format!("{} ({:.1}%)", hw.resources.dsp, 100.0 * hw.resources.utilization(&hw.budget).dsp)),
        row3("Clock frequency", "667 MHz", "100 MHz"),
        row3("Power", &format!("{:.3} W", sw.power_w), &format!("{:.3} W", hw.power_w)),
        row3("Calculation time", &format!("{:.2} s", sw.calc_s()), &format!("{:.2} s", hw.calc_s())),
        row3("Training time", &format!("{:.2} s", sw.train_s), &format!("{:.2} s", hw.train_s)),
        row3("Inference time", &format!("{:.2} s", sw.infer_s), &format!("{:.2} s", hw.infer_s)),
        row3("Energy", &format!("{:.2} J", sw.energy_j), &format!("{:.2} J", hw.energy_j)),
        row3(
            "ratio SW/HW (time)",
            "-",
            &format!("{:.1}x", sw.calc_s() / hw.calc_s()),
        ),
        row3(
            "ratio SW/HW (energy)",
            "-",
            &format!("{:.1}x", sw.energy_j / hw.energy_j),
        ),
    ];
    markdown_table(&["", "SW only", "HW only"], &rows)
}

/// Render the three Table 11 configuration rows.
pub fn table11_markdown(
    shape: ShapeParams,
    n_train: u64,
    epochs: u64,
    n_betas: u64,
    n_test: u64,
) -> String {
    let reps: Vec<DesignReport> = [
        DesignConfig::NonPipelined,
        DesignConfig::Standard,
        DesignConfig::Inlined,
    ]
    .into_iter()
    .map(|c| SystemModel::new(shape, c).report(n_train, epochs, n_betas, n_test))
    .collect();
    let rows: Vec<Vec<String>> = reps
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                format!("{} ({:.1}%)", r.resources.lut, 100.0 * r.resources.utilization(&r.budget).lut),
                format!("{}", r.resources.ff),
                format!("{:.1}", r.resources.bram36),
                format!("{}", r.resources.dsp),
                format!("{:.3} W", r.power_w),
                format!("{:.2} s", r.calc_s()),
                format!("{:.2} J", r.energy_j),
            ]
        })
        .collect();
    markdown_table(
        &["config", "LUT", "FF", "BRAM", "DSP", "power", "calc time", "energy"],
        &rows,
    )
}

fn row3(a: &str, b: &str, c: &str) -> Vec<String> {
    vec![a.to_string(), b.to_string(), c.to_string()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        let t12 = table12_markdown();
        assert!(t12.contains("prop."));
        let shape = ShapeParams::new(30, 12, 9, 29);
        let t9 = table9_markdown(shape, 270, 25, 4, 370);
        assert!(t9.contains("ratio SW/HW"));
        let t11 = table11_markdown(shape, 270, 25, 4, 370);
        assert!(t11.contains("non-pipelined"));
        assert_eq!(t11.lines().count(), 2 + 3);
    }
}
