//! [`QuantEngine`] — the quantized datapath behind the sharded
//! coordinator.
//!
//! Implements [`Engine`] so quantized serving is a config switch, not a
//! code path: `features`/`infer` run the bit-accurate Q-format forward
//! pass + integer MAC output layer, while `train_step` delegates to the
//! f32 [`NativeEngine`] — mirroring the deployment split where the
//! truncated-BP parameter search runs on the PS (ARM) side in float and
//! the serving datapath is the PL's fixed-point pipeline. The ridge
//! phase therefore trains on **quantized** features (what the hardware
//! will actually produce at inference time), which is the
//! quantization-aware choice.
//!
//! Steady-state `features_into`/`infer_into` perform **zero heap
//! allocations** (per-replica workspace + in-place mask refresh +
//! grow-only quantized-weight cache) — asserted by the counting
//! allocator in `tests/zero_alloc.rs`.

use std::cell::RefCell;

use anyhow::Result;

use crate::coordinator::engine::{Engine, NativeEngine};
use crate::data::dataset::Sample;
use crate::dfr::backprop::softmax_inplace;
use crate::dfr::mask::Mask;
use crate::dfr::reservoir::Nonlinearity;
use crate::runtime::executor::TrainState;

use super::reservoir::{QuantForwardScratch, QuantReservoir};
use super::QuantConfig;

/// The fixed-point compute engine (see module docs).
pub struct QuantEngine {
    pub nx: usize,
    pub n_c: usize,
    pub f: Nonlinearity,
    pub cfg: QuantConfig,
    /// f32 reference backing `train_step` (PS-side SGD)
    native: NativeEngine,
    /// per-replica workspace; never contended — each shard exclusively
    /// owns its engine replica (`Engine: Send`, not `Sync`)
    scratch: RefCell<QuantScratch>,
}

struct QuantScratch {
    res: QuantReservoir,
    fwd: QuantForwardScratch,
    /// quantized output-layer cache, refreshed in place per infer
    qw: Vec<i32>,
}

impl QuantEngine {
    pub fn new(nx: usize, n_c: usize) -> Self {
        Self::with_config(
            nx,
            n_c,
            Nonlinearity::Linear { alpha: 1.0 },
            QuantConfig::default(),
        )
    }

    pub fn with_config(nx: usize, n_c: usize, f: Nonlinearity, cfg: QuantConfig) -> Self {
        let placeholder = Mask {
            nx,
            v: 0,
            m: Vec::new(),
        };
        // a segment must span at least one raw unit: narrow words (e.g.
        // a parsed --qformat q2.3) clamp the LUT size instead of
        // tripping PwlLut's assert
        let lut_segments = cfg.lut_log2_segments.min(cfg.arith.fmt.bits).max(1);
        QuantEngine {
            nx,
            n_c,
            f,
            cfg,
            native: NativeEngine::with_nonlinearity(nx, n_c, f),
            scratch: RefCell::new(QuantScratch {
                res: QuantReservoir::new(placeholder, f, cfg.arith, lut_segments),
                fwd: QuantForwardScratch::new(nx, 0),
                qw: Vec::new(),
            }),
        }
    }

    /// Saturation count of the most recent forward pass — 0 means the
    /// error budget's no-overflow assumption held for that sample.
    pub fn last_saturations(&self) -> u64 {
        self.scratch.borrow().fwd.saturations()
    }

    /// Run the quantized forward into the replica workspace (in-place
    /// mask refresh, reallocation only on shape change — zero
    /// steady-state allocations).
    fn forward_scratch(&self, s: &Sample, mask: &Mask, p: f32, q: f32, sc: &mut QuantScratch) {
        if sc.res.mask.nx != mask.nx || sc.res.mask.v != mask.v {
            sc.res.mask = mask.clone();
        } else if sc.res.mask.m != mask.m {
            sc.res.mask.m.copy_from_slice(&mask.m);
        }
        sc.res.set_params(p, q);
        sc.res.forward_into(&s.u, s.t, &mut sc.fwd);
    }
}

impl Engine for QuantEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        // PS-side f32 SGD (see module docs) — the quantized datapath
        // only serves features/inference
        self.native.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.features_into(s, mask, p, q, &mut out)?;
        Ok(out)
    }

    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        sc.fwd.r_tilde_into(self.cfg.arith, out);
        Ok(())
    }

    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w_tilde: &[f32]) -> Result<Vec<f32>> {
        let mut z = Vec::new();
        self.infer_into(s, mask, p, q, w_tilde, &mut z)?;
        Ok(z)
    }

    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        let arith = self.cfg.arith;
        let frac = arith.fmt.frac;
        // requantize the served layer into the grow-only cache — O(ny·s)
        // compares-and-stores, cheaper than the forward pass it follows
        if sc.qw.len() != w_tilde.len() {
            sc.qw.resize(w_tilde.len(), 0);
        }
        for (qw, &w) in sc.qw.iter_mut().zip(w_tilde) {
            *qw = arith.quantize(w);
        }
        // integer MAC per class: products at scale 2²ᶠ accumulated in
        // i64 (exact), one dequantizing rescale per output score
        let sc_ref = &*sc;
        let n_r = sc_ref.fwd.r_mat_raw().len();
        let sdim = n_r + 1;
        let ny = w_tilde.len() / sdim;
        scores.clear();
        scores.reserve(ny);
        let inv_scale = (-2.0 * f64::from(frac)).exp2();
        for i in 0..ny {
            let row = &sc_ref.qw[i * sdim..(i + 1) * sdim];
            let mut acc: i64 = 0;
            for (&w, &r) in row[..n_r].iter().zip(sc_ref.fwd.r_mat_raw()) {
                acc += i64::from(w) * i64::from(r);
            }
            // the tilde-1 feature: constant 1.0 is exactly 1 << frac
            acc += i64::from(row[n_r]) << frac;
            scores.push((acc as f64 * inv_scale) as f32);
        }
        softmax_inplace(scores);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "quant"
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        // configuration-only state: replicas rebuild their own LUT and
        // workspace
        Some(Box::new(QuantEngine::with_config(
            self.nx, self.n_c, self.f, self.cfg,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::{QArith, QFormat};
    use crate::util::prng::Pcg32;

    fn sample(t: usize, v: usize, seed: u64, label: usize) -> Sample {
        let mut rng = Pcg32::seed(seed);
        Sample {
            u: (0..t * v).map(|_| 0.5 * rng.normal()).collect(),
            t,
            label,
        }
    }

    #[test]
    fn infer_is_probability() {
        let eng = QuantEngine::new(6, 2);
        let mask = Mask::golden(6, 2);
        let s = sample(9, 2, 2, 0);
        let sdim = 6 * 7 + 1;
        let w = vec![0.01f32; 2 * sdim];
        let y = eng.infer(&s, &mask, 0.2, 0.1, &w).unwrap();
        assert_eq!(y.len(), 2);
        assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(eng.last_saturations(), 0);
    }

    #[test]
    fn features_close_to_native_and_end_with_one() {
        let eng = QuantEngine::new(5, 2);
        let nat = NativeEngine::new(5, 2);
        let mask = Mask::golden(5, 2);
        let s = sample(11, 2, 3, 0);
        let fq = eng.features(&s, &mask, 0.2, 0.15).unwrap();
        let ff = nat.features(&s, &mask, 0.2, 0.15).unwrap();
        assert_eq!(fq.len(), ff.len());
        assert_eq!(*fq.last().unwrap(), 1.0);
        for (i, (a, b)) in fq.iter().zip(&ff).enumerate() {
            // loose sanity here; the tight analytic-bound assertion
            // lives in tests/quant_equivalence.rs
            assert!((a - b).abs() < 5e-3, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn train_step_delegates_to_f32_reference() {
        let eng = QuantEngine::new(8, 3);
        let mask = Mask::golden(8, 2);
        let mut st = TrainState::init(3, 8, 0.1, 0.1);
        let s = sample(12, 2, 1, 1);
        let l = eng.train_step(&s, &mask, &mut st, 0.1, 0.1).unwrap();
        assert!(l.is_finite());
        assert!(st.w.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn narrow_parsed_format_builds_and_serves() {
        // a CLI-parsed 5-bit word must clamp the LUT size, not panic
        let fmt = QFormat::parse("q2.3").unwrap();
        let eng = QuantEngine::with_config(
            4,
            2,
            Nonlinearity::Linear { alpha: 1.0 },
            QuantConfig::with_format(fmt),
        );
        let mask = Mask::golden(4, 2);
        let s = sample(6, 2, 9, 0);
        let w = vec![0.01f32; 2 * (4 * 5 + 1)];
        let y = eng.infer(&s, &mask, 0.2, 0.1, &w).unwrap();
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn fork_replicates_config() {
        let cfg = QuantConfig {
            arith: QArith::new(QFormat::q6_10()),
            lut_log2_segments: 7,
        };
        let eng = QuantEngine::with_config(6, 2, Nonlinearity::Tanh, cfg);
        let replica = eng.fork().expect("quant engines fork freely");
        assert_eq!(replica.name(), "quant");
        // identical results through the replica
        let mask = Mask::golden(6, 2);
        let s = sample(9, 2, 5, 0);
        let a = eng.features(&s, &mask, 0.2, 0.1).unwrap();
        let b = replica.features(&s, &mask, 0.2, 0.1).unwrap();
        assert_eq!(a, b);
    }
}
