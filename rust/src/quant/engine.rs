//! [`QuantEngine`] — the quantized datapath behind the sharded
//! coordinator.
//!
//! Implements [`Engine`] so quantized serving is a config switch, not a
//! code path: `features`/`infer` run the bit-accurate Q-format forward
//! pass + integer MAC output layer, while `train_step` delegates to the
//! f32 [`NativeEngine`] — mirroring the deployment split where the
//! truncated-BP parameter search runs on the PS (ARM) side in float and
//! the serving datapath is the PL's fixed-point pipeline. The ridge
//! phase therefore trains on **quantized** features (what the hardware
//! will actually produce at inference time), which is the
//! quantization-aware choice.
//!
//! Steady-state `features_into`/`infer_into` perform **zero heap
//! allocations** (per-replica workspace + in-place mask refresh +
//! grow-only quantized-weight cache) — asserted by the counting
//! allocator in `tests/zero_alloc.rs`.
//!
//! # Recalibration (online reservoir adaptation)
//!
//! When the Serve-phase reservoir optimizer moves (p, q),
//! [`Engine::recalibrate`] rebuilds the PWL LUT (re-measuring its
//! sup-error), re-runs the §12 error budget for the active Q-format
//! against the session's observed workload envelope
//! ([`budget_for_workload`](super::budget::budget_for_workload)), and —
//! if the new parameters violate the budget's stability region — flips
//! serving to the **f32 fallback** (logged + counted): `features`/`infer`
//! route through the embedded [`NativeEngine`] until a later
//! recalibration lands back inside the budget. Every recalibration bumps
//! the engine's reservoir [`generation`](Engine::generation), which is
//! what lets sessions keep ridge factors and features generation-
//! coherent across the datapath switch (DESIGN.md §13).

use std::cell::{Cell, RefCell};

use anyhow::Result;

use crate::coordinator::engine::{
    Engine, FeatureRequest, NativeEngine, Recalibration, ReservoirUpdate,
};
use crate::data::dataset::Sample;
use crate::dfr::backprop::softmax_inplace;
use crate::dfr::mask::Mask;
use crate::dfr::reservoir::Nonlinearity;
use crate::runtime::executor::TrainState;
use crate::{log_info, log_warn};

use super::budget::budget_for_workload;
use super::reservoir::{QuantForwardScratch, QuantReservoir};
use super::QuantConfig;

/// The fixed-point compute engine (see module docs).
pub struct QuantEngine {
    pub nx: usize,
    pub n_c: usize,
    pub f: Nonlinearity,
    pub cfg: QuantConfig,
    /// f32 reference backing `train_step` (PS-side SGD) and the
    /// budget-violation serving fallback
    native: NativeEngine,
    /// per-replica workspace; never contended — each shard exclusively
    /// owns its engine replica (`Engine: Send`, not `Sync`)
    scratch: RefCell<QuantScratch>,
    /// datapath generation: bumped when a `recalibrate` actually changes
    /// the shared serving datapath (the f32 fallback flipping on or off)
    generation: Cell<u64>,
    /// serving datapath switch: when set, `features`/`infer` route
    /// through the f32 native engine (budget violation)
    fallback: Cell<bool>,
    /// lifetime recalibration count
    recalibrations: Cell<u64>,
    /// lifetime budget-violation (fallback) count
    fallbacks: Cell<u64>,
    /// last recalibration's r̃ error bound (+∞ while fallen back,
    /// NaN before the first recalibration)
    last_bound: Cell<f32>,
}

struct QuantScratch {
    res: QuantReservoir,
    fwd: QuantForwardScratch,
    /// quantized output-layer cache, refreshed in place per infer
    qw: Vec<i32>,
}

impl QuantEngine {
    pub fn new(nx: usize, n_c: usize) -> Self {
        Self::with_config(
            nx,
            n_c,
            Nonlinearity::Linear { alpha: 1.0 },
            QuantConfig::default(),
        )
    }

    pub fn with_config(nx: usize, n_c: usize, f: Nonlinearity, cfg: QuantConfig) -> Self {
        let placeholder = Mask {
            nx,
            v: 0,
            m: Vec::new(),
        };
        // a segment must span at least one raw unit: narrow words (e.g.
        // a parsed --qformat q2.3) clamp the LUT size instead of
        // tripping PwlLut's assert
        let lut_segments = cfg.lut_log2_segments.min(cfg.arith.fmt.bits).max(1);
        QuantEngine {
            nx,
            n_c,
            f,
            cfg,
            native: NativeEngine::with_nonlinearity(nx, n_c, f),
            scratch: RefCell::new(QuantScratch {
                res: QuantReservoir::new(placeholder, f, cfg.arith, lut_segments),
                fwd: QuantForwardScratch::new(nx, 0),
                qw: Vec::new(),
            }),
            generation: Cell::new(0),
            fallback: Cell::new(false),
            recalibrations: Cell::new(0),
            fallbacks: Cell::new(0),
            last_bound: Cell::new(f32::NAN),
        }
    }

    /// Saturation count of the most recent forward pass — 0 means the
    /// error budget's no-overflow assumption held for that sample.
    pub fn last_saturations(&self) -> u64 {
        self.scratch.borrow().fwd.saturations()
    }

    /// Whether serving currently routes through the f32 fallback (the
    /// last recalibration's (p, q) violated the error budget).
    pub fn is_fallback(&self) -> bool {
        self.fallback.get()
    }

    /// Lifetime `recalibrate` calls.
    pub fn recalibrations(&self) -> u64 {
        self.recalibrations.get()
    }

    /// Lifetime budget violations (recalibrations that fell back).
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.get()
    }

    /// The per-element r̃ error bound of the last recalibration
    /// (infinite while fallen back; NaN before the first call).
    pub fn last_error_bound(&self) -> f32 {
        self.last_bound.get()
    }

    /// Run the quantized forward into the replica workspace (in-place
    /// mask refresh, reallocation only on shape change — zero
    /// steady-state allocations).
    fn forward_scratch(&self, s: &Sample, mask: &Mask, p: f32, q: f32, sc: &mut QuantScratch) {
        if sc.res.mask.nx != mask.nx || sc.res.mask.v != mask.v {
            sc.res.mask = mask.clone();
        } else if sc.res.mask.m != mask.m {
            sc.res.mask.m.copy_from_slice(&mask.m);
        }
        sc.res.set_params(p, q);
        sc.res.forward_into(&s.u, s.t, &mut sc.fwd);
    }
}

impl Engine for QuantEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        // PS-side f32 SGD (see module docs) — the quantized datapath
        // only serves features/inference
        self.native.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.features_into(s, mask, p, q, &mut out)?;
        Ok(out)
    }

    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if self.fallback.get() {
            return self.native.features_into(s, mask, p, q, out);
        }
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        sc.fwd.r_tilde_into(self.cfg.arith, out);
        Ok(())
    }

    fn features_batch_into(
        &self,
        reqs: &[FeatureRequest<'_>],
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        if self.fallback.get() {
            // fallen-back serving IS the f32 native path — use its real
            // batched kernel (bitwise-equal to per-call fallback serving)
            return self.native.features_batch_into(reqs, outs);
        }
        // Fixed-point datapath: no batched integer kernel yet
        // (DESIGN.md §14 documents why integer-MAC batching stays gated
        // off), so this routes through the shared audited per-call
        // loop — the coordinator's drain logic (and the equivalence
        // suite) is identical for both engines and a future batched
        // Q-format sweep is a drop-in.
        crate::coordinator::engine::features_batch_per_call(self, reqs, outs)
    }

    fn kernels(&self) -> crate::simd::Kernels {
        // meaningful only while fallen back (the f32 path serves) —
        // which is exactly when `scores_from_features_exact` lets the
        // planner score batched features with this table
        self.native.kernels()
    }

    fn scores_from_features_exact(&self) -> bool {
        // only while fallen back: fixed-point inference is an integer
        // MAC over the raw i32 feature words (`r_mat_raw`), not a float
        // dot over the dequantized r̃ — scoring dequantized features
        // would NOT be bitwise-equal, so batched `Infer` must go through
        // `infer_into` while the quant datapath is live
        self.fallback.get()
    }

    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w_tilde: &[f32]) -> Result<Vec<f32>> {
        let mut z = Vec::new();
        self.infer_into(s, mask, p, q, w_tilde, &mut z)?;
        Ok(z)
    }

    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        if self.fallback.get() {
            return self.native.infer_into(s, mask, p, q, w_tilde, scores);
        }
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        let arith = self.cfg.arith;
        let frac = arith.fmt.frac;
        // requantize the served layer into the grow-only cache — O(ny·s)
        // compares-and-stores, cheaper than the forward pass it follows
        if sc.qw.len() != w_tilde.len() {
            sc.qw.resize(w_tilde.len(), 0);
        }
        for (qw, &w) in sc.qw.iter_mut().zip(w_tilde) {
            *qw = arith.quantize(w);
        }
        // integer MAC per class: products at scale 2²ᶠ accumulated in
        // i64 (exact), one dequantizing rescale per output score
        let sc_ref = &*sc;
        let n_r = sc_ref.fwd.r_mat_raw().len();
        let sdim = n_r + 1;
        let ny = w_tilde.len() / sdim;
        scores.clear();
        scores.reserve(ny);
        let inv_scale = (-2.0 * f64::from(frac)).exp2();
        for i in 0..ny {
            let row = &sc_ref.qw[i * sdim..(i + 1) * sdim];
            let mut acc: i64 = 0;
            for (&w, &r) in row[..n_r].iter().zip(sc_ref.fwd.r_mat_raw()) {
                acc += i64::from(w) * i64::from(r);
            }
            // the tilde-1 feature: constant 1.0 is exactly 1 << frac
            acc += i64::from(row[n_r]) << frac;
            scores.push((acc as f64 * inv_scale) as f32);
        }
        softmax_inplace(scores);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "quant"
    }

    fn generation(&self) -> u64 {
        self.generation.get()
    }

    fn fell_back(&self) -> bool {
        self.fallback.get()
    }

    fn recalibrate(&self, upd: &ReservoirUpdate) -> Result<Recalibration> {
        // rebuild the PWL LUT and re-measure its sup-error — the budget
        // below is evaluated against the freshly measured ε_f. Today the
        // LUT depends only on (f, format, segments), so the rebuild is
        // bit-identical (asserted in tests) and cheap (2^k segment
        // evals); it stays in the recalibration contract so a future
        // range-adaptive or (p, q)-scaled table re-measures correctly.
        let eps_f = {
            let mut sc = self.scratch.borrow_mut();
            sc.res.rebuild_lut();
            sc.res.lut().max_err()
        };
        let bound = budget_for_workload(
            self.cfg.arith.fmt,
            self.f,
            upd.p,
            upd.q,
            self.nx,
            upd.n_v,
            upd.t_max.max(1),
            upd.u_max,
            eps_f,
        );
        let fell_back = !bound.is_finite();
        self.recalibrations.set(self.recalibrations.get() + 1);
        let flipped = fell_back != self.fallback.get();
        if fell_back {
            self.fallbacks.set(self.fallbacks.get() + 1);
            if flipped {
                log_warn!(
                    "quant: (p={:.4}, q={:.4}) violates the {} error budget — serving falls back to f32",
                    upd.p,
                    upd.q,
                    self.cfg.arith.fmt.name()
                );
            }
        } else if flipped {
            log_info!(
                "quant: (p={:.4}, q={:.4}) back inside the {} budget (bound {:.3e}) — fixed-point serving resumes",
                upd.p,
                upd.q,
                self.cfg.arith.fmt.name(),
                bound
            );
        }
        self.fallback.set(fell_back);
        self.last_bound.set(bound);
        // the DATAPATH generation moves only when the datapath itself
        // changed (quant ⇄ f32): parameter-only recalibrations leave the
        // shared feature function untouched, so other sessions on the
        // shard have nothing to re-featurize against
        if flipped {
            self.generation.set(self.generation.get() + 1);
        }
        Ok(Recalibration {
            generation: self.generation.get(),
            fell_back,
            error_bound: Some(bound),
        })
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        // configuration-only state: replicas rebuild their own LUT and
        // workspace (and start un-fallen-back at generation 0 — each
        // shard's sessions recalibrate their own replica)
        Some(Box::new(QuantEngine::with_config(
            self.nx, self.n_c, self.f, self.cfg,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::{QArith, QFormat};
    use crate::util::prng::Pcg32;

    fn sample(t: usize, v: usize, seed: u64, label: usize) -> Sample {
        let mut rng = Pcg32::seed(seed);
        Sample {
            u: (0..t * v).map(|_| 0.5 * rng.normal()).collect(),
            t,
            label,
        }
    }

    #[test]
    fn infer_is_probability() {
        let eng = QuantEngine::new(6, 2);
        let mask = Mask::golden(6, 2);
        let s = sample(9, 2, 2, 0);
        let sdim = 6 * 7 + 1;
        let w = vec![0.01f32; 2 * sdim];
        let y = eng.infer(&s, &mask, 0.2, 0.1, &w).unwrap();
        assert_eq!(y.len(), 2);
        assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(eng.last_saturations(), 0);
    }

    #[test]
    fn features_close_to_native_and_end_with_one() {
        let eng = QuantEngine::new(5, 2);
        let nat = NativeEngine::new(5, 2);
        let mask = Mask::golden(5, 2);
        let s = sample(11, 2, 3, 0);
        let fq = eng.features(&s, &mask, 0.2, 0.15).unwrap();
        let ff = nat.features(&s, &mask, 0.2, 0.15).unwrap();
        assert_eq!(fq.len(), ff.len());
        assert_eq!(*fq.last().unwrap(), 1.0);
        for (i, (a, b)) in fq.iter().zip(&ff).enumerate() {
            // loose sanity here; the tight analytic-bound assertion
            // lives in tests/quant_equivalence.rs
            assert!((a - b).abs() < 5e-3, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn train_step_delegates_to_f32_reference() {
        let eng = QuantEngine::new(8, 3);
        let mask = Mask::golden(8, 2);
        let mut st = TrainState::init(3, 8, 0.1, 0.1);
        let s = sample(12, 2, 1, 1);
        let l = eng.train_step(&s, &mask, &mut st, 0.1, 0.1).unwrap();
        assert!(l.is_finite());
        assert!(st.w.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn narrow_parsed_format_builds_and_serves() {
        // a CLI-parsed 5-bit word must clamp the LUT size, not panic
        let fmt = QFormat::parse("q2.3").unwrap();
        let eng = QuantEngine::with_config(
            4,
            2,
            Nonlinearity::Linear { alpha: 1.0 },
            QuantConfig::with_format(fmt),
        );
        let mask = Mask::golden(4, 2);
        let s = sample(6, 2, 9, 0);
        let w = vec![0.01f32; 2 * (4 * 5 + 1)];
        let y = eng.infer(&s, &mask, 0.2, 0.1, &w).unwrap();
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn recalibrate_inside_budget_keeps_fixed_point_serving() {
        let eng = QuantEngine::new(5, 2);
        let mask = Mask::golden(5, 2);
        let s = sample(11, 2, 7, 0);
        let before = eng.features(&s, &mask, 0.2, 0.15).unwrap();
        let r = eng
            .recalibrate(&ReservoirUpdate {
                p: 0.2,
                q: 0.15,
                n_v: 2,
                t_max: 11,
                u_max: 1.5,
            })
            .unwrap();
        assert!(!r.fell_back);
        let bound = r.error_bound.expect("quant engines report a bound");
        assert!(bound.is_finite() && bound > 0.0, "{bound}");
        assert!(!eng.is_fallback());
        // the datapath never changed (stayed fixed-point), so the shared
        // datapath generation must NOT move — other sessions on the
        // shard keep their factors
        assert_eq!(r.generation, 0);
        assert_eq!(eng.generation(), 0);
        assert_eq!(eng.recalibrations(), 1);
        assert_eq!(eng.fallbacks(), 0);
        // the quantized datapath (rebuilt LUT included) is bit-stable
        let after = eng.features(&s, &mask, 0.2, 0.15).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn recalibrate_outside_budget_falls_back_to_f32_and_recovers() {
        let eng = QuantEngine::new(5, 2);
        let nat = NativeEngine::new(5, 2);
        let mask = Mask::golden(5, 2);
        let s = sample(11, 2, 8, 1);
        // p·L_f + |q| = 1.3 ≥ 1: no contraction → +∞ bound → fallback
        let r = eng
            .recalibrate(&ReservoirUpdate {
                p: 0.8,
                q: 0.5,
                n_v: 2,
                t_max: 11,
                u_max: 1.5,
            })
            .unwrap();
        assert!(r.fell_back);
        assert!(r.error_bound.unwrap().is_infinite());
        assert!(eng.is_fallback());
        assert_eq!(eng.fallbacks(), 1);
        assert!(eng.last_error_bound().is_infinite());
        // fallen-back serving is EXACTLY the f32 native path
        let fq = eng.features(&s, &mask, 0.3, 0.2).unwrap();
        let ff = nat.features(&s, &mask, 0.3, 0.2).unwrap();
        assert_eq!(fq, ff);
        let w = vec![0.01f32; 2 * (5 * 6 + 1)];
        let yq = eng.infer(&s, &mask, 0.3, 0.2, &w).unwrap();
        let yf = nat.infer(&s, &mask, 0.3, 0.2, &w).unwrap();
        assert_eq!(yq, yf);
        // a later recalibration back inside the budget resumes the
        // fixed-point datapath
        let r2 = eng
            .recalibrate(&ReservoirUpdate {
                p: 0.2,
                q: 0.1,
                n_v: 2,
                t_max: 11,
                u_max: 1.5,
            })
            .unwrap();
        assert!(!r2.fell_back);
        assert_eq!(r2.generation, 2);
        assert!(!eng.is_fallback());
        assert_eq!(eng.fallbacks(), 1, "recovery is not a fallback");
        let fq2 = eng.features(&s, &mask, 0.2, 0.1).unwrap();
        let fresh = QuantEngine::new(5, 2);
        assert_eq!(fq2, fresh.features(&s, &mask, 0.2, 0.1).unwrap());
    }

    #[test]
    fn fork_replicates_config() {
        let cfg = QuantConfig {
            arith: QArith::new(QFormat::q6_10()),
            lut_log2_segments: 7,
        };
        let eng = QuantEngine::with_config(6, 2, Nonlinearity::Tanh, cfg);
        let replica = eng.fork().expect("quant engines fork freely");
        assert_eq!(replica.name(), "quant");
        // identical results through the replica
        let mask = Mask::golden(6, 2);
        let s = sample(9, 2, 5, 0);
        let a = eng.features(&s, &mask, 0.2, 0.1).unwrap();
        let b = replica.features(&s, &mask, 0.2, 0.1).unwrap();
        assert_eq!(a, b);
    }
}
