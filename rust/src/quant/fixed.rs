//! Runtime Q-format fixed-point arithmetic — the FPGA datapath word.
//!
//! HLS designs pick one `ap_fixed<W, I>` word per datapath; this module
//! is the bit-accurate software model of that word: two's-complement
//! `W`-bit raw values (stored in `i32`, computed through `i64`), a
//! runtime [`QFormat`] carrying the total/fractional split, and the two
//! HLS quantization knobs — [`Rounding`] (`AP_RND` half-up vs `AP_TRN`
//! truncation) and [`Overflow`] (`AP_SAT` saturation vs `AP_WRAP`
//! two's-complement wrap).
//!
//! Every operation is exact integer arithmetic: a product of two raw
//! values is formed in `i64` at scale `2^(2F)` and brought back to the
//! word with **one** rounding — the same single-rounding semantics the
//! synthesized multiplier has, which is what makes the software model
//! bit-accurate rather than "f32 but noisier".

/// Runtime Q-format: `bits` total (two's complement, sign included) with
/// `frac` fractional bits — the classic `Q<I>.<F>` notation has
/// `I = bits − frac` (sign included). `Q4.12` ⇒ 16-bit word, 12
/// fractional bits, range [−8, 8) at resolution 2⁻¹².
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// total word width (2..=24; products and the T-long DPRR
    /// accumulation must fit i64 — see [`QFormat::new`])
    pub bits: u32,
    /// fractional bits (1..bits — the datapath's product rescale rounds
    /// by half an LSB, which needs at least one fractional bit)
    pub frac: u32,
}

impl QFormat {
    pub const fn new(bits: u32, frac: u32) -> Self {
        assert!(bits >= 2 && bits <= 24, "word width out of the modelled range");
        assert!(frac >= 1, "the product rescale needs at least one fractional bit");
        assert!(frac < bits, "need at least the sign bit above the fraction");
        QFormat { bits, frac }
    }

    /// Q4.12 — 16-bit word, range [−8, 8), resolution 2⁻¹².
    pub const fn q4_12() -> Self {
        QFormat::new(16, 12)
    }

    /// Q6.10 — 16-bit word, range [−32, 32), resolution 2⁻¹⁰.
    pub const fn q6_10() -> Self {
        QFormat::new(16, 10)
    }

    /// Q8.8 — 16-bit word, range [−128, 128), resolution 2⁻⁸.
    pub const fn q8_8() -> Self {
        QFormat::new(16, 8)
    }

    /// Parse "q4.12" / "Q6.10"-style names (the CLI `--qformat` values).
    pub fn parse(name: &str) -> Option<QFormat> {
        let rest = name.strip_prefix('q').or_else(|| name.strip_prefix('Q'))?;
        let (int_s, frac_s) = rest.split_once('.')?;
        let int_bits: u32 = int_s.parse().ok()?;
        let frac: u32 = frac_s.parse().ok()?;
        let bits = int_bits.checked_add(frac)?;
        if !(2..=24).contains(&bits) || frac == 0 || frac >= bits {
            return None;
        }
        Some(QFormat::new(bits, frac))
    }

    /// "Q4.12"-style display name.
    pub fn name(&self) -> String {
        format!("Q{}.{}", self.bits - self.frac, self.frac)
    }

    /// One unit in the last place, 2⁻ᶠ.
    pub fn lsb(&self) -> f32 {
        (-(self.frac as f64)).exp2() as f32
    }

    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Largest representable value (max_raw · 2⁻ᶠ).
    pub fn max_value(&self) -> f32 {
        self.max_raw() as f32 * self.lsb()
    }

    pub fn min_value(&self) -> f32 {
        self.min_raw() as f32 * self.lsb()
    }
}

/// Rounding applied whenever precision is dropped (requantization and
/// post-product rescale).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Rounding {
    /// round to nearest, ties up (add half, floor-shift) — HLS `AP_RND`
    #[default]
    Nearest,
    /// truncate toward −∞ (plain arithmetic shift) — HLS `AP_TRN`
    Floor,
}

/// Overflow handling whenever a result leaves the representable range.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Overflow {
    /// clamp to [min_raw, max_raw] — HLS `AP_SAT`
    #[default]
    Saturate,
    /// keep the low `bits` bits (two's complement) — HLS `AP_WRAP`
    Wrap,
}

/// A format plus its rounding/overflow modes: everything needed to
/// evaluate one fixed-point operation. Copy-cheap; kernels pass it by
/// value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QArith {
    pub fmt: QFormat,
    pub round: Rounding,
    pub overflow: Overflow,
}

impl QArith {
    pub fn new(fmt: QFormat) -> Self {
        QArith {
            fmt,
            round: Rounding::default(),
            overflow: Overflow::default(),
        }
    }

    /// Bring an out-of-range wide value back into the word. `sats`
    /// counts range violations (saturation in `Saturate` mode, wraps in
    /// `Wrap` mode) — the error budget is only valid while this stays 0.
    #[inline]
    pub fn clamp_counting(&self, x: i64, sats: &mut u64) -> i32 {
        let (lo, hi) = (self.fmt.min_raw(), self.fmt.max_raw());
        if x >= lo && x <= hi {
            return x as i32;
        }
        *sats += 1;
        match self.overflow {
            Overflow::Saturate => x.clamp(lo, hi) as i32,
            Overflow::Wrap => {
                let m = 1i64 << self.fmt.bits;
                let w = x.rem_euclid(m);
                (if w > hi { w - m } else { w }) as i32
            }
        }
    }

    #[inline]
    pub fn clamp(&self, x: i64) -> i32 {
        let mut sats = 0;
        self.clamp_counting(x, &mut sats)
    }

    /// Drop `shift` low bits of a wide intermediate (one rounding), then
    /// range-handle — the single-rounding product semantics.
    #[inline]
    pub fn rescale_counting(&self, wide: i64, shift: u32, sats: &mut u64) -> i32 {
        debug_assert!(shift >= 1 && shift < 63);
        let r = match self.round {
            Rounding::Nearest => (wide + (1i64 << (shift - 1))) >> shift,
            Rounding::Floor => wide >> shift,
        };
        self.clamp_counting(r, sats)
    }

    #[inline]
    pub fn rescale(&self, wide: i64, shift: u32) -> i32 {
        let mut sats = 0;
        self.rescale_counting(wide, shift, &mut sats)
    }

    /// [`rescale_counting`](Self::rescale_counting) for the extra-wide
    /// normalization product (accumulator × reciprocal, scale 2⁴ᶠ) — the
    /// worst-case magnitude exceeds i64 for wide formats, so the shift
    /// happens in i128. After the shift the value is ≤ 2^(2·bits−2−frac),
    /// far inside i64 for every supported format.
    #[inline]
    pub fn rescale_wide_counting(&self, wide: i128, shift: u32, sats: &mut u64) -> i32 {
        debug_assert!(shift >= 1 && shift < 127);
        let r = match self.round {
            Rounding::Nearest => (wide + (1i128 << (shift - 1))) >> shift,
            Rounding::Floor => wide >> shift,
        };
        self.clamp_counting(r as i64, sats)
    }

    /// f32 → raw. NaN maps to 0; ±∞ saturates. Scaling runs in f64 so
    /// the 2ᶠ factor is exact.
    pub fn quantize(&self, x: f32) -> i32 {
        let mut sats = 0;
        self.quantize_counting(x, &mut sats)
    }

    /// [`quantize`](Self::quantize) with range-violation counting — the
    /// datapath's input conversion uses this so that an out-of-range
    /// input series shows up in the forward pass's saturation counter.
    pub fn quantize_counting(&self, x: f32, sats: &mut u64) -> i32 {
        if x.is_nan() {
            return 0;
        }
        let scaled = f64::from(x) * (1i64 << self.fmt.frac) as f64;
        // beyond ±2^40 the word is out of range for every supported
        // format; pre-clamp so the f64→i64 cast stays in range
        let r = match self.round {
            Rounding::Nearest => (scaled + 0.5).floor(),
            Rounding::Floor => scaled.floor(),
        }
        .clamp(-(2f64.powi(40)), 2f64.powi(40));
        self.clamp_counting(r as i64, sats)
    }

    /// raw → f32 (exact: raw · 2⁻ᶠ is representable for all ≤24-bit raws).
    #[inline]
    pub fn dequantize(&self, raw: i32) -> f32 {
        raw as f32 * self.fmt.lsb()
    }

    /// Word-width addition.
    #[inline]
    pub fn add_counting(&self, a: i32, b: i32, sats: &mut u64) -> i32 {
        self.clamp_counting(i64::from(a) + i64::from(b), sats)
    }

    /// Word-width product: i64 intermediate at scale 2²ᶠ, one rescale.
    #[inline]
    pub fn mul_counting(&self, a: i32, b: i32, sats: &mut u64) -> i32 {
        self.rescale_counting(i64::from(a) * i64::from(b), self.fmt.frac, sats)
    }

    pub fn add(&self, a: i32, b: i32) -> i32 {
        let mut sats = 0;
        self.add_counting(a, b, &mut sats)
    }

    pub fn mul(&self, a: i32, b: i32) -> i32 {
        let mut sats = 0;
        self.mul_counting(a, b, &mut sats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_formats() {
        assert_eq!(QFormat::q4_12().name(), "Q4.12");
        assert_eq!(QFormat::q6_10().name(), "Q6.10");
        assert_eq!(QFormat::q8_8().name(), "Q8.8");
        assert_eq!(QFormat::parse("q4.12"), Some(QFormat::q4_12()));
        assert_eq!(QFormat::parse("Q6.10"), Some(QFormat::q6_10()));
        assert_eq!(QFormat::parse("nope"), None);
        assert_eq!(QFormat::parse("q40.12"), None);
        // frac = 0 would underflow the product rescale's half-LSB shift
        assert_eq!(QFormat::parse("q16.0"), None);
        // narrow-but-valid words parse (the engine clamps its LUT size)
        assert_eq!(QFormat::parse("q2.3"), Some(QFormat::new(5, 3)));
    }

    #[test]
    fn quantize_dequantize_roundtrip_on_grid() {
        let a = QArith::new(QFormat::q4_12());
        for raw in [-32768i32, -1000, -1, 0, 1, 999, 32767] {
            let v = a.dequantize(raw);
            assert_eq!(a.quantize(v), raw, "raw {raw}");
        }
    }

    #[test]
    fn quantize_rounds_and_saturates() {
        let a = QArith::new(QFormat::q4_12());
        // 2^-12 grid: 0.00013 → rounds to 1 raw
        assert_eq!(a.quantize(1.4 * a.fmt.lsb()), 1);
        assert_eq!(a.quantize(1.6 * a.fmt.lsb()), 2);
        // half-up ties
        assert_eq!(a.quantize(1.5 * a.fmt.lsb()), 2);
        assert_eq!(a.quantize(-1.5 * a.fmt.lsb()), -1);
        // saturation at ±8
        assert_eq!(a.quantize(100.0), a.fmt.max_raw() as i32);
        assert_eq!(a.quantize(-100.0), a.fmt.min_raw() as i32);
        assert_eq!(a.quantize(f32::NAN), 0);
        assert_eq!(a.quantize(f32::INFINITY), a.fmt.max_raw() as i32);
    }

    #[test]
    fn floor_rounding_truncates() {
        let mut a = QArith::new(QFormat::q4_12());
        a.round = Rounding::Floor;
        assert_eq!(a.quantize(1.9 * a.fmt.lsb()), 1);
        assert_eq!(a.quantize(-0.1 * a.fmt.lsb()), -1);
    }

    #[test]
    fn mul_single_rounding() {
        let a = QArith::new(QFormat::q4_12());
        // 1.5 * 2.25 = 3.375, exactly representable at F=12
        let x = a.quantize(1.5);
        let y = a.quantize(2.25);
        assert_eq!(a.dequantize(a.mul(x, y)), 3.375);
        // 3 * 3 = 9 saturates to ~8
        let t = a.quantize(3.0);
        let mut sats = 0;
        let r = a.mul_counting(t, t, &mut sats);
        assert_eq!(sats, 1);
        assert_eq!(r, a.fmt.max_raw() as i32);
    }

    #[test]
    fn wrap_mode_wraps_two_complement() {
        let mut a = QArith::new(QFormat::new(8, 4));
        a.overflow = Overflow::Wrap;
        // max_raw 127; 130 wraps to -126
        assert_eq!(a.clamp(130), -126);
        assert_eq!(a.clamp(-130), 126);
        assert_eq!(a.clamp(127), 127);
        assert_eq!(a.clamp(-128), -128);
    }

    #[test]
    fn add_saturates() {
        let a = QArith::new(QFormat::q4_12());
        let big = a.quantize(6.0);
        let mut sats = 0;
        let r = a.add_counting(big, big, &mut sats);
        assert_eq!(sats, 1);
        assert_eq!(r, a.fmt.max_raw() as i32);
        assert_eq!(a.add(a.quantize(1.0), a.quantize(2.0)), a.quantize(3.0));
    }

    #[test]
    fn lsb_and_ranges() {
        let f = QFormat::q6_10();
        assert_eq!(f.lsb(), 1.0 / 1024.0);
        assert_eq!(f.max_raw(), 32767);
        assert_eq!(f.min_raw(), -32768);
        assert!((f.max_value() - 31.999).abs() < 1e-2);
        assert_eq!(f.min_value(), -32.0);
    }
}
