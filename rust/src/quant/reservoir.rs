//! Bit-accurate quantized DFR forward pass — the FPGA datapath model.
//!
//! Mirrors `dfr::reservoir::Reservoir::forward_into` operation for
//! operation, but in Q-format integer arithmetic:
//!
//! * **masking** — the ±1 mask makes `j = M u` a signed add tree over
//!   the quantized inputs, accumulated exactly in i64 and clamped once
//!   (no multipliers, exactly like the HLS datapath);
//! * **node cascade** — `x_n = p ⊗ f_LUT(j_n ⊕ x_n) ⊕ q ⊗ x_{n−1}` with
//!   word-width saturating ops and the PWL-LUT nonlinearity;
//! * **DPRR** — rank-1 products accumulated in a *wide* i64 accumulator
//!   at scale 2²ᶠ (the HLS pattern: narrow multipliers, wide adder
//!   chain), normalized by a reciprocal `1/T` held at 2F fractional
//!   bits, with a **single** rescale per output element.
//!
//! Saturation events are counted per forward pass
//! ([`QuantForwardScratch::saturations`]); the analytic error budget
//! (`quant::budget`) is valid exactly while that counter stays 0, and
//! the equivalence tests assert both together.

use crate::dfr::mask::Mask;
use crate::dfr::reservoir::Nonlinearity;

use super::fixed::QArith;
use super::lut::PwlLut;

/// Reusable workspace of the quantized forward: every buffer is sized by
/// (Nx, V) only, so steady-state `forward_into` performs **zero heap
/// allocations** regardless of the series length T (asserted through the
/// engine layer in `tests/zero_alloc.rs`).
#[derive(Clone, Debug)]
pub struct QuantForwardScratch {
    nx: usize,
    v: usize,
    /// quantized input sample of the current step (V words)
    qu: Vec<i32>,
    /// state x(k) raw
    x: Vec<i32>,
    /// state x(k-1) raw
    x_prev: Vec<i32>,
    /// masked input j(k) raw
    j: Vec<i32>,
    /// wide DPRR accumulator, scale 2²ᶠ, row-major Nx×(Nx+1)
    acc: Vec<i64>,
    /// normalized DPRR matrix (raw words, scale 2ᶠ)
    r_mat: Vec<i32>,
    t_len: usize,
    /// range violations (saturations/wraps) of the last forward pass
    saturations: u64,
}

impl QuantForwardScratch {
    pub fn new(nx: usize, v: usize) -> Self {
        QuantForwardScratch {
            nx,
            v,
            qu: vec![0; v],
            x: vec![0; nx],
            x_prev: vec![0; nx],
            j: vec![0; nx],
            acc: vec![0; nx * (nx + 1)],
            r_mat: vec![0; nx * (nx + 1)],
            t_len: 0,
            saturations: 0,
        }
    }

    /// Re-size for a different shape; allocates only on change.
    pub fn ensure(&mut self, nx: usize, v: usize) {
        if self.nx != nx || self.v != v {
            *self = QuantForwardScratch::new(nx, v);
        }
    }

    /// Normalized DPRR matrix of the last forward (raw Q words).
    pub fn r_mat_raw(&self) -> &[i32] {
        &self.r_mat
    }

    pub fn t_len(&self) -> usize {
        self.t_len
    }

    /// Range violations of the last forward pass. The error budget
    /// assumes this is 0 — a positive count means the chosen Q-format's
    /// integer bits cannot hold this workload's dynamic range.
    pub fn saturations(&self) -> u64 {
        self.saturations
    }

    /// Dequantized r̃ = [vec(R), 1] into a caller-owned f32 buffer
    /// (capacity reused — no allocation once sized).
    pub fn r_tilde_into(&self, arith: QArith, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.r_mat.len() + 1);
        out.extend(self.r_mat.iter().map(|&r| arith.dequantize(r)));
        out.push(1.0);
    }
}

/// A configured quantized modular-DFR reservoir.
///
/// Holds the mask plus the quantized parameters and the LUT; `p`/`q` are
/// requantized via [`set_params`](Self::set_params) when the session's
/// f32 training state moves (one quantize each — negligible next to the
/// forward pass).
#[derive(Clone, Debug)]
pub struct QuantReservoir {
    pub mask: Mask,
    pub arith: QArith,
    f: Nonlinearity,
    log2_segments: u32,
    p_raw: i32,
    q_raw: i32,
    lut: PwlLut,
}

impl QuantReservoir {
    pub fn new(mask: Mask, f: Nonlinearity, arith: QArith, log2_segments: u32) -> Self {
        let lut = PwlLut::new(f, arith, log2_segments);
        QuantReservoir {
            mask,
            arith,
            f,
            log2_segments,
            p_raw: 0,
            q_raw: 0,
            lut,
        }
    }

    pub fn nx(&self) -> usize {
        self.mask.nx
    }

    /// The configured nonlinearity.
    pub fn f(&self) -> Nonlinearity {
        self.f
    }

    /// Quantize (p, q) into the datapath words.
    pub fn set_params(&mut self, p: f32, q: f32) {
        self.p_raw = self.arith.quantize(p);
        self.q_raw = self.arith.quantize(q);
    }

    /// The LUT (error-budget inputs: `max_err`, `words`).
    pub fn lut(&self) -> &PwlLut {
        &self.lut
    }

    /// Rebuild the PWL LUT from the stored configuration — the
    /// recalibration hook (`QuantEngine::recalibrate`): reconstruction
    /// re-measures the sup-error the fresh error budget is evaluated
    /// against.
    pub fn rebuild_lut(&mut self) {
        self.lut = PwlLut::new(self.f, self.arith, self.log2_segments);
    }

    /// Bit-accurate streaming forward over a series `u` (row-major T×V).
    ///
    /// Same structure as `Reservoir::forward_into`: per step the mask
    /// add-tree, the node cascade, and the DPRR push; at the end one
    /// reciprocal multiply + rescale per DPRR element. The f32 inputs
    /// are quantized on the fly (one word per channel per step).
    pub fn forward_into(&self, u: &[f32], t: usize, s: &mut QuantForwardScratch) {
        let nx = self.mask.nx;
        let v = self.mask.v;
        assert_eq!(u.len(), t * v, "series shape mismatch");
        let a = self.arith;
        let frac = a.fmt.frac;
        s.ensure(nx, v);
        s.x.fill(0);
        s.x_prev.fill(0);
        s.j.fill(0);
        s.acc.fill(0);
        s.saturations = 0;
        let sats = &mut s.saturations;
        let w = nx + 1;
        for k in 0..t {
            s.x_prev.copy_from_slice(&s.x);
            // quantize this step's input sample (clipped inputs count as
            // range violations — they void the error budget too)
            for (qu, &uv) in s.qu.iter_mut().zip(&u[k * v..(k + 1) * v]) {
                *qu = a.quantize_counting(uv, sats);
            }
            // masking: ±1 add tree, exact in i64, one clamp per node
            for (n, j) in s.j.iter_mut().enumerate() {
                let row = &self.mask.m[n * v..(n + 1) * v];
                let mut acc = 0i64;
                for (&m, &qu) in row.iter().zip(&s.qu) {
                    acc += if m > 0.0 { i64::from(qu) } else { -i64::from(qu) };
                }
                *j = a.clamp_counting(acc, sats);
            }
            // node cascade (Eq. 14), word-width ops + LUT
            let mut prev_node = s.x[nx - 1];
            for n in 0..nx {
                let arg = a.add_counting(s.j[n], s.x[n], sats);
                let fx = self.lut.eval(arg);
                let xn = a.add_counting(
                    a.mul_counting(self.p_raw, fx, sats),
                    a.mul_counting(self.q_raw, prev_node, sats),
                    sats,
                );
                prev_node = xn;
                s.x[n] = xn;
            }
            // DPRR push into the wide accumulator (exact)
            for i in 0..nx {
                let xi = i64::from(s.x[i]);
                let row = &mut s.acc[i * w..(i + 1) * w];
                for (r, &xp) in row[..nx].iter_mut().zip(&s.x_prev) {
                    *r += xi * i64::from(xp);
                }
                row[nx] += xi << frac;
            }
        }
        // normalize by 1/T: reciprocal at 2F fractional bits, one
        // multiply + one rescale (4F → F) per element
        let t_div = t.max(1) as i64;
        let inv_t_raw = ((1i64 << (2 * frac)) + t_div / 2) / t_div;
        for (r, &acc) in s.r_mat.iter_mut().zip(&s.acc) {
            let wide = i128::from(acc) * i128::from(inv_t_raw);
            *r = a.rescale_wide_counting(wide, 3 * frac, sats);
        }
        s.t_len = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfr::reservoir::{ForwardScratch, Reservoir};
    use crate::quant::fixed::QFormat;
    use crate::util::prng::Pcg32;

    fn pair(nx: usize, v: usize, p: f32, q: f32, fmt: QFormat) -> (Reservoir, QuantReservoir) {
        let mask = Mask::golden(nx, v);
        let f = Nonlinearity::Linear { alpha: 1.0 };
        let res = Reservoir {
            mask: mask.clone(),
            p,
            q,
            f,
        };
        let mut qres = QuantReservoir::new(mask, f, QArith::new(fmt), 6);
        qres.set_params(p, q);
        (res, qres)
    }

    #[test]
    fn tracks_f32_reference_closely_at_wide_format() {
        // Q8.14 (22-bit): quantization error ~6e-5 per op — the quant
        // forward must sit within a small multiple of that of f32
        let (res, qres) = pair(6, 2, 0.25, 0.2, QFormat::new(22, 14));
        let mut rng = Pcg32::seed(71);
        let t = 40;
        let u: Vec<f32> = (0..t * 2).map(|_| rng.normal()).collect();
        let mut fs = ForwardScratch::new(6);
        res.forward_into(&u, t, &mut fs);
        let mut qs = QuantForwardScratch::new(6, 2);
        qres.forward_into(&u, t, &mut qs);
        assert_eq!(qs.saturations(), 0);
        let mut rt = Vec::new();
        qs.r_tilde_into(qres.arith, &mut rt);
        let mut rt_f = Vec::new();
        fs.r_tilde_into(&mut rt_f);
        assert_eq!(rt.len(), rt_f.len());
        for (i, (a, b)) in rt.iter().zip(&rt_f).enumerate() {
            assert!((a - b).abs() < 2e-3, "elem {i}: {a} vs {b}");
        }
        assert_eq!(*rt.last().unwrap(), 1.0);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (_, qres) = pair(5, 3, 0.2, 0.1, QFormat::q4_12());
        let mut rng = Pcg32::seed(72);
        let u: Vec<f32> = (0..15 * 3).map(|_| rng.normal() * 0.3).collect();
        let mut s1 = QuantForwardScratch::new(5, 3);
        qres.forward_into(&u, 15, &mut s1);
        let first: Vec<i32> = s1.r_mat_raw().to_vec();
        // a different series through the same scratch, then the original
        // again — stale state would break bit-identity
        let u2: Vec<f32> = (0..7 * 3).map(|_| rng.normal()).collect();
        qres.forward_into(&u2, 7, &mut s1);
        qres.forward_into(&u, 15, &mut s1);
        assert_eq!(s1.r_mat_raw(), &first[..]);
        assert_eq!(s1.t_len(), 15);
    }

    #[test]
    fn saturation_counter_fires_on_overdriven_input() {
        // Q6.2 (8-bit, range ±32): inputs of 100 clip at the input
        // quantizer itself — counted as range violations
        let (_, qres) = pair(4, 4, 0.2, 0.1, QFormat::new(8, 2));
        let u = vec![100.0f32; 6 * 4];
        let mut s = QuantForwardScratch::new(4, 4);
        qres.forward_into(&u, 6, &mut s);
        assert!(s.saturations() > 0);
        // in-range inputs on the same shape stay clean
        let u_ok = vec![1.0f32; 6 * 4];
        qres.forward_into(&u_ok, 6, &mut s);
        assert_eq!(s.saturations(), 0);
    }

    #[test]
    fn zero_input_gives_zero_features() {
        let (_, qres) = pair(5, 2, 0.3, 0.2, QFormat::q4_12());
        let u = vec![0.0f32; 9 * 2];
        let mut s = QuantForwardScratch::new(5, 2);
        qres.forward_into(&u, 9, &mut s);
        assert!(s.r_mat_raw().iter().all(|&r| r == 0));
        assert_eq!(s.saturations(), 0);
    }

    #[test]
    fn ensure_resizes_on_shape_change() {
        let mut s = QuantForwardScratch::new(4, 2);
        s.ensure(9, 3);
        assert_eq!(s.r_mat_raw().len(), 9 * 10);
        let (_, qres) = pair(9, 3, 0.2, 0.1, QFormat::q4_12());
        // forward_into itself ensures, so a wrongly-sized scratch is fine
        let mut s2 = QuantForwardScratch::new(2, 1);
        let u = vec![0.25f32; 8 * 3];
        qres.forward_into(&u, 8, &mut s2);
        assert_eq!(s2.r_mat_raw().len(), 9 * 10);
    }
}
