//! Width-selection sweep: measured deviation + analytic bound +
//! end-task accuracy + width-aware FPGA cost, per candidate Q-format.
//!
//! This is the co-design loop the paper runs by hand when it fixes the
//! FPGA word: for each candidate width, (1) run a reference workload
//! through the f32 and the quantized datapaths and measure the feature
//! deviation (absolute and in LSB units), (2) evaluate the analytic
//! budget (`quant::budget`) the deviation must stay under, (3) score the
//! end task with both datapaths (ridge layer trained on quantized
//! features — the quantization-aware protocol), and (4) price the width
//! on the Zynq via [`SystemModel::with_arith`] so Tables 9/11 become
//! width-aware. [`SweepReport::choose`] then picks the narrowest format
//! whose bound clears the tolerance with zero saturations.

use crate::coordinator::engine::{Engine, NativeEngine};
use crate::data::profiles::Profile;
use crate::data::synth;
use crate::dfr::mask::Mask;
use crate::dfr::reservoir::{Nonlinearity, Reservoir};
use crate::dfr::train::{ridge_phase, TrainConfig};
use crate::fpga::design::{DesignConfig, SystemModel};
use crate::fpga::resource::{Arith, ResourceUsage};
use crate::fpga::schedule::ShapeParams;
use crate::linalg::ridge::argmax;
use crate::util::prng::Pcg32;

use super::budget::{r_tilde_error_bound, BudgetInputs};
use super::engine::QuantEngine;
use super::fixed::QFormat;
use super::QuantConfig;

/// One candidate width's scorecard.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub format: QFormat,
    /// measured max |r̃_quant − r̃_f32| over the workload
    pub max_abs_dev: f32,
    pub mean_abs_dev: f32,
    /// max deviation in LSB units of this format (the "ulp-style" view)
    pub max_dev_lsb: f32,
    /// the analytic budget the deviation must stay under (+∞ = format
    /// cannot represent the workload)
    pub bound: f32,
    /// forward-pass range violations across the workload (budget is
    /// valid only at 0)
    pub saturations: u64,
    pub accuracy_f32: f64,
    pub accuracy_quant: f64,
    /// Zynq cost of the paper-scale design at this width
    pub resources: ResourceUsage,
    pub power_w: f32,
}

/// The whole sweep plus the f32 baseline cost for deltas.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub rows: Vec<SweepRow>,
    pub f32_resources: ResourceUsage,
    pub f32_power_w: f32,
}

impl SweepReport {
    /// Narrowest-first selection: the first row whose analytic bound is
    /// finite, at most `max_bound`, and whose run saturated nowhere.
    pub fn choose(&self, max_bound: f32) -> Option<&SweepRow> {
        self.rows
            .iter()
            .find(|r| r.bound.is_finite() && r.bound <= max_bound && r.saturations == 0)
    }

    /// GitHub-flavoured markdown table (docs / example output).
    pub fn markdown(&self) -> String {
        let mut rows: Vec<Vec<String>> = vec![vec![
            "f32".into(),
            "—".into(),
            "—".into(),
            "—".into(),
            "—".into(),
            format!("{:.3}", self.rows.first().map_or(0.0, |r| r.accuracy_f32)),
            format!("{}", self.f32_resources.lut),
            format!("{}", self.f32_resources.dsp),
            format!("{:.1}", self.f32_resources.bram36),
            format!("{:.3}", self.f32_power_w),
        ]];
        for r in &self.rows {
            rows.push(vec![
                r.format.name(),
                format!("{:.2e}", r.max_abs_dev),
                format!("{:.1}", r.max_dev_lsb),
                if r.bound.is_finite() {
                    format!("{:.2e}", r.bound)
                } else {
                    "∞ (overflow)".into()
                },
                format!("{}", r.saturations),
                format!("{:.3}", r.accuracy_quant),
                format!("{}", r.resources.lut),
                format!("{}", r.resources.dsp),
                format!("{:.1}", r.resources.bram36),
                format!("{:.3}", r.power_w),
            ]);
        }
        crate::util::bench::markdown_table(
            &[
                "datapath", "max dev", "dev (LSB)", "bound", "sat", "accuracy", "LUT", "DSP",
                "BRAM36", "power (W)",
            ],
            &rows,
        )
    }
}

/// Paper-scale anchor shape for the width-aware resource pricing
/// (jpvow: Nx=30, V=12, C=9, T=29 — the Table 9/11 workload).
fn anchor_shape() -> ShapeParams {
    ShapeParams::new(30, 12, 9, 29)
}

/// Run the sweep over `formats` (report rows keep the given order, so
/// pass narrowest-resolution-last if you want [`SweepReport::choose`]'s
/// narrowest-first semantics — the conventional order Q4.12, Q6.10,
/// Q8.8 ranks by *coarseness*, with `choose` picking the first viable).
pub fn error_budget_sweep(formats: &[QFormat], lut_log2_segments: u32, seed: u64) -> SweepReport {
    // reference workload: the mini synthetic profile — big enough for a
    // stable accuracy signal, small enough for tests
    let prof = Profile {
        name: "quant_sweep",
        n_v: 2,
        n_c: 2,
        train: 48,
        test: 24,
        t_min: 10,
        t_max: 14,
    };
    let mut ds = synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.15,
            ar: 0.35,
        },
        seed,
    );
    // FPGA front-ends scale inputs into the datapath word range (an
    // AXI-side shift, free in hardware); mirror it so the V-channel add
    // tree of even the narrow-range Q4.12 keeps saturation headroom.
    // Both datapaths see the same scaled series, so the comparison and
    // the end-task accuracy are unaffected.
    for s in ds.train.iter_mut().chain(ds.test.iter_mut()) {
        for u in s.u.iter_mut() {
            *u *= 0.25;
        }
    }
    let nx = 8usize;
    let (p, q) = (0.25f32, 0.2f32);
    let f = Nonlinearity::Linear { alpha: 1.0 };
    let mut rng = Pcg32::new(seed, 0x0_9_F0);
    let mask = Mask::random(nx, prof.n_v, &mut rng);
    let res = Reservoir {
        mask: mask.clone(),
        p,
        q,
        f,
    };
    // quantization-aware output layer: ridge-train on the f32 features
    // (the engines share the solved layer; QuantEngine requantizes it)
    let cfg = TrainConfig {
        nx,
        ..Default::default()
    };
    let sol = ridge_phase(&ds, &res, &cfg);

    // workload magnitudes for the budget (f32 reference trajectories)
    let mut x_max = 0.0f32;
    let mut u_max = 0.0f32;
    let mut t_max = 0usize;
    for s in ds.test.iter().chain(&ds.train) {
        let h = res.forward_history(&s.u, s.t);
        for &x in &h.xs {
            x_max = x_max.max(x.abs());
        }
        for &u in &s.u {
            u_max = u_max.max(u.abs());
        }
        t_max = t_max.max(s.t);
    }
    let j_max = prof.n_v as f32 * u_max;
    let f_max = f.abs_bound(x_max + j_max);

    let native = NativeEngine::with_nonlinearity(nx, prof.n_c, f);
    let acc_f32 = engine_accuracy(&native, &ds.test, &mask, p, q, &sol.w_tilde);

    let f32_model = SystemModel::new(anchor_shape(), DesignConfig::Standard);
    let f32_resources = f32_model.total_resources();
    let f32_power_w = f32_model.power_w();

    let rows = formats
        .iter()
        .map(|&fmt| {
            let qcfg = QuantConfig {
                arith: super::fixed::QArith::new(fmt),
                lut_log2_segments,
            };
            let qeng = QuantEngine::with_config(nx, prof.n_c, f, qcfg);
            let mut max_dev = 0.0f32;
            let mut dev_sum = 0.0f64;
            let mut dev_n = 0usize;
            let mut sats = 0u64;
            for s in &ds.test {
                let fq = qeng.features(s, &mask, p, q).expect("quant features");
                sats += qeng.last_saturations();
                let ff = native.features(s, &mask, p, q).expect("native features");
                for (a, b) in fq.iter().zip(&ff) {
                    let d = (a - b).abs();
                    max_dev = max_dev.max(d);
                    dev_sum += f64::from(d);
                    dev_n += 1;
                }
            }
            let eps_f = {
                // a throwaway LUT only to read its measured sup-error
                super::lut::PwlLut::new(f, qcfg.arith, lut_log2_segments).max_err()
            };
            let bound = r_tilde_error_bound(
                fmt,
                &BudgetInputs {
                    p,
                    q,
                    lf: f.lipschitz_bound(),
                    eps_f,
                    t: t_max,
                    nx,
                    v: prof.n_v,
                    x_max,
                    u_max,
                    f_max,
                },
            );
            let acc_q = engine_accuracy(&qeng, &ds.test, &mask, p, q, &sol.w_tilde);
            let model = SystemModel::with_arith(
                anchor_shape(),
                DesignConfig::Standard,
                Arith::Fixed { bits: fmt.bits },
            );
            SweepRow {
                format: fmt,
                max_abs_dev: max_dev,
                mean_abs_dev: (dev_sum / dev_n.max(1) as f64) as f32,
                max_dev_lsb: max_dev / fmt.lsb(),
                bound,
                saturations: sats,
                accuracy_f32: acc_f32,
                accuracy_quant: acc_q,
                resources: model.total_resources(),
                power_w: model.power_w(),
            }
        })
        .collect();

    SweepReport {
        rows,
        f32_resources,
        f32_power_w,
    }
}

fn engine_accuracy(
    eng: &dyn Engine,
    test: &[crate::data::dataset::Sample],
    mask: &Mask,
    p: f32,
    q: f32,
    w_tilde: &[f32],
) -> f64 {
    let mut correct = 0usize;
    for s in test {
        let scores = eng.infer(s, mask, p, q, w_tilde).expect("infer");
        if argmax(&scores) == s.label {
            correct += 1;
        }
    }
    correct as f64 / test.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_rows_and_width_monotonicity() {
        let formats = [QFormat::q4_12(), QFormat::q6_10(), QFormat::q8_8()];
        let rep = error_budget_sweep(&formats, 6, 0xC0DE);
        assert_eq!(rep.rows.len(), 3);
        for r in &rep.rows {
            assert_eq!(r.saturations, 0, "{} saturated", r.format.name());
            assert!(r.bound.is_finite(), "{}", r.format.name());
            assert!(
                r.max_abs_dev <= r.bound,
                "{}: dev {} vs bound {}",
                r.format.name(),
                r.max_abs_dev,
                r.bound
            );
        }
        // more fractional bits → smaller deviation (Q4.12 < Q6.10 < Q8.8)
        assert!(rep.rows[0].max_abs_dev < rep.rows[2].max_abs_dev);
        // all 16-bit formats share the same hardware cost, below f32's
        assert_eq!(rep.rows[0].resources.dsp, rep.rows[1].resources.dsp);
        assert!(rep.rows[0].resources.lut < rep.f32_resources.lut);
        assert!(rep.rows[0].power_w < rep.f32_power_w);
    }

    #[test]
    fn finest_format_preserves_end_task_accuracy() {
        let rep = error_budget_sweep(&[QFormat::q4_12()], 6, 0xC0DE);
        let r = &rep.rows[0];
        assert!(
            // ≤ 2 flipped samples of 24: Q4.12's ~1e-4 feature deviation
            // only flips near-zero-margin predictions
            (r.accuracy_quant - r.accuracy_f32).abs() <= 0.1,
            "quant {} vs f32 {}",
            r.accuracy_quant,
            r.accuracy_f32
        );
    }

    #[test]
    fn choose_prefers_the_first_viable_format() {
        let formats = [QFormat::q4_12(), QFormat::q6_10()];
        let rep = error_budget_sweep(&formats, 6, 0xC0DE);
        let chosen = rep.choose(1.0).expect("a format clears a loose tolerance");
        assert_eq!(chosen.format, QFormat::q4_12());
        assert!(rep.choose(1e-12).is_none(), "no format clears 1e-12");
        let md = rep.markdown();
        assert!(md.contains("Q4.12") && md.contains("f32"), "{md}");
    }
}
