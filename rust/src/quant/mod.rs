//! Bit-accurate fixed-point (quantized) DFR engine + error budgeting.
//!
//! The paper's hardware claims (1/13 time, 1/27 power on the Zynq-7000)
//! rest on a fixed-point FPGA datapath, but the rest of this repo
//! computes in f32 — the `fpga` module models *when* the hardware
//! computes, this module models *what* it computes:
//!
//! * [`fixed`] — runtime Q-format words ([`QFormat`], [`QArith`]) with
//!   HLS rounding/overflow modes (`AP_RND`/`AP_TRN`, `AP_SAT`/`AP_WRAP`)
//!   and single-rounding product semantics;
//! * [`lut`] — the piecewise-linear LUT nonlinearity HLS instantiates
//!   (bit-slice segment index, integer interpolation, measured
//!   sup-error);
//! * [`reservoir`] — the quantized masking → cascade → DPRR forward pass
//!   with a wide integer accumulator and per-pass saturation counting;
//! * [`budget`] — the analytic worst-case error bound the equivalence
//!   tests assert (validated by `python/tests/quant_mirror.py`);
//! * [`engine`] — [`QuantEngine`], a drop-in
//!   [`coordinator::Engine`](crate::coordinator::Engine) so quantized
//!   serving runs behind the sharded server unchanged (zero
//!   steady-state allocations, `tests/zero_alloc.rs`);
//! * [`sweep`] — the width-selection sweep: measured deviation vs
//!   analytic bound vs end-task accuracy vs width-aware Zynq cost
//!   (`fpga::resource::Arith`), per candidate format.
//!
//! Motivated by the hardware-friendly quantization argument of
//! "Modular DFR" (arXiv:2307.11094) and FPGA reservoir practice in
//! Penkovsky et al. (arXiv:1805.03033). See DESIGN.md §12.

pub mod budget;
pub mod engine;
pub mod fixed;
pub mod lut;
pub mod reservoir;
pub mod sweep;

pub use budget::{r_tilde_error_bound, score_error_bound, BudgetInputs};
pub use engine::QuantEngine;
pub use fixed::{Overflow, QArith, QFormat, Rounding};
pub use lut::PwlLut;
pub use reservoir::{QuantForwardScratch, QuantReservoir};
pub use sweep::{error_budget_sweep, SweepReport, SweepRow};

/// Engine-level quantization knobs: the datapath word + the LUT size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    pub arith: QArith,
    /// log₂ of the PWL-LUT segment count (6 → 64 segments ≈ one BRAM
    /// half for the table)
    pub lut_log2_segments: u32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            arith: QArith::new(QFormat::q4_12()),
            lut_log2_segments: 6,
        }
    }
}

impl QuantConfig {
    pub fn with_format(fmt: QFormat) -> Self {
        QuantConfig {
            arith: QArith::new(fmt),
            ..Default::default()
        }
    }
}
