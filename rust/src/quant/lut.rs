//! Piecewise-linear LUT nonlinearity — what HLS instantiates for `f`.
//!
//! An FPGA datapath does not call `tanh`/`powf`; it reads a small BRAM
//! table of segment endpoints and linearly interpolates. This module
//! models exactly that: `2^k` equal-width segments spanning the **whole
//! representable range** of the Q-format (so the segment index is a bit
//! slice of the raw input — no comparator tree), endpoint values stored
//! as raw words, and an integer interpolation
//! `y = y₀ + ((y₁ − y₀)·rem) >> seg_shift` with one rounding.
//!
//! For `Linear { alpha: 1 }` (the paper's evaluation nonlinearity) the
//! interpolation is exact to the LSB, so the quantized reservoir pays no
//! nonlinearity-approximation cost on the golden fixtures; for
//! `Tanh`/`MackeyGlass` the construction-time measured sup-error
//! ([`PwlLut::max_err`]) feeds the error budget directly — a measured
//! number, not an assumption.

use crate::dfr::reservoir::Nonlinearity;

use super::fixed::{QArith, Rounding};

/// An integer piecewise-linear approximation of a scalar nonlinearity
/// over the full Q-format range.
#[derive(Clone, Debug)]
pub struct PwlLut {
    arith: QArith,
    /// log₂(segment width in raw units) = bits − log₂(segments)
    seg_shift: u32,
    lo_raw: i64,
    /// segment endpoint values (raw), `segments + 1` entries
    table: Vec<i32>,
    /// measured sup |LUT(x) − f(x)| over the range (dense sampling at
    /// construction) — the ε_f term of the error budget
    max_err: f32,
}

impl PwlLut {
    /// Build a `2^log2_segments`-segment table for `f`. BRAM cost is
    /// `segments + 1` words; `log2_segments` must not exceed the word
    /// width (a segment spans at least one raw unit).
    pub fn new(f: Nonlinearity, arith: QArith, log2_segments: u32) -> Self {
        assert!(
            log2_segments >= 1 && log2_segments <= arith.fmt.bits,
            "segment count must be in [2, 2^bits]"
        );
        let seg_shift = arith.fmt.bits - log2_segments;
        let lo_raw = arith.fmt.min_raw();
        let segments = 1usize << log2_segments;
        let lsb = arith.fmt.lsb();
        let table: Vec<i32> = (0..=segments)
            .map(|i| {
                let node_raw = lo_raw + ((i as i64) << seg_shift);
                arith.quantize(f.eval(node_raw as f32 * lsb))
            })
            .collect();
        let mut lut = PwlLut {
            arith,
            seg_shift,
            lo_raw,
            table,
            max_err: 0.0,
        };
        // measure the approximation sup-error: 8 probes per segment
        let mut max_err = 0.0f32;
        for i in 0..segments {
            for j in 0..8u32 {
                let raw = lo_raw
                    + ((i as i64) << seg_shift)
                    + ((u64::from(j) << seg_shift) / 8) as i64;
                let x = raw as f32 * lsb;
                let err = (lut.eval_value(raw as i32) - f.eval(x)).abs();
                if err.is_finite() && err > max_err {
                    max_err = err;
                }
            }
        }
        lut.max_err = max_err;
        lut
    }

    /// Measured sup-error of the approximation (error-budget input).
    pub fn max_err(&self) -> f32 {
        self.max_err
    }

    /// Table words (BRAM sizing).
    pub fn words(&self) -> usize {
        self.table.len()
    }

    /// Evaluate at a raw input (must be a valid word of the format).
    #[inline]
    pub fn eval(&self, x_raw: i32) -> i32 {
        // the offset is a plain bit-slice: idx = high bits, rem = low bits
        let off = (i64::from(x_raw) - self.lo_raw) as u64;
        let segments = self.table.len() - 1;
        let mut idx = (off >> self.seg_shift) as usize;
        if idx >= segments {
            idx = segments - 1; // x == max_raw lands in the top segment
        }
        let rem = (off - ((idx as u64) << self.seg_shift)) as i64;
        let y0 = i64::from(self.table[idx]);
        if self.seg_shift == 0 {
            // one raw unit per segment: the node value IS the answer
            return self.arith.clamp(y0);
        }
        let y1 = i64::from(self.table[idx + 1]);
        let half = match self.arith.round {
            Rounding::Nearest => 1i64 << (self.seg_shift - 1),
            Rounding::Floor => 0,
        };
        let y = y0 + (((y1 - y0) * rem + half) >> self.seg_shift);
        self.arith.clamp(y)
    }

    /// Evaluate and dequantize (tests / error measurement).
    pub fn eval_value(&self, x_raw: i32) -> f32 {
        self.arith.dequantize(self.eval(x_raw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fixed::QFormat;

    fn arith() -> QArith {
        QArith::new(QFormat::q4_12())
    }

    #[test]
    fn linear_lut_is_exact_off_the_top_segment() {
        let a = arith();
        let lut = PwlLut::new(Nonlinearity::Linear { alpha: 1.0 }, a, 6);
        // identity: LUT(x) == x exactly everywhere below the top segment
        // (whose upper node's true value max+lsb saturates by one raw
        // unit, shaving the interpolated values there by ≤ 1 raw)
        for raw in [-32768i32, -12345, -1, 0, 1, 4095, 20000, 31743] {
            assert_eq!(lut.eval(raw), raw, "raw {raw}");
        }
        // top segment: within one raw unit of exact
        assert!((i64::from(lut.eval(32255)) - 32255).abs() <= 1);
        assert!(lut.max_err() <= 2.0 * a.fmt.lsb(), "{}", lut.max_err());
    }

    #[test]
    fn scaled_linear_lut_tracks_alpha() {
        let a = arith();
        let lut = PwlLut::new(Nonlinearity::Linear { alpha: 0.5 }, a, 6);
        for v in [-6.0f32, -1.25, 0.0, 0.7, 3.5] {
            let raw = a.quantize(v);
            let got = lut.eval_value(raw);
            assert!((got - 0.5 * v).abs() <= 2.0 * a.fmt.lsb(), "{v}: {got}");
        }
    }

    #[test]
    fn tanh_lut_error_shrinks_with_segments() {
        let a = arith();
        let coarse = PwlLut::new(Nonlinearity::Tanh, a, 4);
        let fine = PwlLut::new(Nonlinearity::Tanh, a, 8);
        assert!(fine.max_err() < coarse.max_err());
        // 256 segments over [-8, 8): chord error of tanh on a 1/16-wide
        // segment is ~1e-4, plus quantization
        assert!(fine.max_err() < 5e-3, "{}", fine.max_err());
        for v in [-3.0f32, -0.4, 0.0, 0.4, 3.0] {
            let got = fine.eval_value(a.quantize(v));
            assert!((got - v.tanh()).abs() <= fine.max_err() + a.fmt.lsb());
        }
    }

    #[test]
    fn mackey_glass_lut_bounded() {
        let a = arith();
        let f = Nonlinearity::MackeyGlass { eta: 0.9, p_exp: 2.0 };
        let lut = PwlLut::new(f, a, 8);
        for v in [-7.9f32, -1.0, 0.0, 1.0, 7.9] {
            let got = lut.eval_value(a.quantize(v));
            assert!((got - f.eval(v)).abs() <= lut.max_err() + a.fmt.lsb(), "{v}");
        }
        assert_eq!(lut.words(), 257);
    }

    #[test]
    fn extreme_inputs_stay_in_range() {
        let a = arith();
        let lut = PwlLut::new(Nonlinearity::Linear { alpha: 1.0 }, a, 6);
        let lo = a.fmt.min_raw() as i32;
        let hi = a.fmt.max_raw() as i32;
        for raw in [lo, lo + 1, hi - 1, hi] {
            let y = i64::from(lut.eval(raw));
            assert!(y >= a.fmt.min_raw() && y <= a.fmt.max_raw());
        }
    }
}
