//! Analytic error budget of the quantized datapath.
//!
//! Answers "how far can the Q-format forward pass drift from the f32
//! reference?" with a worst-case first-order bound — the number the
//! equivalence tests assert against and the width-selection sweep ranks
//! formats by. Validated against an exact integer mirror of the datapath
//! in `python/tests/quant_mirror.py` (observed margins 2–40× on the
//! golden-fixture configurations).
//!
//! # Derivation
//!
//! Let δ = 2⁻ᶠ be the LSB and write e(·) for worst-case absolute error
//! vs exact real arithmetic over f32 inputs. Per forward step:
//!
//! * input quantization: e(u) ≤ δ/2, so the ±1 add tree gives
//!   e(j) ≤ V·δ/2 (the i64 accumulation itself is exact);
//! * node update `x_n = p·f(j + x_n) + q·x_{n−1}` accrues
//!   - `p·(ε_f + L_f·(e(j) + e(x)))` — LUT sup-error ε_f (measured at
//!     construction) plus input error through f's Lipschitz bound L_f,
//!   - `(|f|_max + x_max)·δ/2` — quantization of p and q themselves,
//!   - `δ` — the two product rescales (half-LSB each),
//!   - `|q|·e(x_{n−1})` — the cascade recurrence *within* the step;
//! * the DPRR wide accumulation is exact; normalization adds the
//!   reciprocal's resolution (`x_max²·T·2⁻²ᶠ/2`) and one final rescale
//!   (δ/2); each accumulated product contributes `2·x_max·e(x) + e(x)²`.
//!
//! The within-step cascade and the across-step state recurrences are
//! iterated *numerically* (T × Nx scalar steps) rather than solved in
//! closed form — for `p·L_f + |q| < 1` they converge geometrically; when
//! the contraction fails, or when the workload's dynamic range does not
//! fit the format's integer bits (saturation voids a linear error
//! model), the bound is `+∞`, which the sweep reads as "this format is
//! unusable here".

use super::fixed::QFormat;

/// Workload description the bound is evaluated against. The magnitudes
/// (`x_max`, `u_max`, `f_max`) come from the f32 reference trajectory —
/// the bound is per-workload, which is what makes it tight enough to be
/// useful (a range-free bound would have to assume full-scale signals).
#[derive(Clone, Copy, Debug)]
pub struct BudgetInputs {
    pub p: f32,
    pub q: f32,
    /// Lipschitz bound of the nonlinearity
    /// ([`Nonlinearity::lipschitz_bound`](crate::dfr::reservoir::Nonlinearity::lipschitz_bound))
    pub lf: f32,
    /// measured LUT sup-error ([`PwlLut::max_err`](super::lut::PwlLut::max_err))
    pub eps_f: f32,
    pub t: usize,
    pub nx: usize,
    pub v: usize,
    /// max |x(k)_n| of the f32 reference trajectory
    pub x_max: f32,
    /// max |u| of the series
    pub u_max: f32,
    /// max |f(arg)| over the trajectory (e.g. `f.abs_bound(x_max + j_max)`)
    pub f_max: f32,
}

/// Worst-case |r̃_quant − r̃_f32| per element, or `+∞` when the format
/// cannot represent the workload (range overflow or no contraction).
pub fn r_tilde_error_bound(fmt: QFormat, inp: &BudgetInputs) -> f32 {
    let lsb = fmt.lsb();
    let half = 0.5 * lsb;
    let (ap, aq) = (inp.p.abs(), inp.q.abs());
    // range check: every word the datapath forms must fit the format
    // (5% headroom for the quantization error itself); saturation breaks
    // the linear error model, so an out-of-range workload gets +∞
    let j_max = inp.v as f32 * inp.u_max;
    let word_max = inp
        .x_max
        .max(j_max)
        .max(j_max + inp.x_max)
        .max(inp.f_max);
    if word_max * 1.05 > fmt.max_value() {
        return f32::INFINITY;
    }
    if ap * inp.lf + aq >= 1.0 {
        return f32::INFINITY;
    }
    let e_j = inp.v as f32 * half;
    let mut e_state = 0.0f32;
    for _ in 0..inp.t {
        let mut e_prev_node = e_state;
        let mut worst = 0.0f32;
        for _ in 0..inp.nx {
            let e_n = ap * inp.lf * (e_j + e_state)
                + ap * inp.eps_f
                + (inp.f_max + inp.x_max) * half // p/q quantization
                + lsb // two product rescales, half-LSB each
                + aq * e_prev_node;
            e_prev_node = e_n;
            if e_n > worst {
                worst = e_n;
            }
        }
        e_state = worst;
        if !e_state.is_finite() || e_state > 1e6 {
            return f32::INFINITY;
        }
    }
    let inv_t_term =
        inp.x_max * inp.x_max * inp.t as f32 * (-2.0 * fmt.frac as f64).exp2() as f32 / 2.0;
    2.0 * inp.x_max * e_state + e_state * e_state + inv_t_term + half
}

/// Evaluate the error budget for a workload described only by its shape
/// and input range — deriving the trajectory magnitudes (`x_max`,
/// `f_max`) from the cascade's contraction fixed point instead of a
/// recorded f32 reference trajectory.
///
/// This is the serve-time **recalibration** entry point
/// (`QuantEngine::recalibrate`): when the online reservoir optimizer
/// moves (p, q), the reference trajectory of the *new* parameters does
/// not exist yet, so the bound conservatively solves
/// `x = |p|·max|f(j_max + x)| + |q|·x` for the state envelope (the
/// steady-state majorant of Eq. 14 under the |f| envelope). Divergence
/// of that iteration — or any of [`r_tilde_error_bound`]'s own +∞
/// conditions (range overflow, `p·L_f + |q| ≥ 1`) — returns +∞, which
/// the engine reads as "fall back to f32".
#[allow(clippy::too_many_arguments)] // the budget's natural arity
pub fn budget_for_workload(
    fmt: QFormat,
    f: crate::dfr::reservoir::Nonlinearity,
    p: f32,
    q: f32,
    nx: usize,
    v: usize,
    t: usize,
    u_max: f32,
    eps_f: f32,
) -> f32 {
    let (ap, aq) = (p.abs(), q.abs());
    let lf = f.lipschitz_bound();
    if ap * lf + aq >= 1.0 {
        return f32::INFINITY;
    }
    let j_max = v as f32 * u_max;
    // fixed point of the state-magnitude recurrence, iterated to
    // convergence; for |p|·L_f + |q| < 1 with the envelopes above this
    // is a contraction for Linear/Tanh and majorized for Mackey–Glass.
    // A slow contraction (rate just under 1) that has not converged
    // within the iteration budget would UNDER-estimate the envelope and
    // yield an unsound finite bound — treat it as unusable instead.
    let mut x_max = 0.0f32;
    let mut converged = false;
    for _ in 0..512 {
        let next = ap * f.abs_bound(j_max + x_max) + aq * x_max;
        if !next.is_finite() || next > 1e6 {
            return f32::INFINITY;
        }
        let done = (next - x_max).abs() <= 1e-6 * next.abs().max(1e-6);
        x_max = next;
        if done {
            converged = true;
            break;
        }
    }
    if !converged {
        return f32::INFINITY;
    }
    let f_max = f.abs_bound(j_max + x_max);
    r_tilde_error_bound(
        fmt,
        &BudgetInputs {
            p,
            q,
            lf,
            eps_f,
            t,
            nx,
            v,
            x_max,
            u_max,
            f_max,
        },
    )
}

/// Worst-case error of one quantized ridge score `Σ_k w_k·r̃_k` given a
/// per-element feature bound `r_bound` (from [`r_tilde_error_bound`]):
/// weights are quantized to δ/2, features carry `r_bound`, the wide MAC
/// is exact, and one rescale closes the sum.
pub fn score_error_bound(fmt: QFormat, s: usize, w_max: f32, r_max: f32, r_bound: f32) -> f32 {
    let half = 0.5 * fmt.lsb();
    if !r_bound.is_finite() {
        return f32::INFINITY;
    }
    s as f32 * (w_max * r_bound + (r_max + r_bound) * half) + half
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> BudgetInputs {
        BudgetInputs {
            p: 0.2,
            q: 0.15,
            lf: 1.0,
            eps_f: 0.0,
            t: 12,
            nx: 5,
            v: 2,
            x_max: 0.2,
            u_max: 1.05,
            f_max: 2.5,
        }
    }

    #[test]
    fn bound_is_finite_and_small_in_the_stable_region() {
        let b = r_tilde_error_bound(QFormat::q4_12(), &base());
        assert!(b.is_finite());
        // python/tests/quant_mirror.py measures ~1.3e-4 deviation and a
        // ~3.2e-4 bound on this configuration
        assert!(b > 1e-5 && b < 2e-3, "{b}");
    }

    #[test]
    fn bound_grows_with_coarser_formats() {
        let inp = base();
        let fine = r_tilde_error_bound(QFormat::q4_12(), &inp);
        let mid = r_tilde_error_bound(QFormat::q6_10(), &inp);
        let coarse = r_tilde_error_bound(QFormat::q8_8(), &inp);
        assert!(fine < mid && mid < coarse, "{fine} {mid} {coarse}");
    }

    #[test]
    fn bound_infinite_outside_contraction() {
        let inp = BudgetInputs {
            p: 0.7,
            q: 0.5,
            ..base()
        };
        assert!(r_tilde_error_bound(QFormat::q4_12(), &inp).is_infinite());
    }

    #[test]
    fn bound_infinite_when_range_overflows() {
        // V=12 channels of |u| ≤ 1.05 → j up to 12.6, beyond Q4.12's ±8
        let inp = BudgetInputs {
            v: 12,
            ..base()
        };
        assert!(r_tilde_error_bound(QFormat::q4_12(), &inp).is_infinite());
        // Q6.10 (±32) absorbs it
        assert!(r_tilde_error_bound(QFormat::q6_10(), &inp).is_finite());
    }

    #[test]
    fn workload_budget_matches_regimes() {
        use crate::dfr::reservoir::Nonlinearity;
        let lin = Nonlinearity::Linear { alpha: 1.0 };
        // stable region, modest range → finite (and at least as large as
        // the trajectory-informed bound at the same shape, since the
        // fixed-point x_max majorizes any realized trajectory)
        let b = budget_for_workload(QFormat::q4_12(), lin, 0.2, 0.15, 5, 2, 12, 1.05, 0.0);
        assert!(b.is_finite() && b > 0.0, "{b}");
        let informed = r_tilde_error_bound(QFormat::q4_12(), &base());
        assert!(b >= informed, "envelope bound {b} below informed {informed}");
        // contraction violated → +∞
        assert!(budget_for_workload(QFormat::q4_12(), lin, 0.8, 0.5, 5, 2, 12, 1.05, 0.0)
            .is_infinite());
        // contraction rate 0.99: the envelope x* = 0.6·0.05/0.01 = 3
        // fits Q6.10 comfortably, but the iteration cannot reach it
        // inside the budget (0.99^512 ≫ 1e-6) — an under-converged
        // x_max would yield an unsound finite bound, so the
        // slow-contraction region must report +∞ on the convergence
        // path itself, not just via range overflow
        assert!(budget_for_workload(QFormat::q6_10(), lin, 0.6, 0.39, 5, 1, 12, 0.05, 0.0)
            .is_infinite());
        // range overflow (V·u_max beyond Q4.12's ±8) → +∞, wider format
        // absorbs it
        assert!(budget_for_workload(QFormat::q4_12(), lin, 0.2, 0.15, 5, 12, 12, 1.05, 0.0)
            .is_infinite());
        assert!(budget_for_workload(QFormat::q6_10(), lin, 0.2, 0.15, 5, 12, 12, 1.05, 0.0)
            .is_finite());
    }

    #[test]
    fn score_bound_scales_with_dimension() {
        let f = QFormat::q4_12();
        let a = score_error_bound(f, 31, 0.5, 2.0, 1e-4);
        let b = score_error_bound(f, 931, 0.5, 2.0, 1e-4);
        assert!(b > a);
        assert!(score_error_bound(f, 10, 1.0, 1.0, f32::INFINITY).is_infinite());
    }
}
