//! Echo-state-network baseline in the spirit of TWIESN (Tanisaro &
//! Heidemann [22], Table 6): a fixed random recurrent reservoir with
//! spectral-radius scaling; per-timestep states are mean-pooled and
//! classified by the same in-place ridge regression as the DFR — which
//! keeps the comparison about the *reservoir*, not the readout.

use crate::data::dataset::{accuracy, Dataset, Sample};
use crate::linalg::ridge::{RidgeAccumulator, RidgeMethod, RidgeSolution};
use crate::util::prng::Pcg32;

/// ESN hyper-parameters.
#[derive(Clone, Debug)]
pub struct EsnConfig {
    pub n_units: usize,
    pub spectral_radius: f32,
    pub input_scale: f32,
    pub leak: f32,
    pub connectivity: f32,
    pub beta: f32,
    pub seed: u64,
}

impl Default for EsnConfig {
    fn default() -> Self {
        EsnConfig {
            n_units: 60,
            spectral_radius: 0.9,
            input_scale: 0.5,
            leak: 0.3,
            connectivity: 0.2,
            beta: 1e-2,
            seed: 0xE51,
        }
    }
}

/// Fixed random reservoir + ridge readout.
pub struct Esn {
    pub cfg: EsnConfig,
    /// recurrent weights, row-major n×n (sparse entries, dense storage)
    w: Vec<f32>,
    /// input weights n×V
    w_in: Vec<f32>,
    n: usize,
    v: usize,
    readout: Option<RidgeSolution>,
}

impl Esn {
    pub fn new(v: usize, cfg: EsnConfig) -> Self {
        let n = cfg.n_units;
        let mut rng = Pcg32::new(cfg.seed, 0xE5);
        let mut w: Vec<f32> = (0..n * n)
            .map(|_| {
                if rng.uniform() < cfg.connectivity {
                    rng.normal()
                } else {
                    0.0
                }
            })
            .collect();
        // scale to the target spectral radius via power iteration
        let rho = spectral_radius_estimate(&w, n, &mut rng);
        if rho > 1e-6 {
            let s = cfg.spectral_radius / rho;
            for x in w.iter_mut() {
                *x *= s;
            }
        }
        let w_in = (0..n * v)
            .map(|_| cfg.input_scale * rng.normal())
            .collect();
        Esn {
            cfg,
            w,
            w_in,
            n,
            v,
            readout: None,
        }
    }

    /// Mean-pooled state features [x̄, 1] for one series.
    pub fn features(&self, s: &Sample) -> Vec<f32> {
        let n = self.n;
        let v = self.v;
        let mut x = vec![0.0f32; n];
        let mut pool = vec![0.0f32; n];
        let mut xn = vec![0.0f32; n];
        for k in 0..s.t {
            let u = s.row(k, v);
            for i in 0..n {
                let mut acc = 0.0f32;
                let row = &self.w[i * n..(i + 1) * n];
                for (wx, xv) in row.iter().zip(&x) {
                    acc += wx * xv;
                }
                let rin = &self.w_in[i * v..(i + 1) * v];
                for (wi, uv) in rin.iter().zip(u) {
                    acc += wi * uv;
                }
                xn[i] = (1.0 - self.cfg.leak) * x[i] + self.cfg.leak * acc.tanh();
            }
            x.copy_from_slice(&xn);
            for (p, xv) in pool.iter_mut().zip(&x) {
                *p += xv;
            }
        }
        let inv_t = 1.0 / s.t.max(1) as f32;
        let mut feat: Vec<f32> = pool.iter().map(|p| p * inv_t).collect();
        feat.push(1.0);
        feat
    }

    /// Fit the ridge readout on the training split.
    pub fn fit(&mut self, ds: &Dataset) {
        let mut acc = RidgeAccumulator::new(self.n + 1, ds.n_c);
        for s in &ds.train {
            acc.accumulate(&self.features(s), s.label);
        }
        self.readout = Some(acc.solve(self.cfg.beta, RidgeMethod::Cholesky1d));
    }

    pub fn predict(&self, s: &Sample) -> usize {
        let sol = self.readout.as_ref().expect("fit first");
        sol.predict_class(&self.features(s))
    }
}

fn spectral_radius_estimate(w: &[f32], n: usize, rng: &mut Pcg32) -> f32 {
    // random matrices often have a complex dominant eigenpair, which makes
    // plain power iteration oscillate; iterate long and average the last
    // norms for a stable modulus estimate
    let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let mut lambdas = Vec::new();
    for _ in 0..200 {
        let mut nv = vec![0.0f32; n];
        for i in 0..n {
            let row = &w[i * n..(i + 1) * n];
            nv[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        let lambda = nv.iter().map(|x| x * x).sum::<f32>().sqrt();
        if lambda < 1e-12 {
            return 0.0;
        }
        lambdas.push(lambda);
        for x in nv.iter_mut() {
            *x /= lambda;
        }
        v = nv;
    }
    // geometric mean of the trailing window damps the oscillation
    let tail = &lambdas[lambdas.len().saturating_sub(32)..];
    let log_mean: f32 = tail.iter().map(|l| l.ln()).sum::<f32>() / tail.len() as f32;
    log_mean.exp()
}

/// Train + evaluate test accuracy.
pub fn evaluate(ds: &Dataset, cfg: EsnConfig) -> f64 {
    let mut esn = Esn::new(ds.n_v, cfg);
    esn.fit(ds);
    let preds: Vec<usize> = ds.test.iter().map(|s| esn.predict(s)).collect();
    accuracy(&preds, &ds.test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    #[test]
    fn spectral_radius_scaled() {
        let cfg = EsnConfig {
            n_units: 40,
            ..Default::default()
        };
        let esn = Esn::new(3, cfg.clone());
        let mut rng = Pcg32::seed(1);
        let rho = spectral_radius_estimate(&esn.w, esn.n, &mut rng);
        assert!(
            (rho - cfg.spectral_radius).abs() < 0.15,
            "rho {rho} target {}",
            cfg.spectral_radius
        );
    }

    #[test]
    fn learns_separable_toy() {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 60,
            test: 40,
            t_min: 15,
            t_max: 20,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.25,
                freq_sep: 0.2,
                ar: 0.3,
            },
            5,
        );
        let acc = evaluate(&ds, EsnConfig::default());
        assert!(acc > 0.75, "ESN accuracy {acc}");
    }

    #[test]
    fn states_bounded_by_tanh_and_leak() {
        let esn = Esn::new(2, EsnConfig::default());
        let s = Sample {
            u: vec![5.0; 2 * 50],
            t: 50,
            label: 0,
        };
        let f = esn.features(&s);
        assert!(f.iter().all(|x| x.is_finite() && x.abs() <= 1.5));
    }
}
