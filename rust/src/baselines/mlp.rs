//! Multi-layer perceptron baseline (Table 6 "MLP", after Wang et al.
//! [23]): flattened (padded) series → two hidden ReLU layers → softmax,
//! trained with SGD + momentum from scratch.

use crate::data::dataset::{accuracy, Dataset};
use crate::util::prng::Pcg32;

/// MLP hyper-parameters.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig {
            hidden: 64,
            epochs: 30,
            lr: 0.01,
            momentum: 0.9,
            seed: 0x317,
        }
    }
}

/// A trained 2-hidden-layer MLP.
pub struct Mlp {
    pub d_in: usize,
    pub n_c: usize,
    pub hidden: usize,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w3: Vec<f32>,
    b3: Vec<f32>,
}

fn matvec(w: &[f32], x: &[f32], b: &[f32], out: &mut [f32]) {
    let d = x.len();
    for (i, o) in out.iter_mut().enumerate() {
        let row = &w[i * d..(i + 1) * d];
        *o = b[i] + row.iter().zip(x).map(|(w, x)| w * x).sum::<f32>();
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

impl Mlp {
    /// Flatten a sample into the fixed input window (pad/truncate to
    /// `d_in` = t_fix × V).
    fn flatten(&self, u: &[f32]) -> Vec<f32> {
        let mut x = vec![0.0f32; self.d_in];
        let n = u.len().min(self.d_in);
        x[..n].copy_from_slice(&u[..n]);
        x
    }

    pub fn forward(&self, u: &[f32]) -> Vec<f32> {
        let x = self.flatten(u);
        let mut h1 = vec![0.0f32; self.hidden];
        matvec(&self.w1, &x, &self.b1, &mut h1);
        relu(&mut h1);
        let mut h2 = vec![0.0f32; self.hidden];
        matvec(&self.w2, &h1, &self.b2, &mut h2);
        relu(&mut h2);
        let mut z = vec![0.0f32; self.n_c];
        matvec(&self.w3, &h2, &self.b3, &mut z);
        crate::dfr::backprop::softmax_inplace(&mut z);
        z
    }

    pub fn predict(&self, u: &[f32]) -> usize {
        crate::linalg::ridge::argmax(&self.forward(u))
    }
}

/// Train on a dataset; the input window is the dataset's T_max.
pub fn train_mlp(ds: &Dataset, cfg: &MlpConfig) -> Mlp {
    let d_in = ds.t_max() * ds.n_v;
    let h = cfg.hidden;
    let c = ds.n_c;
    let mut rng = Pcg32::new(cfg.seed, 0x313);
    let glorot = |fan_in: usize, fan_out: usize, rng: &mut Pcg32| -> f32 {
        let s = (6.0 / (fan_in + fan_out) as f32).sqrt();
        rng.uniform_in(-s, s)
    };
    let mut net = Mlp {
        d_in,
        n_c: c,
        hidden: h,
        w1: (0..h * d_in).map(|_| glorot(d_in, h, &mut rng)).collect(),
        b1: vec![0.0; h],
        w2: (0..h * h).map(|_| glorot(h, h, &mut rng)).collect(),
        b2: vec![0.0; h],
        w3: (0..c * h).map(|_| glorot(h, c, &mut rng)).collect(),
        b3: vec![0.0; c],
    };
    // momentum buffers
    let mut v1 = vec![0.0f32; net.w1.len()];
    let mut vb1 = vec![0.0f32; h];
    let mut v2 = vec![0.0f32; net.w2.len()];
    let mut vb2 = vec![0.0f32; h];
    let mut v3 = vec![0.0f32; net.w3.len()];
    let mut vb3 = vec![0.0f32; c];

    let mut order: Vec<usize> = (0..ds.train.len()).collect();
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let s = &ds.train[i];
            let x = net.flatten(&s.u);
            // forward with caches
            let mut h1 = vec![0.0f32; h];
            matvec(&net.w1, &x, &net.b1, &mut h1);
            let a1: Vec<f32> = h1.iter().map(|&v| v.max(0.0)).collect();
            let mut h2 = vec![0.0f32; h];
            matvec(&net.w2, &a1, &net.b2, &mut h2);
            let a2: Vec<f32> = h2.iter().map(|&v| v.max(0.0)).collect();
            let mut z = vec![0.0f32; c];
            matvec(&net.w3, &a2, &net.b3, &mut z);
            crate::dfr::backprop::softmax_inplace(&mut z);

            // backward
            let mut dz = z;
            dz[s.label] -= 1.0;
            let mut da2 = vec![0.0f32; h];
            for (i, &d) in dz.iter().enumerate() {
                for (j, g) in da2.iter_mut().enumerate() {
                    *g += net.w3[i * h + j] * d;
                }
            }
            let dh2: Vec<f32> = da2
                .iter()
                .zip(&h2)
                .map(|(&g, &pre)| if pre > 0.0 { g } else { 0.0 })
                .collect();
            let mut da1 = vec![0.0f32; h];
            for (i, &d) in dh2.iter().enumerate() {
                for (j, g) in da1.iter_mut().enumerate() {
                    *g += net.w2[i * h + j] * d;
                }
            }
            let dh1: Vec<f32> = da1
                .iter()
                .zip(&h1)
                .map(|(&g, &pre)| if pre > 0.0 { g } else { 0.0 })
                .collect();

            // updates (momentum SGD)
            let step = |w: &mut [f32], v: &mut [f32], grad_row: &dyn Fn(usize) -> f32| {
                for (k, (wk, vk)) in w.iter_mut().zip(v.iter_mut()).enumerate() {
                    *vk = cfg.momentum * *vk - cfg.lr * grad_row(k);
                    *wk += *vk;
                }
            };
            step(&mut net.w3, &mut v3, &|k| dz[k / h] * a2[k % h]);
            step(&mut net.b3, &mut vb3, &|k| dz[k]);
            step(&mut net.w2, &mut v2, &|k| dh2[k / h] * a1[k % h]);
            step(&mut net.b2, &mut vb2, &|k| dh2[k]);
            step(&mut net.w1, &mut v1, &|k| dh1[k / d_in] * x[k % d_in]);
            step(&mut net.b1, &mut vb1, &|k| dh1[k]);
        }
    }
    net
}

/// Convenience: train and report test accuracy.
pub fn evaluate(ds: &Dataset, cfg: &MlpConfig) -> f64 {
    let net = train_mlp(ds, cfg);
    let preds: Vec<usize> = ds.test.iter().map(|s| net.predict(&s.u)).collect();
    accuracy(&preds, &ds.test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    #[test]
    fn learns_separable_toy() {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 60,
            test: 40,
            t_min: 10,
            t_max: 12,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.2,
                freq_sep: 0.25,
                ar: 0.2,
            },
            3,
        );
        let acc = evaluate(
            &ds,
            &MlpConfig {
                hidden: 24,
                epochs: 20,
                ..Default::default()
            },
        );
        assert!(acc > 0.8, "MLP accuracy {acc}");
    }

    #[test]
    fn probabilities_valid() {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 3,
            train: 12,
            test: 6,
            t_min: 8,
            t_max: 8,
        };
        let ds = synth::generate(&prof, 1);
        let net = train_mlp(
            &ds,
            &MlpConfig {
                hidden: 8,
                epochs: 2,
                ..Default::default()
            },
        );
        let y = net.forward(&ds.test[0].u);
        let sum: f32 = y.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
        assert!(y.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
