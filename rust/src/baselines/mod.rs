//! Machine-learning comparators for Table 6.
//!
//! The paper compares its DFR against seven methods, quoting their
//! accuracies from Ismail Fawaz et al. [12]. We implement the two that
//! are feasible and meaningful at edge scale from scratch — an [`mlp`]
//! trained by backprop and a [`twiesn`]-style echo state network — and
//! carry the remaining rows as published constants ([`published`]), as
//! the paper itself did.

pub mod mlp;
pub mod published;
pub mod twiesn;
