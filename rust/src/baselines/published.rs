//! Published comparator accuracies (Table 6).
//!
//! The paper itself copies these rows from Ismail Fawaz et al. [12]
//! ("Deep learning for time series classification: a review"); we carry
//! the same constants so the Table 6 bench can print the full comparison
//! next to our measured DFR/MLP/ESN numbers.

/// (dataset, MLP, FCN, ResNet, Encoder, MCDCNN, Time-CNN, TWIESN,
/// prop. bp) — Table 6 of the paper, in its row order.
pub const TABLE6: [(&str, [f64; 8]); 12] = [
    ("arab", [0.969, 0.994, 0.996, 0.981, 0.959, 0.958, 0.853, 0.981]),
    ("aus", [0.933, 0.975, 0.974, 0.938, 0.854, 0.726, 0.724, 0.954]),
    ("char", [0.969, 0.990, 0.990, 0.971, 0.938, 0.960, 0.920, 0.918]),
    ("cmu", [0.600, 1.000, 0.997, 0.983, 0.514, 0.976, 0.893, 0.931]),
    ("ecg", [0.748, 0.872, 0.867, 0.872, 0.500, 0.841, 0.737, 0.850]),
    ("jpvow", [0.976, 0.993, 0.992, 0.976, 0.944, 0.956, 0.965, 0.978]),
    ("kick", [0.610, 0.540, 0.510, 0.610, 0.560, 0.620, 0.670, 0.800]),
    ("lib", [0.780, 0.964, 0.954, 0.783, 0.651, 0.637, 0.794, 0.806]),
    ("net", [0.550, 0.891, 0.627, 0.777, 0.630, 0.890, 0.945, 0.783]),
    ("uwav", [0.901, 0.934, 0.926, 0.908, 0.845, 0.859, 0.754, 0.850]),
    ("waf", [0.894, 0.982, 0.989, 0.986, 0.658, 0.948, 0.949, 0.983]),
    ("walk", [0.700, 1.000, 1.000, 1.000, 0.450, 1.000, 0.944, 1.000]),
];

/// Column labels matching [`TABLE6`].
pub const TABLE6_METHODS: [&str; 8] = [
    "MLP", "FCN", "ResNet", "Encoder", "MCDCNN", "Time-CNN", "TWIESN", "prop. bp",
];

/// Paper Table 5 reference rows: (dataset, bp acc, bp time s, gs divs,
/// gs time s) — the shape target for `benches/table5_bp_vs_gs`.
pub const TABLE5: [(&str, f64, f64, usize, f64); 12] = [
    ("arab", 0.981, 245.0, 8, 25_040.0),
    ("aus", 0.954, 54.0, 8, 5_535.0),
    ("char", 0.918, 44.0, 10, 4_820.0),
    ("cmu", 0.931, 4.0, 1, 3.0),
    ("ecg", 0.850, 11.0, 16, 4_977.0),
    ("jpvow", 0.978, 4.0, 4, 106.0),
    ("kick", 0.800, 7.0, 1, 2.0),
    ("lib", 0.806, 12.0, 18, 8_423.0),
    ("net", 0.783, 45.0, 1, 49.0),
    ("uwav", 0.850, 65.0, 10, 6_322.0),
    ("waf", 0.983, 14.0, 3, 188.0),
    ("walk", 1.000, 4.0, 1, 3.0),
];

/// Table 12: qualitative comparison with existing FPGA DFR systems.
pub const TABLE12: [(&str, &str, &str, usize, usize); 3] = [
    ("prop.", "both", "fully digital", 12, 9),
    ("[1] Alomar+15", "inference only", "fully digital", 1, 3),
    ("[19] Shears+21", "inference only", "digital/analog hybrid", 1, 1),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_rows_everywhere() {
        assert_eq!(TABLE6.len(), 12);
        assert_eq!(TABLE5.len(), 12);
        let names: Vec<&str> = TABLE6.iter().map(|(n, _)| *n).collect();
        for (n, ..) in TABLE5 {
            assert!(names.contains(&n), "{n}");
        }
    }

    #[test]
    fn accuracies_are_probabilities() {
        for (name, row) in TABLE6 {
            for a in row {
                assert!((0.0..=1.0).contains(&a), "{name}: {a}");
            }
        }
    }

    #[test]
    fn paper_bp_speedup_reaches_700x() {
        // Table 5's headline: up to ~700x faster than grid search
        let max_ratio = TABLE5
            .iter()
            .map(|(_, _, bp_t, _, gs_t)| gs_t / bp_t)
            .fold(0.0f64, f64::max);
        assert!((690.0..=720.0).contains(&max_ratio), "{max_ratio}");
    }
}
