//! # dfr-edge
//!
//! Online training and inference system for delayed feedback reservoirs
//! (DFR), reproducing Ikeda, Awano & Sato, *"Online Training and Inference
//! System on Edge FPGA Using Delayed Feedback Reservoir"*, IEEE TCAD 2025.
//!
//! Layer map (see DESIGN.md):
//! - [`coordinator`] — the online edge system: session FSM, sharded
//!   worker pool, per-session routing, metrics.
//! - [`runtime`] — PJRT client for AOT artifacts produced by
//!   `python/compile` (cargo feature `pjrt`; stubbed otherwise).
//! - [`linalg`] — the paper's in-place 1-D Cholesky ridge regression
//!   (Algorithms 1–5) with op/memory counters (Tables 2–3).
//! - [`dfr`] — pure-Rust DFR stack: masking, modular reservoir, DPRR,
//!   truncated backpropagation, SGD, grid search.
//! - [`quant`] — bit-accurate fixed-point datapath: Q-format words,
//!   PWL-LUT nonlinearity, quantized forward + MAC inference behind the
//!   same `Engine` trait, analytic error budgeting and width sweeps.
//! - [`simd`] — explicit-SIMD kernel layer: runtime-dispatched AVX2/FMA
//!   implementations of the batched forward sweep, the ridge Gram
//!   update and the score dots, pinned to the scalar reference by
//!   bitwise/tolerance equivalence suites (DESIGN.md §18).
//! - [`fpga`] — HLS-like co-design simulator substituting the Zynq board.
//! - [`data`] — synthetic dataset generators (Table 4 profiles) + npz IO.
//! - [`baselines`] — MLP / ESN comparators for Table 6.
//! - [`util`] — substrates: PRNG, arg parser, JSON, mini runtime, bench
//!   harness, property-test driver.

pub mod util;
pub mod data;
pub mod dfr;
pub mod linalg;
pub mod fpga;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod quant;
pub mod report;
pub mod simd;
