//! `artifacts/manifest.json` — the contract between `aot.py` and the
//! Rust runtime: which HLO files exist, for which dataset profile, with
//! which argument shapes and order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One artifact entry point.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    /// (arg name, dims, dtype) in call order
    pub args: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<String>,
}

/// All artifacts for one dataset profile.
#[derive(Clone, Debug)]
pub struct ProfileArtifacts {
    pub name: String,
    pub n_v: usize,
    pub n_c: usize,
    pub t_pad: usize,
    pub nx: usize,
    pub s: usize,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl ProfileArtifacts {
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact entry '{name}' missing for profile {}", self.name))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub profiles: BTreeMap<String, ProfileArtifacts>,
}

impl Manifest {
    /// Load from `artifacts/` (or any directory holding manifest.json).
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("manifest.json parse")?;
        let mut profiles = BTreeMap::new();
        let profs = v
            .get("profiles")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'profiles'"))?;
        for (name, p) in profs {
            let get = |k: &str| -> Result<usize> {
                p.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("profile {name}: missing {k}"))
            };
            let mut entries = BTreeMap::new();
            let ents = p
                .get("entries")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("profile {name}: missing entries"))?;
            for (ename, e) in ents {
                let file = e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry {ename}: missing file"))?;
                let args = e
                    .get("args")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {ename}: missing args"))?
                    .iter()
                    .map(|a| {
                        let an = a.get("name").and_then(Json::as_str).unwrap_or("?");
                        let dims = a
                            .get("dims")
                            .and_then(Json::as_arr)
                            .map(|d| d.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default();
                        let dt = a
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string();
                        (an.to_string(), dims, dt)
                    })
                    .collect();
                let outputs = e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .map(|o| {
                        o.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .unwrap_or_default();
                entries.insert(
                    ename.clone(),
                    ArtifactEntry {
                        name: ename.clone(),
                        file: dir.join(file),
                        args,
                        outputs,
                    },
                );
            }
            profiles.insert(
                name.clone(),
                ProfileArtifacts {
                    name: name.clone(),
                    n_v: get("n_v")?,
                    n_c: get("n_c")?,
                    t_pad: get("t_pad")?,
                    nx: get("nx")?,
                    s: get("s")?,
                    entries,
                },
            );
        }
        Ok(Manifest { dir, profiles })
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileArtifacts> {
        self.profiles
            .get(name)
            .ok_or_else(|| anyhow!("profile '{name}' not in manifest (have: {:?})",
                self.profiles.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn parses_repo_manifest_when_built() {
        let Some(m) = repo_artifacts() else {
            return; // `make artifacts` not run — skip
        };
        let p = m.profile("jpvow").unwrap();
        assert_eq!(p.n_v, 12);
        assert_eq!(p.n_c, 9);
        assert_eq!(p.s, 931);
        for name in ["forward", "train_step", "infer", "features", "step"] {
            let e = p.entry(name).unwrap();
            assert!(e.file.exists(), "{:?}", e.file);
            assert!(!e.args.is_empty());
        }
        // argument order of train_step is the aot.py contract
        let ts = p.entry("train_step").unwrap();
        let names: Vec<&str> = ts.args.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(
            names,
            ["u", "length", "e", "mask", "p", "q", "w", "b", "lr_res", "lr_out"]
        );
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
