//! PJRT runtime: loads the HLO-text artifacts compiled by
//! `python/compile/aot.py` and executes them from the request path.
//!
//! Python never runs here — the artifacts are self-contained HLO modules
//! compiled once per dataset profile. The interchange format is HLO
//! *text* (xla_extension 0.5.1 rejects jax≥0.5 serialized protos; the
//! text parser reassigns instruction ids — see /opt/xla-example).
//!
//! [`manifest`] parses `artifacts/manifest.json` (the shape contract),
//! [`executor`] wraps `PjRtClient` with typed entry points for the five
//! artifact kinds (forward / train_step / infer / features / step).

pub mod executor;
pub mod manifest;

pub use executor::{DfrExecutor, TrainState};
pub use manifest::{ArtifactEntry, Manifest, ProfileArtifacts};
