//! PJRT runtime: loads the HLO-text artifacts compiled by
//! `python/compile/aot.py` and executes them from the request path.
//!
//! Python never runs here — the artifacts are self-contained HLO modules
//! compiled once per dataset profile. The interchange format is HLO
//! *text* (xla_extension 0.5.1 rejects jax≥0.5 serialized protos; the
//! text parser reassigns instruction ids — see /opt/xla-example).
//!
//! [`manifest`] parses `artifacts/manifest.json` (the shape contract),
//! [`executor`] wraps `PjRtClient` with typed entry points for the five
//! artifact kinds (forward / train_step / infer / features / step).
//!
//! The `xla` bindings are vendored into the deployment image (not a
//! registry dependency), so the real executor is gated behind the `pjrt`
//! cargo feature; default builds get a stub whose constructor errors and
//! callers fall back to the native engine (see DESIGN.md §7).

pub mod executor;
pub mod manifest;

pub use executor::{DfrExecutor, TrainState};
pub use manifest::{ArtifactEntry, Manifest, ProfileArtifacts};
