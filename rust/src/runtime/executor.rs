//! Typed PJRT executor for the DFR artifacts.
//!
//! Wraps `PjRtClient::cpu()` + `HloModuleProto::from_text_file` +
//! `client.compile` (the /opt/xla-example/load_hlo pattern) and exposes
//! the five entry points with concrete Rust signatures. One compiled
//! executable per entry point, compiled lazily and cached; buffers are
//! rebuilt per call (PJRT owns device memory).
//!
//! The `xla` bindings are vendored into the deployment image, not pulled
//! from a registry, so the real executor is gated behind the `pjrt`
//! cargo feature. Without it, [`DfrExecutor::new`] returns an error and
//! every caller falls back to the pure-Rust
//! [`NativeEngine`](crate::coordinator::NativeEngine) path.

/// Mutable training state mirrored across PJRT calls (the artifact is
/// pure; the coordinator owns the state).
#[derive(Clone, Debug)]
pub struct TrainState {
    pub p: f32,
    pub q: f32,
    /// row-major n_c × (s-1)
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl TrainState {
    /// Paper §4.1 initial state (see `dfr::train::TrainConfig` for the
    /// init deviation note).
    pub fn init(n_c: usize, nx: usize, p0: f32, q0: f32) -> Self {
        TrainState {
            p: p0,
            q: q0,
            w: vec![0.0; n_c * nx * (nx + 1)],
            b: vec![0.0; n_c],
        }
    }
}

/// Output of one forward artifact call.
#[derive(Clone, Debug)]
pub struct ForwardOut {
    pub r_mat: Vec<f32>,
    pub x_t: Vec<f32>,
    pub x_tm1: Vec<f32>,
    pub j_t: Vec<f32>,
}

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow, Context, Result};

    use super::{ForwardOut, TrainState};
    use crate::data::dataset::Sample;
    use crate::dfr::mask::Mask;
    use crate::runtime::manifest::{ArtifactEntry, ProfileArtifacts};

    /// Compiled executables for one dataset profile.
    pub struct DfrExecutor {
        pub profile: ProfileArtifacts,
        client: xla::PjRtClient,
        forward: xla::PjRtLoadedExecutable,
        train_step: xla::PjRtLoadedExecutable,
        infer: xla::PjRtLoadedExecutable,
        features: xla::PjRtLoadedExecutable,
        step: xla::PjRtLoadedExecutable,
    }

    impl DfrExecutor {
        /// Compile all five entry points for a profile on the CPU client.
        pub fn new(profile: &ProfileArtifacts) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
            let compile = |entry: &ArtifactEntry| -> Result<xla::PjRtLoadedExecutable> {
                let path = entry
                    .file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", entry.file))?;
                let proto = xla::HloModuleProto::from_text_file(path)
                    .map_err(to_anyhow)
                    .with_context(|| format!("parsing {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(to_anyhow)
                    .with_context(|| format!("compiling {path}"))
            };
            Ok(DfrExecutor {
                forward: compile(profile.entry("forward")?)?,
                train_step: compile(profile.entry("train_step")?)?,
                infer: compile(profile.entry("infer")?)?,
                features: compile(profile.entry("features")?)?,
                step: compile(profile.entry("step")?)?,
                client,
                profile: profile.clone(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn mat(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
            debug_assert_eq!(data.len(), rows * cols);
            xla::Literal::vec1(data)
                .reshape(&[rows as i64, cols as i64])
                .map_err(to_anyhow)
        }

        /// Pad a sample into the profile's [T_pad, V] window.
        fn padded_u(&self, s: &Sample) -> Result<xla::Literal> {
            let p = &self.profile;
            if s.t > p.t_pad {
                return Err(anyhow!(
                    "sample length {} exceeds artifact T_pad {}",
                    s.t,
                    p.t_pad
                ));
            }
            self.mat(&s.padded(p.n_v, p.t_pad), p.t_pad, p.n_v)
        }

        fn run(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            args: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>> {
            let result = exe.execute::<xla::Literal>(args).map_err(to_anyhow)?;
            let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
            lit.to_tuple().map_err(to_anyhow)
        }

        /// Forward pass: (R, x_T, x_Tm1, j_T).
        pub fn forward(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<ForwardOut> {
            let prof = &self.profile;
            let args = [
                self.padded_u(s)?,
                xla::Literal::scalar(s.t as i32),
                self.mat(&mask.m, prof.nx, prof.n_v)?,
                xla::Literal::scalar(p),
                xla::Literal::scalar(q),
            ];
            let out = self.run(&self.forward, &args)?;
            if out.len() != 4 {
                return Err(anyhow!("forward returned {} outputs", out.len()));
            }
            Ok(ForwardOut {
                r_mat: out[0].to_vec::<f32>().map_err(to_anyhow)?,
                x_t: out[1].to_vec::<f32>().map_err(to_anyhow)?,
                x_tm1: out[2].to_vec::<f32>().map_err(to_anyhow)?,
                j_t: out[3].to_vec::<f32>().map_err(to_anyhow)?,
            })
        }

        /// One truncated-BP SGD step; updates `state` in place and returns
        /// the loss.
        pub fn train_step(
            &self,
            s: &Sample,
            mask: &Mask,
            state: &mut TrainState,
            lr_res: f32,
            lr_out: f32,
        ) -> Result<f32> {
            let prof = &self.profile;
            let mut e = vec![0.0f32; prof.n_c];
            e[s.label] = 1.0;
            let args = [
                self.padded_u(s)?,
                xla::Literal::scalar(s.t as i32),
                xla::Literal::vec1(&e),
                self.mat(&mask.m, prof.nx, prof.n_v)?,
                xla::Literal::scalar(state.p),
                xla::Literal::scalar(state.q),
                self.mat(&state.w, prof.n_c, prof.s - 1)?,
                xla::Literal::vec1(&state.b),
                xla::Literal::scalar(lr_res),
                xla::Literal::scalar(lr_out),
            ];
            let out = self.run(&self.train_step, &args)?;
            if out.len() != 5 {
                return Err(anyhow!("train_step returned {} outputs", out.len()));
            }
            state.p = out[0].get_first_element::<f32>().map_err(to_anyhow)?;
            state.q = out[1].get_first_element::<f32>().map_err(to_anyhow)?;
            state.w = out[2].to_vec::<f32>().map_err(to_anyhow)?;
            state.b = out[3].to_vec::<f32>().map_err(to_anyhow)?;
            out[4].get_first_element::<f32>().map_err(to_anyhow)
        }

        /// Inference with the ridge output layer: class probabilities.
        pub fn infer(
            &self,
            s: &Sample,
            mask: &Mask,
            p: f32,
            q: f32,
            w_tilde: &[f32],
        ) -> Result<Vec<f32>> {
            let prof = &self.profile;
            let args = [
                self.padded_u(s)?,
                xla::Literal::scalar(s.t as i32),
                self.mat(&mask.m, prof.nx, prof.n_v)?,
                xla::Literal::scalar(p),
                xla::Literal::scalar(q),
                self.mat(w_tilde, prof.n_c, prof.s)?,
            ];
            let out = self.run(&self.infer, &args)?;
            out[0].to_vec::<f32>().map_err(to_anyhow)
        }

        /// Ridge feature vector r̃ = [r, 1] for one sample.
        pub fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
            let args = [
                self.padded_u(s)?,
                xla::Literal::scalar(s.t as i32),
                self.mat(&mask.m, self.profile.nx, self.profile.n_v)?,
                xla::Literal::scalar(p),
                xla::Literal::scalar(q),
            ];
            let out = self.run(&self.features, &args)?;
            out[0].to_vec::<f32>().map_err(to_anyhow)
        }

        /// Streaming single-step state update.
        pub fn step(
            &self,
            x_prev: &[f32],
            u_t: &[f32],
            mask: &Mask,
            p: f32,
            q: f32,
        ) -> Result<Vec<f32>> {
            let args = [
                xla::Literal::vec1(x_prev),
                xla::Literal::vec1(u_t),
                self.mat(&mask.m, self.profile.nx, self.profile.n_v)?,
                xla::Literal::scalar(p),
                xla::Literal::scalar(q),
            ];
            let out = self.run(&self.step, &args)?;
            out[0].to_vec::<f32>().map_err(to_anyhow)
        }
    }

    fn to_anyhow(e: xla::Error) -> anyhow::Error {
        anyhow!("{e}")
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{bail, Result};

    use super::{ForwardOut, TrainState};
    use crate::data::dataset::Sample;
    use crate::dfr::mask::Mask;
    use crate::runtime::manifest::ProfileArtifacts;

    /// Stub executor used when the crate is built without the `pjrt`
    /// feature (no vendored `xla` bindings). [`DfrExecutor::new`] always
    /// fails, so the instance methods are unreachable; they exist only to
    /// keep call sites compiling identically in both configurations.
    pub struct DfrExecutor {
        pub profile: ProfileArtifacts,
    }

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: dfr_edge was built without the `pjrt` feature \
         (vendored xla bindings) — use the native engine";

    impl DfrExecutor {
        /// Always fails in this configuration; callers fall back to
        /// [`NativeEngine`](crate::coordinator::NativeEngine).
        pub fn new(_profile: &ProfileArtifacts) -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn forward(&self, _s: &Sample, _mask: &Mask, _p: f32, _q: f32) -> Result<ForwardOut> {
            bail!(UNAVAILABLE)
        }

        pub fn train_step(
            &self,
            _s: &Sample,
            _mask: &Mask,
            _state: &mut TrainState,
            _lr_res: f32,
            _lr_out: f32,
        ) -> Result<f32> {
            bail!(UNAVAILABLE)
        }

        pub fn infer(
            &self,
            _s: &Sample,
            _mask: &Mask,
            _p: f32,
            _q: f32,
            _w_tilde: &[f32],
        ) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }

        pub fn features(&self, _s: &Sample, _mask: &Mask, _p: f32, _q: f32) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }

        pub fn step(
            &self,
            _x_prev: &[f32],
            _u_t: &[f32],
            _mask: &Mask,
            _p: f32,
            _q: f32,
        ) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }
    }
}

pub use imp::DfrExecutor;

#[cfg(test)]
mod tests {
    //! Executor tests live in `rust/tests/runtime_integration.rs` (they
    //! need built artifacts and a PJRT client, which is process-global).
}
