//! Portable scalar kernels — the reference implementation of the
//! [`Kernels`](super::Kernels) table and the tail path of the AVX2
//! table (lane counts mod 8, Gram rows mod 8).
//!
//! Every function here executes, per lane / per element, **exactly** the
//! operation sequence of the pre-SIMD code it replaced
//! (`BatchScratch::forward_batch_into`'s inner loops, `rank1_fold_packed`'s
//! axpy rows, `rankk_update_packed`, `scores_from_r_tilde`'s dot) — the
//! bitwise and tolerance equivalence suites pin the vector tables against
//! these functions, and these functions against the original per-call
//! paths.

use crate::dfr::reservoir::Nonlinearity;

/// See [`CascadeRowFn`](super::CascadeRowFn). Per active lane this is the
/// per-call `Reservoir::step` chain verbatim: `p·f(j+x) + q·prev`
/// (two muls, one add — never fused).
pub fn cascade_row(
    f: Nonlinearity,
    ps: &[f32],
    qs: &[f32],
    x_row: &mut [f32],
    j_row: &[f32],
    cascade: &mut [f32],
    active: &[u32],
) {
    let b = x_row.len();
    if active.is_empty() {
        for l in 0..b {
            let xn = ps[l] * f.eval(j_row[l] + x_row[l]) + qs[l] * cascade[l];
            cascade[l] = xn;
            x_row[l] = xn;
        }
    } else {
        for l in 0..b {
            if active[l] != 0 {
                let xn = ps[l] * f.eval(j_row[l] + x_row[l]) + qs[l] * cascade[l];
                cascade[l] = xn;
                x_row[l] = xn;
            }
        }
    }
}

/// See [`DprrRowFn`](super::DprrRowFn): one `acc += x_i·x'_m` per active
/// lane — per-element identical to `DprrAccumulator::push`.
pub fn dprr_row(acc_row: &mut [f32], xi: &[f32], xm: &[f32], active: &[u32]) {
    let b = acc_row.len();
    if active.is_empty() {
        for l in 0..b {
            acc_row[l] += xi[l] * xm[l];
        }
    } else {
        for l in 0..b {
            if active[l] != 0 {
                acc_row[l] += xi[l] * xm[l];
            }
        }
    }
}

/// See [`DprrBiasFn`](super::DprrBiasFn): the DPRR bias column,
/// `acc += x_i` per active lane.
pub fn dprr_bias(acc_row: &mut [f32], xi: &[f32], active: &[u32]) {
    let b = acc_row.len();
    if active.is_empty() {
        for l in 0..b {
            acc_row[l] += xi[l];
        }
    } else {
        for l in 0..b {
            if active[l] != 0 {
                acc_row[l] += xi[l];
            }
        }
    }
}

/// See [`GramRankkFn`](super::GramRankkFn): `P += Σ_b r_b r_bᵀ` on the
/// packed lower triangle from a row-major B×s block.
///
/// Register-blocked micro-kernel (moved verbatim from
/// `linalg::ridge::rankk_update_packed`, which now dispatches here):
/// each triangle row is processed for **4 samples at a time** (one
/// load-modify-store of the row per quad instead of per sample), and
/// within a quad the column loop is a pure axpy with no loop-carried
/// reduction, so LLVM vectorizes it without fast-math. Total MAC count
/// is identical to B rank-1 passes; the memory traffic over `P` drops
/// by ~B (the row stays in L1 across the whole block, `P` is streamed
/// once per block).
pub fn gram_rankk(p: &mut [f32], rs: &[f32], s: usize) {
    debug_assert_eq!(rs.len() % s.max(1), 0);
    let mut idx = 0;
    for i in 0..s {
        let n = i + 1;
        let row = &mut p[idx..idx + n];
        let mut quads = rs.chunks_exact(4 * s);
        for quad in quads.by_ref() {
            let (q0, rest) = quad.split_at(s);
            let (q1, rest) = rest.split_at(s);
            let (q2, q3) = rest.split_at(s);
            let (a0, a1, a2, a3) = (q0[i], q1[i], q2[i], q3[i]);
            let (r0, r1, r2, r3) = (&q0[..n], &q1[..n], &q2[..n], &q3[..n]);
            for j in 0..n {
                row[j] += a0 * r0[j] + a1 * r1[j] + a2 * r2[j] + a3 * r3[j];
            }
        }
        for r in quads.remainder().chunks_exact(s) {
            let ri = r[i];
            for (pe, &re) in row.iter_mut().zip(&r[..n]) {
                *pe += ri * re;
            }
        }
        idx += n;
    }
}

/// See [`AxpyFn`](super::AxpyFn): `row[j] += a·x[j]` — the 4-wide
/// chunked axpy `rank1_fold_packed` has always used (per-element
/// mul+add; chunking does not change per-element math).
pub fn axpy(row: &mut [f32], a: f32, x: &[f32]) {
    let mut rc = row.chunks_exact_mut(4);
    let mut xc = x.chunks_exact(4);
    for (p4, x4) in rc.by_ref().zip(xc.by_ref()) {
        p4[0] += a * x4[0];
        p4[1] += a * x4[1];
        p4[2] += a * x4[2];
        p4[3] += a * x4[3];
    }
    for (pe, &re) in rc.into_remainder().iter_mut().zip(xc.remainder()) {
        *pe += a * re;
    }
}

/// See [`DotFn`](super::DotFn): the sequential left-to-right reduction
/// `scores_from_r_tilde` has always used.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}
