//! x86-64 AVX2/FMA kernels — the vector side of the
//! [`Kernels`](super::Kernels) table. **All `unsafe` of the SIMD layer
//! lives in this file**, behind safe wrappers that assert every slice
//! bound the raw loads rely on.
//!
//! Soundness story: the `#[target_feature]` functions here are only ever
//! reachable through the table built by `Kernels::try_select*`, which
//! requires `is_x86_feature_detected!("avx2") && ("fma")` before
//! constructing it — so every wrapper's `unsafe` block discharges the
//! same single obligation (the CPU runs the emitted instructions).
//!
//! Numeric contracts (DESIGN.md §18):
//!
//! * the **bitwise** kernels ([`cascade_row`], [`dprr_row`],
//!   [`dprr_bias`]) use `vaddps`/`vmulps`/`vdivps` only — no FMA, no
//!   reordering *within* a lane — so each lane computes exactly the
//!   scalar op chain. Frozen lanes (ragged `k ≥ t_len[l]`, or any
//!   masked batch position) are handled with `vblendvps` against the
//!   *old* value: adding a masked zero instead would turn a stored
//!   `-0.0` into `+0.0` and break bit equality. Batch tails (B mod 8)
//!   run the scalar reference on the remainder slice — same ops, same
//!   bits.
//! * the **tolerance-bounded** kernels ([`gram_rankk`], [`axpy`],
//!   [`dot`]) reassociate sums across the feature dimension and use
//!   `vfmadd`; their equivalence to scalar is bounded, not exact, and
//!   tested that way (`tests/simd_equivalence.rs`).
//!
//! `tanh` (and non-integer Mackey–Glass exponents) have no vector libm
//! on stable; those lanes round-trip through a stack buffer and call the
//! *same* scalar libm function — identical input bits produce identical
//! output bits, preserving the bitwise contract at ~gather cost while
//! the surrounding adds/muls still vectorize.

use core::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_blendv_ps, _mm256_div_ps, _mm256_fmadd_ps, _mm256_loadu_ps,
    _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
};

use super::scalar;
use crate::dfr::reservoir::Nonlinearity;

const W: usize = 8;

/// Vectorized `f` evaluation on 8 lanes.
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2.
#[target_feature(enable = "avx2")]
unsafe fn eval8(f: Nonlinearity, t: __m256) -> __m256 {
    match f {
        // scalar eval is `alpha * x`: one mul — identical per lane
        Nonlinearity::Linear { alpha } => _mm256_mul_ps(_mm256_set1_ps(alpha), t),
        // scalar eval is `eta * x / (1.0 + x*x)` (pow_abs fast path):
        // mul, then div by (1 + mul) — the same op chain per lane
        Nonlinearity::MackeyGlass { eta, p_exp } if p_exp == 2.0 => {
            let num = _mm256_mul_ps(_mm256_set1_ps(eta), t);
            let den = _mm256_add_ps(_mm256_set1_ps(1.0), _mm256_mul_ps(t, t));
            _mm256_div_ps(num, den)
        }
        // tanh / |x|^p powf: no stable vector libm — call the scalar
        // libm per lane through a stack buffer (same input bits -> same
        // output bits, so bit equality survives)
        _ => {
            let mut buf = [0.0f32; W];
            _mm256_storeu_ps(buf.as_mut_ptr(), t);
            for v in &mut buf {
                *v = f.eval(*v);
            }
            _mm256_loadu_ps(buf.as_ptr())
        }
    }
}

/// # Safety
/// CPU must support AVX2; all slices must hold ≥ `l + 8` elements
/// (and `active`, when non-empty, likewise).
#[target_feature(enable = "avx2")]
unsafe fn cascade_row_body(
    f: Nonlinearity,
    ps: &[f32],
    qs: &[f32],
    x_row: &mut [f32],
    j_row: &[f32],
    cascade: &mut [f32],
    active: &[u32],
    l: usize,
) {
    let xo = _mm256_loadu_ps(x_row.as_ptr().add(l));
    let jv = _mm256_loadu_ps(j_row.as_ptr().add(l));
    let t = _mm256_add_ps(jv, xo);
    let fv = eval8(f, t);
    let pv = _mm256_loadu_ps(ps.as_ptr().add(l));
    let qv = _mm256_loadu_ps(qs.as_ptr().add(l));
    let cv = _mm256_loadu_ps(cascade.as_ptr().add(l));
    // p·f(j+x) + q·prev: vmulps, vmulps, vaddps — the scalar chain,
    // never contracted to FMA (Rust scalar f32 does not contract)
    let xn = _mm256_add_ps(_mm256_mul_ps(pv, fv), _mm256_mul_ps(qv, cv));
    let (xs, cs) = if active.is_empty() {
        (xn, xn)
    } else {
        // the mask words are !0 (sign bit set) for active lanes and 0
        // for frozen ones; vblendvps keys on the sign bit, so frozen
        // lanes keep their old x and cascade values bit-for-bit
        let m = _mm256_loadu_ps(active.as_ptr().add(l).cast::<f32>());
        (_mm256_blendv_ps(xo, xn, m), _mm256_blendv_ps(cv, xn, m))
    };
    _mm256_storeu_ps(x_row.as_mut_ptr().add(l), xs);
    _mm256_storeu_ps(cascade.as_mut_ptr().add(l), cs);
}

/// AVX2 [`CascadeRowFn`](super::CascadeRowFn) — 8 lanes per iteration,
/// scalar reference on the `B mod 8` tail.
pub fn cascade_row(
    f: Nonlinearity,
    ps: &[f32],
    qs: &[f32],
    x_row: &mut [f32],
    j_row: &[f32],
    cascade: &mut [f32],
    active: &[u32],
) {
    let b = x_row.len();
    assert!(
        ps.len() >= b && qs.len() >= b && j_row.len() >= b && cascade.len() >= b,
        "cascade_row: lane buffers shorter than the x row"
    );
    assert!(
        active.is_empty() || active.len() >= b,
        "cascade_row: active mask shorter than the x row"
    );
    let mut l = 0;
    while l + W <= b {
        // SAFETY: this fn is only installed by `Kernels::avx2_table`,
        // which the selection layer builds strictly after positive AVX2
        // detection; the asserts above guarantee `l + 8` elements exist
        // in every slice the body loads/stores.
        unsafe {
            cascade_row_body(f, ps, qs, x_row, j_row, cascade, active, l);
        }
        l += W;
    }
    if l < b {
        let act = if active.is_empty() { active } else { &active[l..] };
        scalar::cascade_row(
            f,
            &ps[l..],
            &qs[l..],
            &mut x_row[l..],
            &j_row[l..],
            &mut cascade[l..],
            act,
        );
    }
}

/// # Safety
/// CPU must support AVX2; all slices must hold ≥ `l + 8` elements.
#[target_feature(enable = "avx2")]
unsafe fn dprr_row_body(acc_row: &mut [f32], xi: &[f32], xm: &[f32], active: &[u32], l: usize) {
    let av = _mm256_loadu_ps(acc_row.as_ptr().add(l));
    let xv = _mm256_loadu_ps(xi.as_ptr().add(l));
    let mv = _mm256_loadu_ps(xm.as_ptr().add(l));
    // acc + xi·xm: vmulps then vaddps — the scalar `+=` chain, no FMA
    let sum = _mm256_add_ps(av, _mm256_mul_ps(xv, mv));
    let out = if active.is_empty() {
        sum
    } else {
        // blend the OLD accumulator back into frozen lanes (adding a
        // masked zero would rewrite -0.0 as +0.0)
        let m = _mm256_loadu_ps(active.as_ptr().add(l).cast::<f32>());
        _mm256_blendv_ps(av, sum, m)
    };
    _mm256_storeu_ps(acc_row.as_mut_ptr().add(l), out);
}

/// AVX2 [`DprrRowFn`](super::DprrRowFn).
pub fn dprr_row(acc_row: &mut [f32], xi: &[f32], xm: &[f32], active: &[u32]) {
    let b = acc_row.len();
    assert!(
        xi.len() >= b && xm.len() >= b,
        "dprr_row: state rows shorter than the accumulator row"
    );
    assert!(
        active.is_empty() || active.len() >= b,
        "dprr_row: active mask shorter than the accumulator row"
    );
    let mut l = 0;
    while l + W <= b {
        // SAFETY: table built only after positive AVX2 detection; the
        // asserts above guarantee `l + 8` elements in every slice.
        unsafe {
            dprr_row_body(acc_row, xi, xm, active, l);
        }
        l += W;
    }
    if l < b {
        let act = if active.is_empty() { active } else { &active[l..] };
        scalar::dprr_row(&mut acc_row[l..], &xi[l..], &xm[l..], act);
    }
}

/// # Safety
/// CPU must support AVX2; all slices must hold ≥ `l + 8` elements.
#[target_feature(enable = "avx2")]
unsafe fn dprr_bias_body(acc_row: &mut [f32], xi: &[f32], active: &[u32], l: usize) {
    let av = _mm256_loadu_ps(acc_row.as_ptr().add(l));
    let xv = _mm256_loadu_ps(xi.as_ptr().add(l));
    let sum = _mm256_add_ps(av, xv);
    let out = if active.is_empty() {
        sum
    } else {
        // frozen lanes keep the old accumulator bits (see dprr_row_body)
        let m = _mm256_loadu_ps(active.as_ptr().add(l).cast::<f32>());
        _mm256_blendv_ps(av, sum, m)
    };
    _mm256_storeu_ps(acc_row.as_mut_ptr().add(l), out);
}

/// AVX2 [`DprrBiasFn`](super::DprrBiasFn).
pub fn dprr_bias(acc_row: &mut [f32], xi: &[f32], active: &[u32]) {
    let b = acc_row.len();
    assert!(
        xi.len() >= b,
        "dprr_bias: state row shorter than the accumulator row"
    );
    assert!(
        active.is_empty() || active.len() >= b,
        "dprr_bias: active mask shorter than the accumulator row"
    );
    let mut l = 0;
    while l + W <= b {
        // SAFETY: table built only after positive AVX2 detection; the
        // asserts above guarantee `l + 8` elements in every slice.
        unsafe {
            dprr_bias_body(acc_row, xi, active, l);
        }
        l += W;
    }
    if l < b {
        let act = if active.is_empty() { active } else { &active[l..] };
        scalar::dprr_bias(&mut acc_row[l..], &xi[l..], act);
    }
}

/// # Safety
/// CPU must support AVX2 and FMA; `p.len() == s(s+1)/2` and
/// `rs.len()` a multiple of `s` (asserted by the safe wrapper).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gram_rankk_body(p: &mut [f32], rs: &[f32], s: usize) {
    let mut idx = 0;
    for i in 0..s {
        let n = i + 1;
        let row = &mut p[idx..idx + n];
        let mut quads = rs.chunks_exact(4 * s);
        for quad in quads.by_ref() {
            let (q0, rest) = quad.split_at(s);
            let (q1, rest) = rest.split_at(s);
            let (q2, q3) = rest.split_at(s);
            let (a0, a1, a2, a3) = (q0[i], q1[i], q2[i], q3[i]);
            let (v0, v1, v2, v3) = (
                _mm256_set1_ps(a0),
                _mm256_set1_ps(a1),
                _mm256_set1_ps(a2),
                _mm256_set1_ps(a3),
            );
            let mut j = 0;
            while j + W <= n {
                let mut acc = _mm256_loadu_ps(row.as_ptr().add(j));
                acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(q0.as_ptr().add(j)), acc);
                acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(q1.as_ptr().add(j)), acc);
                acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(q2.as_ptr().add(j)), acc);
                acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(q3.as_ptr().add(j)), acc);
                _mm256_storeu_ps(row.as_mut_ptr().add(j), acc);
                j += W;
            }
            for jj in j..n {
                row[jj] += a0 * q0[jj] + a1 * q1[jj] + a2 * q2[jj] + a3 * q3[jj];
            }
        }
        for r in quads.remainder().chunks_exact(s) {
            let ri = r[i];
            let rv = _mm256_set1_ps(ri);
            let mut j = 0;
            while j + W <= n {
                let acc = _mm256_fmadd_ps(
                    rv,
                    _mm256_loadu_ps(r.as_ptr().add(j)),
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                );
                _mm256_storeu_ps(row.as_mut_ptr().add(j), acc);
                j += W;
            }
            for jj in j..n {
                row[jj] += ri * r[jj];
            }
        }
        idx += n;
    }
}

/// AVX2/FMA [`GramRankkFn`](super::GramRankkFn) — same quad blocking as
/// the scalar kernel, inner axpy fused 8-wide (tolerance class).
pub fn gram_rankk(p: &mut [f32], rs: &[f32], s: usize) {
    assert_eq!(p.len(), s * (s + 1) / 2, "packed triangle size mismatch");
    assert_eq!(rs.len() % s.max(1), 0, "block not a multiple of s");
    // SAFETY: table built only after positive AVX2+FMA detection; the
    // asserts pin the triangle/row shapes, and the body indexes only
    // within `row[..n]` / `q[..n]` with `n ≤ s` (slice-checked splits,
    // vector loads bounded by `j + 8 <= n`).
    unsafe {
        gram_rankk_body(p, rs, s);
    }
}

/// # Safety
/// CPU must support AVX2 and FMA; `x.len() >= row.len()`.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_body(row: &mut [f32], a: f32, x: &[f32]) {
    let n = row.len();
    let av = _mm256_set1_ps(a);
    let mut j = 0;
    while j + W <= n {
        let acc = _mm256_fmadd_ps(
            av,
            _mm256_loadu_ps(x.as_ptr().add(j)),
            _mm256_loadu_ps(row.as_ptr().add(j)),
        );
        _mm256_storeu_ps(row.as_mut_ptr().add(j), acc);
        j += W;
    }
    for jj in j..n {
        row[jj] += a * x[jj];
    }
}

/// AVX2/FMA [`AxpyFn`](super::AxpyFn) (tolerance class: per-element FMA
/// rounds once where scalar rounds twice).
pub fn axpy(row: &mut [f32], a: f32, x: &[f32]) {
    assert!(x.len() >= row.len(), "axpy: x shorter than row");
    // SAFETY: table built only after positive AVX2+FMA detection; the
    // assert guarantees every `j + 8 <= row.len()` load is in bounds
    // for both slices.
    unsafe {
        axpy_body(row, a, x);
    }
}

/// # Safety
/// CPU must support AVX2 and FMA; `b.len() >= a.len()`.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_body(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut acc = _mm256_setzero_ps();
    let mut j = 0;
    while j + W <= n {
        acc = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.as_ptr().add(j)),
            _mm256_loadu_ps(b.as_ptr().add(j)),
            acc,
        );
        j += W;
    }
    let mut lanes = [0.0f32; W];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut sum = lanes.iter().sum::<f32>();
    for jj in j..n {
        sum += a[jj] * b[jj];
    }
    sum
}

/// AVX2/FMA [`DotFn`](super::DotFn) — 8 partial sums reduced at the end
/// (tolerance class: reassociated relative to the scalar left fold).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert!(b.len() >= a.len(), "dot: operand length mismatch");
    // SAFETY: table built only after positive AVX2+FMA detection; the
    // assert guarantees every `j + 8 <= a.len()` load is in bounds for
    // both slices.
    unsafe { dot_body(a, b) }
}
