//! Explicit-SIMD kernel layer: a runtime-dispatched table of the three
//! serving-stack hot loops (DESIGN.md §18).
//!
//! The batched forward sweep (PR 6) laid reservoir state out node-major
//! with lanes contiguous (`x[n·B + l]`) precisely so the lane dimension
//! is data-parallel — this module is the software counterpart of the
//! paper's node-parallel FPGA datapath: an 8-wide AVX2 implementation of
//! the lane loops, selected at boot and dispatched through a [`Kernels`]
//! table of plain function pointers.
//!
//! Three kernels, two equivalence classes:
//!
//! * **bitwise** — [`Kernels::cascade_row`], [`Kernels::dprr_row`],
//!   [`Kernels::dprr_bias`]: each lane is an independent scalar
//!   recurrence, so an 8-wide kernel that keeps every lane's op order
//!   (mul/add only, **no FMA** — Rust's scalar f32 never contracts) is
//!   bit-identical to the scalar path. Ragged/tail lanes are handled by
//!   *blending* the old value back in (never by adding a zero:
//!   `-0.0 + 0.0 == +0.0` would flip sign bits on frozen lanes).
//!   Pinned by the zero-tolerance `tests/batch_equivalence.rs` +
//!   `tests/simd_equivalence.rs` suites.
//! * **tolerance-bounded** — [`Kernels::gram_rankk`], [`Kernels::axpy`],
//!   [`Kernels::dot`]: sums over the feature dimension reassociate
//!   (8-wide partial sums, FMA allowed), so these get golden-fixture +
//!   property equivalence suites with derived tolerances instead of
//!   `assert_eq!` — the same contract `accumulate_block` already ships
//!   under (its block fold reassociates relative to sequential folds).
//!
//! Selection ([`Kernels::try_select`]): `Off` → scalar, `Force` → AVX2
//! or a typed [`SimdError`] (never UB — the table is only built after
//! `is_x86_feature_detected!`), `Auto` → a benchmark-at-boot probe races
//! the two cascade kernels on a synthetic batch and keeps the winner.
//! Non-x86-64 targets compile the scalar table only; `Force` errors.
//!
//! Process-wide default: [`global_kernels`] (the `DFR_SIMD` env knob or
//! [`set_global_kernels`] from the CLI's `--engine simd` / `--simd`
//! flags). Engines additionally carry their own copy so selection is
//! per shard ([`crate::coordinator::NativeEngine::with_kernels`]).
//!
//! All `unsafe` lives in the [`avx2`] submodule, every block carries a
//! SAFETY comment (`#![deny(clippy::undocumented_unsafe_blocks)]`), and
//! the crate adds **zero dependencies** — `core::arch` +
//! `#[target_feature]` on stable only.
#![deny(clippy::undocumented_unsafe_blocks)]

use std::fmt;
use std::sync::OnceLock;
use std::time::Instant;

use crate::dfr::reservoir::Nonlinearity;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
pub mod scalar;

/// One virtual-node row of the batched Eq.-14 cascade over the lane
/// dimension: for every active lane `l`,
/// `x[l] = p[l]·f(j[l] + x[l]) + q[l]·cascade[l]`, then
/// `cascade[l] = x[l]`. `active` is empty (all lanes active) or one
/// word per lane (`!0` = active, `0` = frozen: both outputs keep their
/// old value bit-for-bit).
pub type CascadeRowFn =
    fn(f: Nonlinearity, ps: &[f32], qs: &[f32], x_row: &mut [f32], j_row: &[f32], cascade: &mut [f32], active: &[u32]);

/// One DPRR element row over lanes: `acc[l] += xi[l]·xm[l]` for active
/// lanes (same `active` contract as [`CascadeRowFn`]).
pub type DprrRowFn = fn(acc_row: &mut [f32], xi: &[f32], xm: &[f32], active: &[u32]);

/// DPRR bias-column row over lanes: `acc[l] += xi[l]` for active lanes.
pub type DprrBiasFn = fn(acc_row: &mut [f32], xi: &[f32], active: &[u32]);

/// Packed-lower-triangle rank-k Gram update `P += Σ_b r_b r_bᵀ`
/// (`rs` row-major B×s) — the `accumulate_block` hot loop.
pub type GramRankkFn = fn(p: &mut [f32], rs: &[f32], s: usize);

/// `row[j] += a·x[j]` — the per-row axpy of the packed rank-1 fold
/// (`OnlineRidge`'s Gram-shadow update).
pub type AxpyFn = fn(row: &mut [f32], a: f32, x: &[f32]);

/// Dot product — the per-class score reduction of `scores_from_r_tilde`.
pub type DotFn = fn(a: &[f32], b: &[f32]) -> f32;

/// The dispatch table. `Copy` by design: engines, accumulators and the
/// online-ridge factor each embed their own copy, so per-shard selection
/// costs nothing and never chases a pointer on the hot path.
#[derive(Clone, Copy)]
pub struct Kernels {
    /// implementation name for logs/metrics/benches ("scalar", "avx2")
    pub name: &'static str,
    pub cascade_row: CascadeRowFn,
    pub dprr_row: DprrRowFn,
    pub dprr_bias: DprrBiasFn,
    pub gram_rankk: GramRankkFn,
    pub axpy: AxpyFn,
    pub dot: DotFn,
}

impl fmt::Debug for Kernels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Kernels").field("name", &self.name).finish()
    }
}

impl PartialEq for Kernels {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
    }
}

impl Default for Kernels {
    fn default() -> Self {
        Kernels::scalar()
    }
}

impl Kernels {
    /// The portable scalar table — the reference implementation every
    /// other table is pinned against. Always available on every target.
    pub const fn scalar() -> Kernels {
        Kernels {
            name: "scalar",
            cascade_row: scalar::cascade_row,
            dprr_row: scalar::dprr_row,
            dprr_bias: scalar::dprr_bias,
            gram_rankk: scalar::gram_rankk,
            axpy: scalar::axpy,
            dot: scalar::dot,
        }
    }

    /// The AVX2 table. Present only on x86-64 builds; callers go through
    /// [`try_select`](Self::try_select), which guards construction with
    /// CPU feature detection.
    #[cfg(target_arch = "x86_64")]
    fn avx2_table() -> Kernels {
        Kernels {
            name: "avx2",
            cascade_row: avx2::cascade_row,
            dprr_row: avx2::dprr_row,
            dprr_bias: avx2::dprr_bias,
            gram_rankk: avx2::gram_rankk,
            axpy: avx2::axpy,
            dot: avx2::dot,
        }
    }

    #[cfg(target_arch = "x86_64")]
    fn avx2_table_opt() -> Option<Kernels> {
        Some(Self::avx2_table())
    }

    /// Non-x86-64 targets have no vector table: `Force` is a typed
    /// error and `Auto` degrades to scalar (acceptance criterion: the
    /// default build compiles and selects scalar everywhere else).
    #[cfg(not(target_arch = "x86_64"))]
    fn avx2_table_opt() -> Option<Kernels> {
        None
    }

    /// Select a table for `mode` using live CPU detection.
    pub fn try_select(mode: SimdMode) -> Result<Kernels, SimdError> {
        Self::try_select_with(mode, avx2_available())
    }

    /// Selection with the detection result injected — the deterministic
    /// seam the `--simd force`-without-AVX2 error path is tested through
    /// on any host. `detected` is ANDed with compile-time availability,
    /// so a forged `true` on a non-x86-64 target still errors instead of
    /// fabricating an unusable table.
    pub fn try_select_with(mode: SimdMode, detected: bool) -> Result<Kernels, SimdError> {
        match mode {
            SimdMode::Off => Ok(Self::scalar()),
            SimdMode::Force => {
                if !detected {
                    return Err(SimdError::Unsupported {
                        wanted: "avx2+fma",
                        target: std::env::consts::ARCH,
                    });
                }
                Self::avx2_table_opt().ok_or(SimdError::Unsupported {
                    wanted: "avx2+fma",
                    target: std::env::consts::ARCH,
                })
            }
            SimdMode::Auto => Ok(match Self::avx2_table_opt() {
                Some(simd) if detected => probe_pick(Self::scalar(), simd),
                _ => Self::scalar(),
            }),
        }
    }
}

/// Whether the running CPU supports every instruction the AVX2 table
/// emits (AVX2 for the bitwise kernels, FMA for the Gram/score ones).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// SIMD selection policy (`--simd` / `DFR_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// benchmark-at-boot probe picks the faster table (scalar when the
    /// CPU lacks AVX2)
    Auto,
    /// require the AVX2 table; typed error if the host cannot run it
    Force,
    /// scalar, unconditionally (the process default)
    Off,
}

impl SimdMode {
    pub fn parse(s: &str) -> Result<SimdMode, SimdError> {
        match s {
            "auto" => Ok(SimdMode::Auto),
            "force" => Ok(SimdMode::Force),
            "off" => Ok(SimdMode::Off),
            other => Err(SimdError::BadMode(other.to_string())),
        }
    }
}

/// Typed selection failure — surfaced as a CLI error for `--simd force`
/// on an unsupported host (graceful, never UB: the vector table is not
/// constructed at all).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimdError {
    /// the host CPU (or compile target) cannot run the requested table
    Unsupported {
        wanted: &'static str,
        target: &'static str,
    },
    /// unparseable `--simd` / `DFR_SIMD` value
    BadMode(String),
}

impl fmt::Display for SimdError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimdError::Unsupported { wanted, target } => write!(
                f,
                "--simd force: this host ({target}) does not support {wanted}; \
                 use --simd auto (probe) or off (scalar)"
            ),
            SimdError::BadMode(m) => {
                write!(f, "unknown SIMD mode {m:?} (expected force|off|auto)")
            }
        }
    }
}

impl std::error::Error for SimdError {}

// ---------------------------------------------------------------------------
// benchmark-at-boot probe
// ---------------------------------------------------------------------------

/// Probe workload shape: one jpvow-scale cascade row sweep (Nx = 30
/// nodes × 64 lanes) plus a DPRR row — the actual hot loops, small
/// enough to stay in L1 so the probe measures compute, not memory.
const PROBE_NX: usize = 30;
const PROBE_LANES: usize = 64;
const PROBE_REPS: usize = 200;
const PROBE_ROUNDS: usize = 3;

fn probe_run(k: &Kernels, x: &mut [f32], j: &[f32], ps: &[f32], qs: &[f32], cascade: &mut [f32]) {
    for n in 0..PROBE_NX {
        let row = n * PROBE_LANES;
        (k.cascade_row)(
            Nonlinearity::Linear { alpha: 1.0 },
            ps,
            qs,
            &mut x[row..row + PROBE_LANES],
            &j[row..row + PROBE_LANES],
            cascade,
            &[],
        );
    }
}

fn probe_time(k: &Kernels) -> std::time::Duration {
    let mut x = vec![0.0f32; PROBE_NX * PROBE_LANES];
    let j: Vec<f32> = (0..PROBE_NX * PROBE_LANES)
        .map(|i| (i as f32 * 0.37).sin() * 0.5)
        .collect();
    let ps = vec![0.2f32; PROBE_LANES];
    let qs = vec![0.3f32; PROBE_LANES];
    let mut cascade = vec![0.0f32; PROBE_LANES];
    // warm-up round, then best-of-N to shrug off scheduler noise
    probe_run(k, &mut x, &j, &ps, &qs, &mut cascade);
    let mut best = std::time::Duration::MAX;
    for _ in 0..PROBE_ROUNDS {
        let t0 = Instant::now();
        for _ in 0..PROBE_REPS {
            probe_run(k, &mut x, &j, &ps, &qs, &mut cascade);
        }
        best = best.min(t0.elapsed());
    }
    // keep the state observable so the kernel calls cannot be elided
    std::hint::black_box(&x);
    best
}

/// The `Auto` selector: race the two cascade kernels on a synthetic
/// batch and keep the winner. Runs once per selection (the global table
/// caches its result), costs single-digit milliseconds at boot.
fn probe_pick(scalar: Kernels, simd: Kernels) -> Kernels {
    let t_scalar = probe_time(&scalar);
    let t_simd = probe_time(&simd);
    let win = if t_simd < t_scalar { simd } else { scalar };
    crate::log_info!(
        "simd boot probe: scalar {:?} vs {} {:?} -> {}",
        t_scalar,
        simd.name,
        t_simd,
        win.name
    );
    win
}

// ---------------------------------------------------------------------------
// process-wide selection
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<Kernels> = OnceLock::new();

/// Pin the process-wide kernel table (the CLI calls this once, before
/// any engine or accumulator is built). Returns `false` if the table was
/// already resolved — later calls never flip kernels mid-process, which
/// is what keeps checkpoint/hibernate round-trips bitwise reproducible.
pub fn set_global_kernels(k: Kernels) -> bool {
    GLOBAL.set(k).is_ok()
}

/// The process-wide kernel table. Resolved once, from the `DFR_SIMD`
/// env knob (`force|off|auto`) — unset means scalar, so existing
/// builds/tests/results are byte-for-byte unaffected unless SIMD is
/// asked for. A `force` that the host cannot satisfy logs and falls
/// back to scalar here (library context); the CLI's `--simd force`
/// path surfaces the typed error instead of starting.
pub fn global_kernels() -> Kernels {
    *GLOBAL.get_or_init(|| match std::env::var("DFR_SIMD") {
        Err(_) => Kernels::scalar(),
        Ok(v) => match SimdMode::parse(&v).and_then(Kernels::try_select) {
            Ok(k) => {
                crate::log_info!("DFR_SIMD={v}: kernel table '{}'", k.name);
                k
            }
            Err(e) => {
                crate::log_warn!("DFR_SIMD={v}: {e}; falling back to scalar kernels");
                Kernels::scalar()
            }
        },
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("force").unwrap(), SimdMode::Force);
        assert_eq!(SimdMode::parse("off").unwrap(), SimdMode::Off);
        assert!(matches!(
            SimdMode::parse("fast"),
            Err(SimdError::BadMode(_))
        ));
    }

    #[test]
    fn off_is_scalar_everywhere() {
        assert_eq!(Kernels::try_select(SimdMode::Off).unwrap().name, "scalar");
    }

    #[test]
    fn force_without_detection_is_a_typed_error() {
        // the deterministic seam: regardless of the running host, a
        // negative detection must produce the typed error (not UB, not
        // a panic) — this is the `--simd force` no-AVX2 path
        let err = Kernels::try_select_with(SimdMode::Force, false).unwrap_err();
        assert!(matches!(err, SimdError::Unsupported { .. }));
        let msg = err.to_string();
        assert!(msg.contains("--simd force"), "actionable message: {msg}");
    }

    #[test]
    fn auto_never_fails() {
        // on AVX2 hosts the probe picks a winner, elsewhere scalar —
        // either way Auto must always return a table
        let k = Kernels::try_select(SimdMode::Auto).unwrap();
        assert!(k.name == "scalar" || k.name == "avx2");
    }

    #[test]
    fn force_matches_detection() {
        match Kernels::try_select(SimdMode::Force) {
            Ok(k) => {
                assert!(avx2_available());
                assert_eq!(k.name, "avx2");
            }
            Err(e) => {
                assert!(!avx2_available());
                assert!(matches!(e, SimdError::Unsupported { .. }));
            }
        }
    }
}
