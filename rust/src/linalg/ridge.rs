//! Online Ridge-regression driver: streaming accumulation of `A`/`B` and
//! β-swept solving — what the coordinator's RidgeTrain phase runs.
//!
//! Accumulates `A = E R̃ᵀ` (ny×s) and the packed lower triangle of
//! `B₀ = R̃ R̃ᵀ` **sample by sample** as rank-1 updates — the edge device
//! never stores the design matrix `R̃` (which would be Train×s words).
//! Solving copies `B₀`, shifts the diagonal by β, and runs either the
//! proposed Cholesky pipeline or the Gaussian baseline.

use super::buffered::ridge_cholesky_buffered;
use super::cholesky1d::{cholesky_1d, ridge_cholesky_1d, solve_c_inplace, solve_ct_inplace};
use super::cholupdate::{chol_downdate_1d, chol_update_1d};
use super::counters::{NoCount, Ops};
use super::gaussian::{ridge_gaussian, GaussianWorkspace};
use super::{tri, tri_len, unpack_symmetric};
use crate::simd::{global_kernels, Kernels};

/// Which solver backs the ridge solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RidgeMethod {
    /// Algorithm 1 (Gauss–Jordan) — the paper's naive baseline.
    Gaussian,
    /// Algorithms 2–4 (in-place 1-D Cholesky) — the proposed method.
    Cholesky1d,
    /// Algorithms 2 + 5 (Cholesky with the write-buffered substitutions)
    /// — what the FPGA executes.
    CholeskyBuffered,
}

/// Streaming accumulator for the ridge system.
pub struct RidgeAccumulator {
    pub s: usize,
    pub ny: usize,
    /// packed lower triangle of B₀ = Σ r̃ r̃ᵀ (no β)
    pub b_packed: Vec<f32>,
    /// A = Σ e r̃ᵀ, row-major ny×s
    pub a: Vec<f32>,
    /// number of samples folded in
    pub count: usize,
    /// compute-kernel table for the Gram folds (process default unless
    /// pinned via [`with_kernels`](Self::with_kernels))
    kernels: Kernels,
}

impl RidgeAccumulator {
    pub fn new(s: usize, ny: usize) -> Self {
        Self::with_kernels(s, ny, global_kernels())
    }

    /// An accumulator pinned to an explicit kernel table (the batch
    /// trainer and the benches use this; [`new`](Self::new) takes the
    /// process-wide selection).
    pub fn with_kernels(s: usize, ny: usize, kernels: Kernels) -> Self {
        RidgeAccumulator {
            s,
            ny,
            b_packed: vec![0.0; tri_len(s)],
            a: vec![0.0; ny * s],
            count: 0,
            kernels,
        }
    }

    /// Fold one sample: `B₀ += r̃ r̃ᵀ` (lower triangle), `A[class] += r̃`
    /// (Eq. 38; `e` one-hot makes A's update a single-row add).
    pub fn accumulate(&mut self, r_tilde: &[f32], class: usize) {
        assert_eq!(r_tilde.len(), self.s);
        assert!(class < self.ny);
        rank1_update_packed_with(&mut self.b_packed, r_tilde, &self.kernels);
        let row = &mut self.a[class * self.s..(class + 1) * self.s];
        for (a, r) in row.iter_mut().zip(r_tilde) {
            *a += r;
        }
        self.count += 1;
    }

    /// Fold a block of B samples in ONE pass over the packed triangle:
    /// `B₀ += Σ_b r̃_b r̃_bᵀ`, `A[class_b] += r̃_b`. `rs` is row-major
    /// B×s, one feature vector per entry of `labels`.
    ///
    /// Each cache line of the s(s+1)/2-word triangle (1.7 MB at paper
    /// scale, s = 931 — far beyond L2) is loaded and stored once per
    /// *block* instead of once per *sample*, which is where the ≥2×
    /// rank-k speedup comes from (see `rankk_update_packed` and
    /// `benches/hotpath_micro.rs`). The f32 sums are reassociated
    /// relative to B sequential [`accumulate`] calls; the equivalence
    /// property test bounds the difference at 1e-5 relative.
    pub fn accumulate_block(&mut self, rs: &[f32], labels: &[usize]) {
        assert_eq!(rs.len(), labels.len() * self.s, "block shape mismatch");
        for (r, &class) in rs.chunks_exact(self.s).zip(labels) {
            assert!(class < self.ny);
            let row = &mut self.a[class * self.s..(class + 1) * self.s];
            for (a, x) in row.iter_mut().zip(r) {
                *a += x;
            }
        }
        rankk_update_packed_with(&mut self.b_packed, rs, self.s, &self.kernels);
        self.count += labels.len();
    }

    pub fn reset(&mut self) {
        self.b_packed.fill(0.0);
        self.a.fill(0.0);
        self.count = 0;
    }

    /// Solve for `W̃_out` with the given β. Returns the solution and the
    /// number of memory words the chosen method required.
    pub fn solve(&self, beta: f32, method: RidgeMethod) -> RidgeSolution {
        self.solve_counted(beta, method, &mut NoCount)
    }

    /// Solve with operation counting (Table 3 / Fig. 9 benches).
    pub fn solve_counted<O: Ops>(
        &self,
        beta: f32,
        method: RidgeMethod,
        ops: &mut O,
    ) -> RidgeSolution {
        let s = self.s;
        let ny = self.ny;
        match method {
            RidgeMethod::Gaussian => {
                let mut b = unpack_symmetric(&self.b_packed, s);
                for i in 0..s {
                    b[i * s + i] += beta;
                }
                let mut ws = GaussianWorkspace::new(s, ny);
                ridge_gaussian(&self.a, &b, &mut ws, ops);
                RidgeSolution {
                    w_tilde: ws.w_out,
                    s,
                    ny,
                    beta,
                    memory_words: super::counters::memory_words_naive(s, ny),
                }
            }
            RidgeMethod::Cholesky1d | RidgeMethod::CholeskyBuffered => {
                let mut p = self.b_packed.clone();
                for i in 0..s {
                    p[tri(i, i)] += beta;
                }
                let mut q = self.a.clone();
                match method {
                    RidgeMethod::Cholesky1d => ridge_cholesky_1d(&mut p, &mut q, s, ny, ops),
                    _ => ridge_cholesky_buffered(&mut p, &mut q, s, ny, ops),
                }
                RidgeSolution {
                    w_tilde: q,
                    s,
                    ny,
                    beta,
                    memory_words: super::counters::memory_words_proposed(s, ny),
                }
            }
        }
    }

    /// Like [`solve`](Self::solve), but reusing `ws` for the β-shifted
    /// triangle and the RHS — the sweep's hot path copies into the
    /// workspace instead of cloning the ~s²/2-word triangle (1.7 MB at
    /// paper scale) once per β. Identical math and op order, so results
    /// are bitwise equal to [`solve`]. The Gaussian baseline keeps its
    /// own dense workspace and falls back to the allocating path.
    pub fn solve_with_workspace(
        &self,
        beta: f32,
        method: RidgeMethod,
        ws: &mut SolveWorkspace,
    ) -> RidgeSolution {
        if method == RidgeMethod::Gaussian {
            return self.solve(beta, method);
        }
        let s = self.s;
        let ny = self.ny;
        if ws.tri.len() != self.b_packed.len() {
            ws.tri.resize(self.b_packed.len(), 0.0);
        }
        ws.tri.copy_from_slice(&self.b_packed);
        for i in 0..s {
            ws.tri[tri(i, i)] += beta;
        }
        if ws.rhs.len() != self.a.len() {
            ws.rhs.resize(self.a.len(), 0.0);
        }
        ws.rhs.copy_from_slice(&self.a);
        match method {
            RidgeMethod::Cholesky1d => {
                ridge_cholesky_1d(&mut ws.tri, &mut ws.rhs, s, ny, &mut NoCount)
            }
            _ => ridge_cholesky_buffered(&mut ws.tri, &mut ws.rhs, s, ny, &mut NoCount),
        }
        RidgeSolution {
            w_tilde: ws.rhs.clone(),
            s,
            ny,
            beta,
            memory_words: super::counters::memory_words_proposed(s, ny),
        }
    }

    /// Sweep β values (the paper's {1e-6, 1e-4, 1e-2, 1}), returning the
    /// solution with the lowest loss under `loss_fn(w_tilde) -> f32`.
    pub fn solve_best_beta(
        &self,
        betas: &[f32],
        method: RidgeMethod,
        loss_fn: impl FnMut(&RidgeSolution) -> f32,
    ) -> (RidgeSolution, f32) {
        let mut ws = SolveWorkspace::new(self.s, self.ny);
        self.solve_best_beta_with(betas, method, &mut ws, loss_fn)
    }

    /// [`solve_best_beta`](Self::solve_best_beta) with a caller-owned
    /// workspace: one scratch triangle is reused across the whole sweep
    /// instead of a fresh clone per β.
    pub fn solve_best_beta_with(
        &self,
        betas: &[f32],
        method: RidgeMethod,
        ws: &mut SolveWorkspace,
        mut loss_fn: impl FnMut(&RidgeSolution) -> f32,
    ) -> (RidgeSolution, f32) {
        assert!(!betas.is_empty());
        let mut best: Option<(RidgeSolution, f32)> = None;
        for &beta in betas {
            let sol = self.solve_with_workspace(beta, method, ws);
            // non-finite loss means the f32 factorization degenerated at
            // this β (rank-deficient B with β ≪ diag); treat as +inf so
            // the sweep can never select it
            let raw = loss_fn(&sol);
            let loss = if raw.is_finite() { raw } else { f32::INFINITY };
            if best.as_ref().map_or(true, |(_, l)| loss < *l) {
                best = Some((sol, loss));
            }
        }
        best.unwrap()
    }

    /// β sweep with the independent per-β solves spread over scoped
    /// worker threads, each with its own [`SolveWorkspace`]. Selection
    /// is identical to [`solve_best_beta`](Self::solve_best_beta):
    /// lowest finite loss wins, ties resolve to the earliest entry of
    /// `betas` (the results are gathered in input order).
    pub fn solve_best_beta_parallel(
        &self,
        betas: &[f32],
        method: RidgeMethod,
        threads: usize,
        loss_fn: impl Fn(&RidgeSolution) -> f32 + Sync,
    ) -> (RidgeSolution, f32) {
        assert!(!betas.is_empty());
        if threads <= 1 || betas.len() == 1 {
            return self.solve_best_beta(betas, method, loss_fn);
        }
        // one contiguous β chunk — and therefore ONE workspace — per
        // worker; flattening contiguous chunks preserves input order
        let per_worker = (betas.len() + threads - 1) / threads;
        let chunks: Vec<&[f32]> = betas.chunks(per_worker).collect();
        let solved = crate::util::scoped_pool::scoped_map(&chunks, threads, |chunk| {
            let mut ws = SolveWorkspace::new(self.s, self.ny);
            chunk
                .iter()
                .map(|&beta| {
                    let sol = self.solve_with_workspace(beta, method, &mut ws);
                    let raw = loss_fn(&sol);
                    let loss = if raw.is_finite() { raw } else { f32::INFINITY };
                    (sol, loss)
                })
                .collect::<Vec<_>>()
        });
        let mut best: Option<(RidgeSolution, f32)> = None;
        for (sol, loss) in solved.into_iter().flatten() {
            if best.as_ref().map_or(true, |(_, l)| loss < *l) {
                best = Some((sol, loss));
            }
        }
        best.unwrap()
    }
}

/// Reusable β-sweep workspace: one packed-triangle scratch plus one RHS
/// scratch, shared across every β of a sweep (see
/// [`RidgeAccumulator::solve_with_workspace`]).
pub struct SolveWorkspace {
    tri: Vec<f32>,
    rhs: Vec<f32>,
}

impl SolveWorkspace {
    pub fn new(s: usize, ny: usize) -> Self {
        SolveWorkspace {
            tri: vec![0.0; tri_len(s)],
            rhs: vec![0.0; ny * s],
        }
    }
}

// ---------------------------------------------------------------------------
// streaming online ridge
// ---------------------------------------------------------------------------

/// Knobs of the [`OnlineRidge`] streaming accumulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineRidgeConfig {
    /// ridge shift β, baked into the maintained system at construction
    /// (`B = βI` before the first fold)
    pub beta: f32,
    /// exponential forgetting factor λ ∈ (0, 1]; every fold first scales
    /// `B ← λB`, `A ← λA` (so the βI term decays too, as in classic
    /// RLS). 1.0 disables decay. Mutually exclusive with `window`.
    pub lambda: f32,
    /// sliding window: once this many samples are held, each fold first
    /// **downdates** the oldest sample out of the factor (and subtracts
    /// it exactly from the Gram shadow). `None` = grow forever.
    pub window: Option<usize>,
    /// drift bound: fully re-factorize the Cholesky factor from the
    /// exact Gram shadow every K folds (0 = only on downdate failure).
    pub refactor_every: usize,
}

impl Default for OnlineRidgeConfig {
    fn default() -> Self {
        OnlineRidgeConfig {
            beta: 1e-2,
            lambda: 1.0,
            window: None,
            refactor_every: 64,
        }
    }
}

/// What one [`OnlineRidge::observe`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObserveStats {
    /// total samples folded in over the accumulator's lifetime
    pub updates: u64,
    /// samples currently inside the maintained system (ring occupancy in
    /// window mode; total folds otherwise)
    pub window_len: usize,
    /// whether this fold triggered a full re-factorization (periodic
    /// cadence or downdate failure)
    pub refactored: bool,
}

/// Streaming online ridge: maintains the **solved** output layer under a
/// per-sample cost of O(s²) — against the O(N·s²/2 + s³/6) of
/// re-accumulating and re-factorizing from scratch.
///
/// State (all fixed-size, allocated once in [`new`](Self::new); the
/// steady-state [`observe`](Self::observe) performs **zero heap
/// allocations** — asserted in `tests/zero_alloc.rs`):
///
/// * `chol` — packed Cholesky factor of `M = B + βI` (same 1-D layout as
///   `cholesky1d`), advanced by rank-1 [`chol_update_1d`] /
///   [`chol_downdate_1d`] rotations;
/// * `b` — the exact Gram **shadow** of the same `M`, advanced by plain
///   rank-1 adds/subtracts. The factor's float drift is bounded by
///   re-factorizing from this shadow every `refactor_every` folds, and
///   it doubles as the recovery source when a downdate reports loss of
///   positive definiteness;
/// * `a` — the right-hand side `A = Σ e r̃ᵀ` (one-hot targets → row add);
/// * `w` — the current `W̃_out`, re-solved in place (Algorithms 3–4,
///   O(N_y·s²)) after each fold;
/// * the sample ring (window mode only) holding the raw `r̃` vectors
///   that will eventually be downdated back out.
pub struct OnlineRidge {
    s: usize,
    ny: usize,
    cfg: OnlineRidgeConfig,
    /// packed factor C with C Cᵀ = B + (decayed) βI
    chol: Vec<f32>,
    /// exact Gram shadow of the same matrix
    b: Vec<f32>,
    /// A, row-major ny×s
    a: Vec<f32>,
    /// solved W̃_out, row-major ny×s
    w: Vec<f32>,
    /// rotation scratch (destroyed by update/downdate)
    x: Vec<f32>,
    /// flat ring of window samples (window mode), window·s words
    ring: Vec<f32>,
    ring_labels: Vec<usize>,
    ring_head: usize,
    ring_len: usize,
    updates: u64,
    since_refactor: usize,
    refactors: u64,
    /// Kernel table for the rank-1 Gram update/downdate pair (process
    /// default at construction; see [`set_kernels`](Self::set_kernels)).
    /// Deliberately **not** part of [`OnlineRidgeState`]: kernel choice
    /// is a process-global property, so a checkpoint restored in the
    /// same process continues bitwise on the same table.
    kernels: Kernels,
}

impl OnlineRidge {
    pub fn new(s: usize, ny: usize, cfg: OnlineRidgeConfig) -> Self {
        assert!(s > 0 && ny > 0, "degenerate system {s}x{ny}");
        assert!(cfg.beta > 0.0, "online ridge needs β > 0 (factor of βI seeds the state)");
        assert!(
            cfg.lambda > 0.0 && cfg.lambda <= 1.0,
            "forgetting factor λ must be in (0, 1], got {}",
            cfg.lambda
        );
        assert!(
            cfg.window.is_none() || cfg.lambda == 1.0,
            "sliding window and λ-forgetting are mutually exclusive (an evicted \
             sample would need its decayed weight tracked to downdate exactly)"
        );
        let window = cfg.window.unwrap_or(0);
        assert!(cfg.window.is_none() || window > 0, "window must be ≥ 1");
        let mut chol = vec![0.0f32; tri_len(s)];
        let mut b = vec![0.0f32; tri_len(s)];
        for i in 0..s {
            b[tri(i, i)] = cfg.beta;
            chol[tri(i, i)] = cfg.beta.sqrt();
        }
        OnlineRidge {
            s,
            ny,
            cfg,
            chol,
            b,
            a: vec![0.0; ny * s],
            w: vec![0.0; ny * s],
            x: vec![0.0; s],
            ring: vec![0.0; window * s],
            ring_labels: vec![0; window],
            ring_head: 0,
            ring_len: 0,
            updates: 0,
            since_refactor: 0,
            refactors: 0,
            kernels: global_kernels(),
        }
    }

    /// Override the kernel table (update **and** downdate switch
    /// together — see [`rank1_sub_packed_with`]). Intended for engines /
    /// tests that pin a specific table; the default is the process
    /// selection.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
    }

    pub fn s(&self) -> usize {
        self.s
    }

    pub fn ny(&self) -> usize {
        self.ny
    }

    pub fn beta(&self) -> f32 {
        self.cfg.beta
    }

    /// The accumulator's construction-time knobs — callers that need to
    /// rebuild an equivalent accumulator (e.g. the session's
    /// re-featurization reseed) clone the configuration from here.
    pub fn config(&self) -> OnlineRidgeConfig {
        self.cfg
    }

    /// Total samples folded in.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Samples currently inside the maintained system (see
    /// [`ObserveStats::window_len`]).
    pub fn window_len(&self) -> usize {
        if self.cfg.window.is_some() {
            self.ring_len
        } else {
            self.updates as usize
        }
    }

    /// Full re-factorizations performed (periodic + recovery).
    pub fn refactors(&self) -> u64 {
        self.refactors
    }

    /// The current solution W̃_out (row-major ny×s) — valid after
    /// [`observe`](Self::observe) or [`solve_now`](Self::solve_now).
    pub fn w_tilde(&self) -> &[f32] {
        &self.w
    }

    /// argmax of `W̃_out r̃` under the current solution (no allocation,
    /// no softmax — monotone-equivalent for classification).
    pub fn predict_class(&self, r_tilde: &[f32]) -> usize {
        assert_eq!(r_tilde.len(), self.s);
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for i in 0..self.ny {
            let row = &self.w[i * self.s..(i + 1) * self.s];
            let score: f32 = row.iter().zip(r_tilde).map(|(w, r)| w * r).sum();
            if score > best_score {
                best = i;
                best_score = score;
            }
        }
        best
    }

    /// Fold one labelled sample **without** re-solving — the seeding
    /// path (batch → online handoff folds N samples, then solves once).
    /// Returns whether a full re-factorization happened.
    pub fn fold(&mut self, r_tilde: &[f32], class: usize) -> bool {
        assert_eq!(r_tilde.len(), self.s);
        assert!(class < self.ny);
        let mut refactored = false;

        // 1. evict the sample sliding out of the window: subtract it
        //    exactly from the shadow + RHS, hyperbolically rotate it out
        //    of the factor (recover from the shadow if that degenerates)
        if let Some(cap) = self.cfg.window {
            if self.ring_len == cap {
                let slot = self.ring_head;
                let old_class = self.ring_labels[slot];
                self.x.copy_from_slice(&self.ring[slot * self.s..(slot + 1) * self.s]);
                rank1_sub_packed_with(&mut self.b, &self.x, &self.kernels);
                let row = &mut self.a[old_class * self.s..(old_class + 1) * self.s];
                for (a, r) in row.iter_mut().zip(&self.x) {
                    *a -= r;
                }
                self.ring_len -= 1;
                self.ring_head = (self.ring_head + 1) % cap;
                if chol_downdate_1d(&mut self.chol, self.s, &mut self.x, &mut NoCount).is_err() {
                    // the shadow already has the eviction applied
                    // exactly — rebuild the factor from it
                    self.refactor();
                    refactored = true;
                }
            }
        }

        // 2. exponential forgetting: B ← λB (factor scales by √λ)
        if self.cfg.lambda < 1.0 {
            let sqrt_l = self.cfg.lambda.sqrt();
            for c in self.chol.iter_mut() {
                *c *= sqrt_l;
            }
            for b in self.b.iter_mut() {
                *b *= self.cfg.lambda;
            }
            for a in self.a.iter_mut() {
                *a *= self.cfg.lambda;
            }
        }

        // 3. fold the new sample into shadow, RHS, ring, and factor
        rank1_update_packed_with(&mut self.b, r_tilde, &self.kernels);
        let row = &mut self.a[class * self.s..(class + 1) * self.s];
        for (a, r) in row.iter_mut().zip(r_tilde) {
            *a += r;
        }
        if let Some(cap) = self.cfg.window {
            let slot = (self.ring_head + self.ring_len) % cap;
            self.ring[slot * self.s..(slot + 1) * self.s].copy_from_slice(r_tilde);
            self.ring_labels[slot] = class;
            self.ring_len += 1;
        }
        self.x.copy_from_slice(r_tilde);
        chol_update_1d(&mut self.chol, self.s, &mut self.x, &mut NoCount);
        self.updates += 1;
        self.since_refactor += 1;

        // 4. drift bound: periodic refactor from the exact shadow
        if self.cfg.refactor_every > 0 && self.since_refactor >= self.cfg.refactor_every {
            self.refactor();
            refactored = true;
        }
        refactored
    }

    /// Re-solve W̃_out from the current factor and RHS (Algorithms 3–4
    /// in place over the `w` buffer, O(N_y·s²), no allocation).
    pub fn solve_now(&mut self) {
        self.w.copy_from_slice(&self.a);
        solve_ct_inplace(&mut self.w, &self.chol, self.s, self.ny, &mut NoCount);
        solve_c_inplace(&mut self.w, &self.chol, self.s, self.ny, &mut NoCount);
    }

    /// The Serve-phase hot path: fold one labelled sample and refresh
    /// the solved output layer. O(s²) + O(N_y·s²), zero allocations.
    pub fn observe(&mut self, r_tilde: &[f32], class: usize) -> ObserveStats {
        let refactored = self.fold(r_tilde, class);
        self.solve_now();
        ObserveStats {
            updates: self.updates,
            window_len: self.window_len(),
            refactored,
        }
    }

    /// Rebuild the factor from the exact Gram shadow (O(s³/6)).
    fn refactor(&mut self) {
        self.chol.copy_from_slice(&self.b);
        cholesky_1d(&mut self.chol, self.s, &mut NoCount);
        self.since_refactor = 0;
        self.refactors += 1;
    }

    /// Copy out the complete accumulator state — factor, shadow, RHS,
    /// solved layer, sample ring and counters — for durable
    /// checkpointing. Importing the result through
    /// [`from_state`](Self::from_state) yields an accumulator whose
    /// every subsequent [`observe`](Self::observe) is **bitwise equal**
    /// to continuing on the original (`x` is pure scratch, destroyed by
    /// each fold, so it is not part of the state).
    pub fn export_state(&self) -> OnlineRidgeState {
        OnlineRidgeState {
            cfg: self.cfg,
            s: self.s,
            ny: self.ny,
            chol: self.chol.clone(),
            b: self.b.clone(),
            a: self.a.clone(),
            w: self.w.clone(),
            ring: self.ring.clone(),
            ring_labels: self.ring_labels.clone(),
            ring_head: self.ring_head,
            ring_len: self.ring_len,
            updates: self.updates,
            since_refactor: self.since_refactor,
            refactors: self.refactors,
        }
    }

    /// Rebuild an accumulator from [`export_state`](Self::export_state)
    /// output. Every invariant `new` asserts is re-validated here as a
    /// typed error instead of a panic — the input may come from a
    /// corrupted or foreign checkpoint.
    pub fn from_state(st: OnlineRidgeState) -> Result<Self, String> {
        let OnlineRidgeState {
            cfg,
            s,
            ny,
            chol,
            b,
            a,
            w,
            ring,
            ring_labels,
            ring_head,
            ring_len,
            updates,
            since_refactor,
            refactors,
        } = st;
        if s == 0 || ny == 0 {
            return Err(format!("degenerate system {s}x{ny}"));
        }
        if !(cfg.beta > 0.0) {
            return Err(format!("β must be > 0, got {}", cfg.beta));
        }
        if !(cfg.lambda > 0.0 && cfg.lambda <= 1.0) {
            return Err(format!("λ must be in (0, 1], got {}", cfg.lambda));
        }
        if cfg.window.is_some() && cfg.lambda != 1.0 {
            return Err("window and λ-forgetting are mutually exclusive".into());
        }
        let window = cfg.window.unwrap_or(0);
        if cfg.window.is_some() && window == 0 {
            return Err("window must be ≥ 1".into());
        }
        if chol.len() != tri_len(s) || b.len() != tri_len(s) {
            return Err(format!(
                "triangle length mismatch: chol {} / shadow {} vs tri_len({s}) = {}",
                chol.len(),
                b.len(),
                tri_len(s)
            ));
        }
        if a.len() != ny * s || w.len() != ny * s {
            return Err(format!(
                "rhs/solution length mismatch: a {} / w {} vs {ny}·{s}",
                a.len(),
                w.len()
            ));
        }
        if ring.len() != window * s || ring_labels.len() != window {
            return Err(format!(
                "ring length mismatch: {} words / {} labels vs window {window} · s {s}",
                ring.len(),
                ring_labels.len()
            ));
        }
        if ring_len > window || (window > 0 && ring_head >= window) {
            return Err(format!(
                "ring cursor out of range: head {ring_head} len {ring_len} window {window}"
            ));
        }
        if ring_labels.iter().any(|&l| l >= ny) {
            return Err(format!("ring label out of range (ny = {ny})"));
        }
        Ok(OnlineRidge {
            s,
            ny,
            cfg,
            chol,
            b,
            a,
            w,
            x: vec![0.0; s],
            ring,
            ring_labels,
            ring_head,
            ring_len,
            updates,
            since_refactor,
            refactors,
            kernels: global_kernels(),
        })
    }
}

/// Plain-data copy of an [`OnlineRidge`]'s complete state (minus the
/// fold scratch, which carries no information between folds) — the
/// serialization bridge for the coordinator's durable session
/// checkpoints ([`OnlineRidge::export_state`] /
/// [`OnlineRidge::from_state`]).
#[derive(Clone, Debug, PartialEq)]
pub struct OnlineRidgeState {
    pub cfg: OnlineRidgeConfig,
    pub s: usize,
    pub ny: usize,
    /// packed Cholesky factor, `tri_len(s)` words
    pub chol: Vec<f32>,
    /// exact Gram shadow, `tri_len(s)` words
    pub b: Vec<f32>,
    /// RHS `A`, row-major ny×s
    pub a: Vec<f32>,
    /// solved `W̃_out`, row-major ny×s
    pub w: Vec<f32>,
    /// flat sample ring (window mode), `window·s` words
    pub ring: Vec<f32>,
    pub ring_labels: Vec<usize>,
    pub ring_head: usize,
    pub ring_len: usize,
    pub updates: u64,
    pub since_refactor: usize,
    pub refactors: u64,
}

/// Shared core of [`rank1_update_packed`] / [`rank1_sub_packed`]: the
/// sign is applied to the broadcast `r[i]` once per row (an exact IEEE
/// sign flip), so both directions run the identical per-row axpy kernel
/// (`crate::simd`: 4-wide chunked scalar or 8-wide FMA) and can never
/// drift apart.
#[inline(always)]
fn rank1_fold_packed<const SUB: bool>(p: &mut [f32], r: &[f32], kernels: &Kernels) {
    let mut idx = 0;
    for i in 0..r.len() {
        let ri = if SUB { -r[i] } else { r[i] };
        (kernels.axpy)(&mut p[idx..idx + i + 1], ri, &r[..i + 1]);
        idx += i + 1;
    }
}

/// `P += r rᵀ` on the packed lower triangle — the ridge hot loop
/// (s(s+1)/2 MACs per sample). Row-wise to stay cache-friendly.
/// Scalar-kernel reference; kernel-dispatched callers use
/// [`rank1_update_packed_with`].
#[inline]
pub fn rank1_update_packed(p: &mut [f32], r: &[f32]) {
    rank1_fold_packed::<false>(p, r, &Kernels::scalar());
}

/// `P −= r rᵀ` on the packed lower triangle — the eviction mirror of
/// [`rank1_update_packed`], used by [`OnlineRidge`]'s sliding window to
/// keep the Gram shadow exact as samples leave.
#[inline]
pub fn rank1_sub_packed(p: &mut [f32], r: &[f32]) {
    rank1_fold_packed::<true>(p, r, &Kernels::scalar());
}

/// [`rank1_update_packed`] through an explicit kernel table.
#[inline]
pub fn rank1_update_packed_with(p: &mut [f32], r: &[f32], kernels: &Kernels) {
    rank1_fold_packed::<false>(p, r, kernels);
}

/// [`rank1_sub_packed`] through an explicit kernel table. Update and
/// downdate must always go through the **same** table: the shadow stays
/// exact only because eviction replays the identical per-element kernel
/// with the sign flipped.
#[inline]
pub fn rank1_sub_packed_with(p: &mut [f32], r: &[f32], kernels: &Kernels) {
    rank1_fold_packed::<true>(p, r, kernels);
}

/// `P += Σ_b r_b r_bᵀ` on the packed lower triangle from a row-major
/// B×s block `rs` — the rank-k generalization of
/// [`rank1_update_packed`].
///
/// The register-blocked micro-kernel (4 samples per row pass, pure-axpy
/// inner loop) now lives in [`crate::simd::scalar::gram_rankk`] so the
/// AVX2 table can provide an 8-wide FMA variant against the same
/// contract; this wrapper is the scalar-kernel reference, and
/// kernel-dispatched callers use [`rankk_update_packed_with`]. Total
/// MAC count is identical to B rank-1 passes; the memory traffic over
/// `P` drops by ~B versus per-sample folds.
pub fn rankk_update_packed(p: &mut [f32], rs: &[f32], s: usize) {
    rankk_update_packed_with(p, rs, s, &Kernels::scalar());
}

/// [`rankk_update_packed`] through an explicit kernel table. Gram
/// accumulation reassociates across samples under the AVX2 table (FMA,
/// 8-wide), so cross-table agreement is tolerance-bounded, not bitwise
/// — see `tests/simd_equivalence.rs`.
pub fn rankk_update_packed_with(p: &mut [f32], rs: &[f32], s: usize, kernels: &Kernels) {
    debug_assert_eq!(p.len(), tri_len(s));
    debug_assert_eq!(rs.len() % s.max(1), 0);
    (kernels.gram_rankk)(p, rs, s);
}

/// The β-selection values used throughout the paper's evaluation (§4.1).
pub const PAPER_BETAS: [f32; 4] = [1e-6, 1e-4, 1e-2, 1.0];

/// A solved output layer.
#[derive(Clone, Debug, PartialEq)]
pub struct RidgeSolution {
    /// W̃_out, row-major ny×s, acting on r̃ = [r, 1]
    pub w_tilde: Vec<f32>,
    pub s: usize,
    pub ny: usize,
    pub beta: f32,
    /// memory words the method holds during the solve (Table 2)
    pub memory_words: usize,
}

impl RidgeSolution {
    /// y = W̃_out r̃ (Eq. 17), returning raw scores.
    pub fn predict(&self, r_tilde: &[f32]) -> Vec<f32> {
        assert_eq!(r_tilde.len(), self.s);
        (0..self.ny)
            .map(|i| {
                let row = &self.w_tilde[i * self.s..(i + 1) * self.s];
                row.iter().zip(r_tilde).map(|(w, r)| w * r).sum()
            })
            .collect()
    }

    pub fn predict_class(&self, r_tilde: &[f32]) -> usize {
        let y = self.predict(r_tilde);
        argmax(&y)
    }
}

/// Index of the maximum element (ties → first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    /// Build an accumulator from synthetic linearly-separable features.
    fn toy_system(s: usize, ny: usize, n: usize, rng: &mut Pcg32) -> (RidgeAccumulator, Vec<(Vec<f32>, usize)>) {
        let mut acc = RidgeAccumulator::new(s, ny);
        let mut data = Vec::new();
        for i in 0..n {
            let class = i % ny;
            let mut r: Vec<f32> = (0..s).map(|_| 0.3 * rng.normal()).collect();
            r[class] += 2.0; // separable signal
            *r.last_mut().unwrap() = 1.0; // the tilde 1
            acc.accumulate(&r, class);
            data.push((r, class));
        }
        (acc, data)
    }

    #[test]
    fn accumulate_builds_b_and_a() {
        let mut acc = RidgeAccumulator::new(3, 2);
        acc.accumulate(&[1.0, 2.0, 1.0], 0);
        acc.accumulate(&[0.5, -1.0, 1.0], 1);
        assert_eq!(acc.count, 2);
        // B[1][0] = 1*2 + 0.5*-1 = 1.5
        assert_eq!(acc.b_packed[tri(1, 0)], 1.5);
        // A row 0 = first sample, row 1 = second
        assert_eq!(&acc.a[0..3], &[1.0, 2.0, 1.0]);
        assert_eq!(&acc.a[3..6], &[0.5, -1.0, 1.0]);
    }

    #[test]
    fn all_methods_classify_separable_data() {
        let mut rng = Pcg32::seed(41);
        let (acc, data) = toy_system(12, 3, 60, &mut rng);
        for method in [
            RidgeMethod::Gaussian,
            RidgeMethod::Cholesky1d,
            RidgeMethod::CholeskyBuffered,
        ] {
            let sol = acc.solve(1e-2, method);
            let correct = data
                .iter()
                .filter(|(r, c)| sol.predict_class(r) == *c)
                .count();
            assert!(
                correct as f64 / data.len() as f64 > 0.95,
                "{method:?}: {correct}/{}",
                data.len()
            );
        }
    }

    #[test]
    fn methods_agree_numerically() {
        let mut rng = Pcg32::seed(42);
        let (acc, _) = toy_system(10, 2, 40, &mut rng);
        let g = acc.solve(0.1, RidgeMethod::Gaussian);
        let c = acc.solve(0.1, RidgeMethod::Cholesky1d);
        let b = acc.solve(0.1, RidgeMethod::CholeskyBuffered);
        for ((x, y), z) in g.w_tilde.iter().zip(&c.w_tilde).zip(&b.w_tilde) {
            assert!((x - y).abs() < 5e-3 * y.abs().max(1.0), "{x} vs {y}");
            assert!((y - z).abs() < 5e-3 * z.abs().max(1.0), "{y} vs {z}");
        }
    }

    #[test]
    fn beta_sweep_picks_lowest_loss() {
        let mut rng = Pcg32::seed(43);
        let (acc, data) = toy_system(8, 2, 30, &mut rng);
        let (sol, _) = acc.solve_best_beta(&PAPER_BETAS, RidgeMethod::Cholesky1d, |sol| {
            // 0-1 loss over the training data
            data.iter()
                .filter(|(r, c)| sol.predict_class(r) != *c)
                .count() as f32
        });
        assert!(PAPER_BETAS.contains(&sol.beta));
    }

    #[test]
    fn memory_words_reported() {
        let acc = RidgeAccumulator::new(31, 2);
        let g = acc.solve(0.1, RidgeMethod::Gaussian);
        let c = acc.solve(0.1, RidgeMethod::Cholesky1d);
        assert!(g.memory_words > 3 * c.memory_words);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn accumulate_block_matches_sequential() {
        let mut rng = Pcg32::seed(45);
        let s = 13;
        let ny = 3;
        // block sizes crossing the 4-sample quad boundary
        for n in [1usize, 3, 4, 7, 8, 11] {
            let rs: Vec<f32> = (0..n * s).map(|_| rng.normal()).collect();
            let labels: Vec<usize> = (0..n).map(|i| i % ny).collect();
            let mut seq = RidgeAccumulator::new(s, ny);
            for (r, &c) in rs.chunks_exact(s).zip(&labels) {
                seq.accumulate(r, c);
            }
            let mut blk = RidgeAccumulator::new(s, ny);
            blk.accumulate_block(&rs, &labels);
            assert_eq!(blk.count, n);
            assert_eq!(blk.a, seq.a);
            for (i, (x, y)) in blk.b_packed.iter().zip(&seq.b_packed).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                    "B={n} elem {i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn accumulate_block_empty_is_noop() {
        let mut acc = RidgeAccumulator::new(5, 2);
        acc.accumulate_block(&[], &[]);
        assert_eq!(acc.count, 0);
        assert!(acc.b_packed.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn workspace_solve_matches_clone_solve() {
        let mut rng = Pcg32::seed(46);
        let (acc, _) = toy_system(11, 2, 40, &mut rng);
        let mut ws = SolveWorkspace::new(acc.s, acc.ny);
        for method in [RidgeMethod::Cholesky1d, RidgeMethod::CholeskyBuffered] {
            for &beta in &PAPER_BETAS {
                let a = acc.solve(beta, method);
                let b = acc.solve_with_workspace(beta, method, &mut ws);
                assert_eq!(a.w_tilde, b.w_tilde, "{method:?} beta {beta}");
                assert_eq!(a.memory_words, b.memory_words);
            }
        }
    }

    #[test]
    fn parallel_beta_sweep_matches_serial() {
        let mut rng = Pcg32::seed(47);
        let (acc, _) = toy_system(9, 2, 30, &mut rng);
        let loss = |sol: &RidgeSolution| sol.w_tilde.iter().map(|w| w * w).sum::<f32>();
        let (a, la) = acc.solve_best_beta(&PAPER_BETAS, RidgeMethod::Cholesky1d, loss);
        let (b, lb) =
            acc.solve_best_beta_parallel(&PAPER_BETAS, RidgeMethod::Cholesky1d, 4, loss);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.w_tilde, b.w_tilde);
        assert_eq!(la, lb);
    }

    #[test]
    fn online_ridge_grow_matches_batch() {
        // no window, no forgetting: after N observes the solution must
        // match the batch accumulator solved at the same β
        let mut rng = Pcg32::seed(48);
        let s = 9;
        let ny = 2;
        let beta = 0.5f32;
        let mut online = OnlineRidge::new(
            s,
            ny,
            OnlineRidgeConfig {
                beta,
                lambda: 1.0,
                window: None,
                refactor_every: 0,
            },
        );
        let mut batch = RidgeAccumulator::new(s, ny);
        for i in 0..24 {
            let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
            let class = i % ny;
            batch.accumulate(&r, class);
            let stats = online.observe(&r, class);
            assert_eq!(stats.updates, i as u64 + 1);
        }
        let sol = batch.solve(beta, RidgeMethod::Cholesky1d);
        for (k, (x, y)) in online.w_tilde().iter().zip(&sol.w_tilde).enumerate() {
            assert!(
                (x - y).abs() < 5e-3 * y.abs().max(1.0),
                "elem {k}: online {x} vs batch {y}"
            );
        }
    }

    #[test]
    fn online_ridge_window_evicts() {
        let mut rng = Pcg32::seed(49);
        let s = 5;
        let mut online = OnlineRidge::new(
            s,
            2,
            OnlineRidgeConfig {
                beta: 0.3,
                window: Some(4),
                ..Default::default()
            },
        );
        for i in 0..10 {
            let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
            let stats = online.observe(&r, i % 2);
            assert_eq!(stats.window_len, (i + 1).min(4));
        }
        assert_eq!(online.updates(), 10);
        assert_eq!(online.window_len(), 4);
    }

    #[test]
    fn online_ridge_predicts_separable() {
        let mut rng = Pcg32::seed(50);
        let (_, data) = toy_system(8, 2, 40, &mut rng);
        let mut online = OnlineRidge::new(
            8,
            2,
            OnlineRidgeConfig {
                beta: 1e-2,
                ..Default::default()
            },
        );
        for (r, c) in &data {
            online.observe(r, *c);
        }
        let correct = data
            .iter()
            .filter(|(r, c)| online.predict_class(r) == *c)
            .count();
        assert!(correct as f64 / data.len() as f64 > 0.9, "{correct}/40");
    }

    #[test]
    fn online_ridge_state_roundtrip_is_bitwise() {
        // export mid-stream, rebuild, and both accumulators must stay
        // bitwise identical through further observes — the property the
        // coordinator's checkpoint/restore leans on
        let mut rng = Pcg32::seed(52);
        let s = 7;
        let ny = 3;
        let configs = [
            OnlineRidgeConfig {
                beta: 0.2,
                lambda: 1.0,
                window: None,
                refactor_every: 5,
            },
            OnlineRidgeConfig {
                beta: 0.2,
                lambda: 0.97,
                window: None,
                refactor_every: 0,
            },
            OnlineRidgeConfig {
                beta: 0.2,
                lambda: 1.0,
                window: Some(6),
                refactor_every: 0,
            },
        ];
        for cfg in configs {
            let mut orig = OnlineRidge::new(s, ny, cfg);
            for i in 0..13 {
                let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
                orig.observe(&r, i % ny);
            }
            let mut copy = OnlineRidge::from_state(orig.export_state()).unwrap();
            assert_eq!(copy.updates(), orig.updates());
            assert_eq!(copy.w_tilde(), orig.w_tilde());
            for i in 0..17 {
                let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
                let a = orig.observe(&r, i % ny);
                let b = copy.observe(&r, i % ny);
                assert_eq!(a.updates, b.updates);
                assert_eq!(a.refactored, b.refactored);
                assert_eq!(orig.w_tilde(), copy.w_tilde(), "window={:?} λ={}", cfg.window, cfg.lambda);
            }
        }
    }

    #[test]
    fn online_ridge_from_state_rejects_corrupt() {
        let online = OnlineRidge::new(
            4,
            2,
            OnlineRidgeConfig {
                beta: 0.1,
                window: Some(3),
                ..Default::default()
            },
        );
        // healthy state imports fine
        assert!(OnlineRidge::from_state(online.export_state()).is_ok());
        let mut st = online.export_state();
        st.chol.pop();
        assert!(OnlineRidge::from_state(st).is_err(), "short factor");
        let mut st = online.export_state();
        st.cfg.beta = -1.0;
        assert!(OnlineRidge::from_state(st).is_err(), "bad beta");
        let mut st = online.export_state();
        st.ring_labels[0] = 99;
        st.ring_len = 3;
        assert!(OnlineRidge::from_state(st).is_err(), "label out of range");
        let mut st = online.export_state();
        st.ring_head = 3;
        assert!(OnlineRidge::from_state(st).is_err(), "head out of range");
        let mut st = online.export_state();
        st.s = 0;
        assert!(OnlineRidge::from_state(st).is_err(), "degenerate");
    }

    #[test]
    #[should_panic(expected = "mutually exclusive")]
    fn online_ridge_rejects_window_plus_forgetting() {
        OnlineRidge::new(
            4,
            2,
            OnlineRidgeConfig {
                beta: 0.1,
                lambda: 0.9,
                window: Some(8),
                refactor_every: 0,
            },
        );
    }

    #[test]
    fn rank1_sub_inverts_update() {
        let mut rng = Pcg32::seed(51);
        for s in [3usize, 7, 12] {
            let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
            let orig: Vec<f32> = (0..tri_len(s)).map(|_| rng.normal()).collect();
            let mut p = orig.clone();
            rank1_update_packed(&mut p, &r);
            rank1_sub_packed(&mut p, &r);
            for (i, (a, b)) in p.iter().zip(&orig).enumerate() {
                assert!((a - b).abs() < 1e-5, "s={s} elem {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rank1_matches_dense() {
        let mut rng = Pcg32::seed(44);
        let s = 9;
        let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
        let mut p = vec![0.0f32; tri_len(s)];
        rank1_update_packed(&mut p, &r);
        for i in 0..s {
            for j in 0..=i {
                assert_eq!(p[tri(i, j)], r[i] * r[j]);
            }
        }
    }
}
