//! Arithmetic-operation counting and memory-word accounting (Tables 2–3).
//!
//! Every linalg routine is generic over [`Ops`]; the [`NoCount`]
//! instantiation compiles to nothing (the hot path), while [`OpCount`]
//! tallies adds/muls/divs/sqrts so the benches can verify the paper's
//! closed-form counts.

/// Operation counter hooks. `n` is the number of operations of that kind
/// executed since the last call (batched to keep loops tight).
pub trait Ops {
    fn add(&mut self, n: u64);
    fn mul(&mut self, n: u64);
    fn div(&mut self, n: u64);
    fn sqrt(&mut self, n: u64);
}

/// Zero-cost counter for production paths.
#[derive(Default, Clone, Copy, Debug)]
pub struct NoCount;

impl Ops for NoCount {
    #[inline(always)]
    fn add(&mut self, _: u64) {}
    #[inline(always)]
    fn mul(&mut self, _: u64) {}
    #[inline(always)]
    fn div(&mut self, _: u64) {}
    #[inline(always)]
    fn sqrt(&mut self, _: u64) {}
}

/// Tallying counter for Table 3 verification.
#[derive(Default, Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpCount {
    pub add: u64,
    pub mul: u64,
    pub div: u64,
    pub sqrt: u64,
}

impl Ops for OpCount {
    #[inline(always)]
    fn add(&mut self, n: u64) {
        self.add += n;
    }
    #[inline(always)]
    fn mul(&mut self, n: u64) {
        self.mul += n;
    }
    #[inline(always)]
    fn div(&mut self, n: u64) {
        self.div += n;
    }
    #[inline(always)]
    fn sqrt(&mut self, n: u64) {
        self.sqrt += n;
    }
}

impl OpCount {
    pub fn total(&self) -> u64 {
        self.add + self.mul + self.div + self.sqrt
    }
}

/// Table 2, "naive": memory words for Ridge regression via Gaussian
/// elimination — `2s(s + N_y) + 1` (B, B⁻¹, A, W̃_out, buf).
pub fn memory_words_naive(s: usize, ny: usize) -> usize {
    2 * s * (s + ny) + 1
}

/// Table 2, "proposed": `½s(s + 2N_y) + ½s` = s(s+1)/2 (packed P) plus
/// N_y·s (the shared A/D/W̃_out array Q).
pub fn memory_words_proposed(s: usize, ny: usize) -> usize {
    s * (s + 1) / 2 + ny * s
}

/// Alias kept for the benches' naming symmetry with Table 2.
pub fn memory_words_proposed_exact(s: usize, ny: usize) -> usize {
    memory_words_proposed(s, ny)
}

/// Table 3, "naive" operation counts for Gaussian elimination
/// (adds: `2s²(s + ½N_y) − 2s²`, muls: `2s²(s + ½N_y)`, divs: `s`).
pub fn ops_naive(s: u64, ny: u64) -> OpCount {
    OpCount {
        add: 2 * s * s * s + s * s * ny - 2 * s * s,
        mul: 2 * s * s * s + s * s * ny,
        div: s,
        sqrt: 0,
    }
}

/// Table 3, "proposed" operation counts for 1-D Cholesky
/// (adds: `⅙s²(s+N_y)... − ⅙s − sN_y`, with the correction terms the
/// paper lists; divs: `s + 2sN_y`; sqrts: `s`).
///
/// The closed forms below are the exact sums of the loop trip counts of
/// Algorithms 2–4 (verified against measured [`OpCount`] in tests):
///   Alg.2 adds: Σᵢ i + Σᵢ (s−1−i)·i = s(s−1)/2 + s(s−1)(s−2)/... computed
///   directly; Alg.3/4 adds: N_y · Σⱼ j  (each), etc.
pub fn ops_proposed(s: u64, ny: u64) -> OpCount {
    // Algorithm 2 (decomposition): for i: i subs+muls on diagonal; for
    // j>i: i fused mul-sub + 1 mul
    let chol_add: u64 = (0..s).map(|i| i + (s - 1 - i) * i).sum();
    let chol_mul: u64 = (0..s).map(|i| i + (s - 1 - i) * (i + 1)).sum();
    let chol_div = s; // buf = 1/diag
    let chol_sqrt = s;
    // Algorithm 3 (D = A C^-T): per row of Q: Σ_j j mul-subs + 1 div
    let sub_add: u64 = ny * (0..s).map(|j| j).sum::<u64>();
    let sub_mul = sub_add;
    let sub_div = ny * s;
    // Algorithm 4 (W = D C^-1): symmetric to Alg. 3
    OpCount {
        add: chol_add + 2 * sub_add,
        mul: chol_mul + 2 * sub_mul,
        div: chol_div + 2 * sub_div,
        sqrt: chol_sqrt,
    }
}

/// Paper Table 3 "proposed" closed forms as printed (leading order):
/// add ≈ ⅙s²(s+N_y), mul ≈ ⅙s²(s+N_y)+½s², div = s + 2sN_y, sqrt = s.
pub fn ops_proposed_paper_leading(s: u64, ny: u64) -> OpCount {
    OpCount {
        add: s * s * (s + ny) / 6,
        mul: s * s * (s + ny) / 6 + s * s / 2,
        div: s + 2 * s * ny,
        sqrt: s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nocount_is_inert() {
        let mut c = NoCount;
        c.add(5);
        c.mul(5);
    }

    #[test]
    fn opcount_tallies() {
        let mut c = OpCount::default();
        c.add(3);
        c.mul(2);
        c.div(1);
        c.sqrt(4);
        assert_eq!(
            c,
            OpCount {
                add: 3,
                mul: 2,
                div: 1,
                sqrt: 4
            }
        );
        assert_eq!(c.total(), 10);
    }

    #[test]
    fn memory_ratio_approaches_four() {
        // Table 2: naive/proposed → 4 when N_y ≪ s
        let s = 931; // Nx = 30
        let ny = 9;
        let ratio =
            memory_words_naive(s, ny) as f64 / memory_words_proposed_exact(s, ny) as f64;
        assert!((3.5..=4.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn ops_ratio_approaches_twelve() {
        // Table 3: (adds+muls) naive/proposed → ~12 when N_y ≪ s
        let s = 931;
        let ny = 2;
        let n = ops_naive(s, ny);
        let p = ops_proposed(s, ny);
        let ratio = (n.add + n.mul) as f64 / (p.add + p.mul) as f64;
        assert!((10.0..=13.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn proposed_matches_paper_leading_order() {
        // The s³/6 decomposition term matches the paper exactly; the
        // substitution term is N_y·s² from the algorithms' own loops
        // (Table 3 prints N_y·s²/6, which is inconsistent with the
        // pseudo-code's trip counts — the relative gap is 5·N_y/s). The
        // ratio conclusions (≈12× fewer add/mul) are unaffected.
        let s = 931u64;
        let ny = 9u64;
        let exact = ops_proposed(s, ny);
        let paper = ops_proposed_paper_leading(s, ny);
        let rel = |a: u64, b: u64| (a as f64 - b as f64).abs() / b as f64;
        let tol = 5.5 * ny as f64 / s as f64;
        assert!(rel(exact.add, paper.add) < tol);
        assert!(rel(exact.mul, paper.mul) < tol);
        assert_eq!(exact.div, paper.div);
        assert_eq!(exact.sqrt, paper.sqrt);
    }
}
