//! Algorithms 2–4: in-place Ridge regression via 1-D Cholesky
//! decomposition — the paper's proposed method.
//!
//! `B` is SPD (Eqs. 37–39), so only its lower triangle is stored, packed
//! row-sequentially into a 1-D array `P[s(s+1)/2]` (Eq. 41). Algorithm 2
//! decomposes `B = C Cᵀ` **in place** in `P`; Algorithm 3 computes
//! `D = A C⁻ᵀ` in place in the array `Q` that initially holds `A`;
//! Algorithm 4 computes `W̃_out = D C⁻¹` in place in `Q`. No memory beyond
//! `P`, `Q` and a few registers is used — that is the whole point.

use super::counters::Ops;
use super::tri;

/// Dot product with 4 independent accumulator lanes so LLVM emits SIMD
/// (a single serial `sum()` is dependence-limited) — the decomposition's
/// inner kernel, s³/6 invocations' worth of work.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 4];
    let ac = a.chunks_exact(4);
    let bc = b.chunks_exact(4);
    let ra = ac.remainder();
    let rb = bc.remainder();
    for (ca, cb) in ac.zip(bc) {
        lanes[0] += ca[0] * cb[0];
        lanes[1] += ca[1] * cb[1];
        lanes[2] += ca[2] * cb[2];
        lanes[3] += ca[3] * cb[3];
    }
    let mut acc = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for (x, y) in ra.iter().zip(rb) {
        acc += x * y;
    }
    acc
}

/// Algorithm 2: in-place Cholesky decomposition in the packed 1-D array.
///
/// On entry `p` holds the lower triangle of `B` (with the βI shift already
/// applied to the diagonal); on exit it holds `C` with `B = C Cᵀ`.
///
/// The update order is the one the paper proves dependence-safe: for each
/// column i, first the diagonal `C[i][i]` (lines 2–5), then the
/// sub-diagonal column entries `C[j][i]`, j > i (lines 7–12), each reading
/// only already-final values of `P`.
pub fn cholesky_1d<O: Ops>(p: &mut [f32], s: usize, ops: &mut O) {
    debug_assert_eq!(p.len(), s * (s + 1) / 2);
    for i in 0..s {
        // lines 2-4: diagonal accumulation (slice dot lets LLVM
        // vectorize; indexing form was 1.9x slower — see §Perf)
        let row_i = tri(i, 0);
        let (head, tail) = p.split_at_mut(row_i + i);
        let ri = &head[row_i..];
        let mut diag = tail[0];
        diag -= dot(ri, ri);
        ops.add(i as u64);
        ops.mul(i as u64);
        // line 5: sqrt (guarded: B is SPD in exact arithmetic; f32
        // round-off with tiny β can graze zero)
        diag = diag.max(f32::MIN_POSITIVE).sqrt();
        tail[0] = diag;
        ops.sqrt(1);
        // line 6
        let buf = 1.0 / diag;
        ops.div(1);
        // lines 7-12: column below the diagonal
        for j in i + 1..s {
            let row_j = tri(j, 0);
            // row_i+i < row_j always (j > i), so split once per j
            let (head, tail) = p.split_at_mut(row_j);
            let ri = &head[row_i..row_i + i];
            let rj = &tail[..i];
            let mut acc = tail[i];
            acc -= dot(ri, rj);
            tail[i] = acc * buf;
            ops.add(i as u64);
            ops.mul(i as u64 + 1);
        }
    }
}

/// Algorithm 3: in-place backward substitution `D = A C⁻ᵀ`.
///
/// `q` (ny×s row-major) holds `A` on entry and `D` on exit; `p` holds `C`
/// from [`cholesky_1d`]. Row-major traversal left→right: every value read
/// on the right-hand side is already final (the in-place property).
pub fn solve_ct_inplace<O: Ops>(q: &mut [f32], p: &[f32], s: usize, ny: usize, ops: &mut O) {
    debug_assert_eq!(q.len(), ny * s);
    for i in 0..ny {
        let row = &mut q[i * s..(i + 1) * s];
        for j in 0..s {
            let row_j = tri(j, 0);
            let cj = &p[row_j..row_j + j];
            let mut acc = row[j];
            acc -= dot(&row[..j], cj);
            row[j] = acc / p[row_j + j];
            ops.add(j as u64);
            ops.mul(j as u64);
            ops.div(1);
        }
    }
}

/// Algorithm 4: in-place forward substitution `W̃_out = D C⁻¹`.
///
/// `q` holds `D` on entry and `W̃_out` on exit; traversal right→left.
pub fn solve_c_inplace<O: Ops>(q: &mut [f32], p: &[f32], s: usize, ny: usize, ops: &mut O) {
    debug_assert_eq!(q.len(), ny * s);
    for i in 0..ny {
        let row = &mut q[i * s..(i + 1) * s];
        for j in (0..s).rev() {
            let mut acc = row[j];
            for k in (j + 1..s).rev() {
                acc -= row[k] * p[tri(k, j)];
            }
            row[j] = acc / p[tri(j, j)];
            ops.add((s - 1 - j) as u64);
            ops.mul((s - 1 - j) as u64);
            ops.div(1);
        }
    }
}

/// Full proposed pipeline: Algorithms 2 → 3 → 4.
///
/// `p` holds packed `B` (β already on the diagonal) and is destroyed;
/// `q` holds `A` and receives `W̃_out`.
pub fn ridge_cholesky_1d<O: Ops>(p: &mut [f32], q: &mut [f32], s: usize, ny: usize, ops: &mut O) {
    cholesky_1d(p, s, ops);
    solve_ct_inplace(q, p, s, ny, ops);
    solve_c_inplace(q, p, s, ny, ops);
}

#[cfg(test)]
mod tests {
    use super::super::counters::{NoCount, OpCount};
    use super::super::{pack_lower, tri_len};
    use super::*;
    use crate::util::prng::Pcg32;

    fn random_spd_dense(s: usize, beta: f32, rng: &mut Pcg32) -> Vec<f32> {
        let g: Vec<f32> = (0..s * s).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let mut acc = 0.0;
                for k in 0..s {
                    acc += g[i * s + k] * g[j * s + k];
                }
                b[i * s + j] = acc / s as f32 + if i == j { beta } else { 0.0 };
            }
        }
        b
    }

    #[test]
    fn decomposition_reconstructs_b() {
        let mut rng = Pcg32::seed(21);
        for s in [1, 2, 5, 13, 29] {
            let b = random_spd_dense(s, 0.3, &mut rng);
            let mut p = pack_lower(&b, s);
            cholesky_1d(&mut p, s, &mut NoCount);
            // check C C^T == B on the lower triangle
            for i in 0..s {
                for j in 0..=i {
                    let mut acc = 0.0f32;
                    for k in 0..=j {
                        acc += p[tri(i, k)] * p[tri(j, k)];
                    }
                    let want = b[i * s + j];
                    assert!(
                        (acc - want).abs() < 1e-3 * want.abs().max(1.0),
                        "s={s} ({i},{j}): {acc} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn ridge_matches_gaussian_baseline() {
        use super::super::gaussian::{ridge_gaussian, GaussianWorkspace};
        let mut rng = Pcg32::seed(22);
        for s in [4, 9, 23] {
            let ny = 3;
            let b = random_spd_dense(s, 0.5, &mut rng);
            let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();

            let mut ws = GaussianWorkspace::new(s, ny);
            ridge_gaussian(&a, &b, &mut ws, &mut NoCount);

            let mut p = pack_lower(&b, s);
            let mut q = a.clone();
            ridge_cholesky_1d(&mut p, &mut q, s, ny, &mut NoCount);

            for (idx, (x, y)) in q.iter().zip(&ws.w_out).enumerate() {
                assert!(
                    (x - y).abs() < 2e-2 * y.abs().max(1.0),
                    "s={s} idx={idx}: cholesky {x} vs gaussian {y}"
                );
            }
        }
    }

    #[test]
    fn solve_verifies_w_b_equals_a() {
        let mut rng = Pcg32::seed(23);
        let s = 17;
        let ny = 4;
        let b = random_spd_dense(s, 1.0, &mut rng);
        let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();
        let mut p = pack_lower(&b, s);
        let mut q = a.clone();
        ridge_cholesky_1d(&mut p, &mut q, s, ny, &mut NoCount);
        for i in 0..ny {
            for j in 0..s {
                let mut acc = 0.0f32;
                for k in 0..s {
                    acc += q[i * s + k] * b[k * s + j];
                }
                assert!(
                    (acc - a[i * s + j]).abs() < 2e-3,
                    "({i},{j}): {acc} vs {}",
                    a[i * s + j]
                );
            }
        }
    }

    #[test]
    fn memory_is_exactly_packed_plus_q() {
        // the in-place property: the pipeline allocates nothing
        let s = 31;
        let ny = 2;
        let words = tri_len(s) + ny * s;
        assert_eq!(
            words,
            super::super::counters::memory_words_proposed(s, ny)
        );
    }

    #[test]
    fn op_counts_match_table3_proposed() {
        let s = 20;
        let ny = 3;
        let b = random_spd_dense(s, 1.0, &mut Pcg32::seed(5));
        let a = vec![0.25f32; ny * s];
        let mut p = pack_lower(&b, s);
        let mut q = a;
        let mut ops = OpCount::default();
        ridge_cholesky_1d(&mut p, &mut q, s, ny, &mut ops);
        let expect = super::super::counters::ops_proposed(s as u64, ny as u64);
        assert_eq!(ops, expect);
    }

    #[test]
    fn property_random_spd_solutions_valid() {
        crate::util::proptest::run_prop(
            "cholesky solves SPD",
            crate::util::proptest::Config {
                cases: 48,
                max_size: 20,
                ..Default::default()
            },
            |rng, size| {
                let s = size as usize + 1;
                let ny = 1 + (rng.below(3) as usize);
                let b = random_spd_dense(s, 0.5 + rng.uniform(), rng);
                let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();
                let mut p = pack_lower(&b, s);
                let mut q = a.clone();
                ridge_cholesky_1d(&mut p, &mut q, s, ny, &mut NoCount);
                // residual ||W B - A||_inf must be small
                for i in 0..ny {
                    for j in 0..s {
                        let mut acc = 0.0f32;
                        for k in 0..s {
                            acc += q[i * s + k] * b[k * s + j];
                        }
                        let want = a[i * s + j];
                        if (acc - want).abs() > 5e-3 * want.abs().max(1.0) {
                            return Err(format!(
                                "s={s} ny={ny} ({i},{j}): {acc} vs {want}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
