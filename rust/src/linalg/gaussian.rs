//! Algorithm 1: Ridge regression via Gauss–Jordan elimination — the
//! paper's "naive" baseline (after Arias-García et al. [5]).
//!
//! Inverts the dense s×s matrix `B` with an explicit identity-seeded
//! inverse, then multiplies `W̃_out = A B⁻¹`. Requires
//! `2s(s+N_y)+1` words (Table 2) and `~4s³` flops (Table 3).

use super::counters::Ops;

/// Workspace for Algorithm 1 (sized once, reused across β sweeps).
pub struct GaussianWorkspace {
    pub s: usize,
    pub ny: usize,
    /// dense B (row-major s×s) — consumed during elimination
    pub b: Vec<f32>,
    /// dense B⁻¹ (row-major s×s)
    pub b_inv: Vec<f32>,
    /// W̃_out (row-major ny×s)
    pub w_out: Vec<f32>,
}

impl GaussianWorkspace {
    pub fn new(s: usize, ny: usize) -> Self {
        GaussianWorkspace {
            s,
            ny,
            b: vec![0.0; s * s],
            b_inv: vec![0.0; s * s],
            w_out: vec![0.0; ny * s],
        }
    }

    /// Memory words actually held (matches Table 2 naive up to the scalar
    /// `buf` register).
    pub fn memory_words(&self) -> usize {
        self.b.len() + self.b_inv.len() + 2 * self.w_out.len() + 1
    }
}

/// Algorithm 1 verbatim: given `A` (ny×s, row-major) and `B` (s×s dense,
/// already including the `βI` shift) compute `W̃_out = A B⁻¹`.
///
/// `ws.b` is overwritten (becomes the identity up to round-off) and
/// `ws.b_inv` receives B⁻¹; the result lands in `ws.w_out`.
pub fn ridge_gaussian<O: Ops>(
    a: &[f32],
    b: &[f32],
    ws: &mut GaussianWorkspace,
    ops: &mut O,
) {
    let s = ws.s;
    let ny = ws.ny;
    assert_eq!(a.len(), ny * s);
    assert_eq!(b.len(), s * s);
    ws.b.copy_from_slice(b);

    // lines 1-9: B^-1 <- I
    ws.b_inv.fill(0.0);
    for i in 0..s {
        ws.b_inv[i * s + i] = 1.0;
    }

    // lines 10-25: Gauss-Jordan with explicit inverse
    for i in 0..s {
        let buf = 1.0 / ws.b[i * s + i];
        ops.div(1);
        for j in 0..s {
            ws.b[i * s + j] *= buf;
            ws.b_inv[i * s + j] *= buf;
        }
        ops.mul(2 * s as u64);
        for j in 0..s {
            if i != j {
                let buf = ws.b[j * s + i];
                // row_j -= row_i * buf (both matrices)
                let (bi, bj) = row_pair(&mut ws.b, s, i, j);
                for k in 0..s {
                    bj[k] -= bi[k] * buf;
                }
                let (ii, ij) = row_pair(&mut ws.b_inv, s, i, j);
                for k in 0..s {
                    ij[k] -= ii[k] * buf;
                }
                ops.mul(2 * s as u64);
                ops.add(2 * s as u64);
            }
        }
    }

    // lines 26-33: W_out = A * B^-1
    for i in 0..ny {
        for j in 0..s {
            let mut acc = 0.0f32;
            for k in 0..s {
                acc += a[i * s + k] * ws.b_inv[k * s + j];
            }
            ws.w_out[i * s + j] = acc;
        }
    }
    ops.mul((ny * s * s) as u64);
    ops.add((ny * s * s) as u64);
}

/// Borrow two distinct rows of a row-major matrix mutably.
fn row_pair(m: &mut [f32], s: usize, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
    debug_assert_ne!(i, j);
    if i < j {
        let (lo, hi) = m.split_at_mut(j * s);
        (&mut lo[i * s..i * s + s], &mut hi[..s])
    } else {
        let (lo, hi) = m.split_at_mut(i * s);
        let a = &mut hi[..s];
        (a, &mut lo[j * s..j * s + s])
    }
}

#[cfg(test)]
mod tests {
    use super::super::counters::{NoCount, OpCount};
    use super::*;
    use crate::util::prng::Pcg32;

    /// Random SPD system B = G Gᵀ + βI with known right-hand side.
    pub fn random_spd(s: usize, beta: f32, rng: &mut Pcg32) -> Vec<f32> {
        let g: Vec<f32> = (0..s * s).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let mut acc = 0.0;
                for k in 0..s {
                    acc += g[i * s + k] * g[j * s + k];
                }
                b[i * s + j] = acc / s as f32 + if i == j { beta } else { 0.0 };
            }
        }
        b
    }

    #[test]
    fn inverts_identity() {
        let s = 6;
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            b[i * s + i] = 2.0;
        }
        let a = vec![1.0f32; s]; // ny = 1
        let mut ws = GaussianWorkspace::new(s, 1);
        ridge_gaussian(&a, &b, &mut ws, &mut NoCount);
        for j in 0..s {
            assert!((ws.w_out[j] - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn solves_random_spd_system() {
        let mut rng = Pcg32::seed(11);
        for s in [3, 8, 17] {
            let b = random_spd(s, 0.5, &mut rng);
            let ny = 2;
            let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();
            let mut ws = GaussianWorkspace::new(s, ny);
            ridge_gaussian(&a, &b, &mut ws, &mut NoCount);
            // check W B = A
            for i in 0..ny {
                for j in 0..s {
                    let mut acc = 0.0f32;
                    for k in 0..s {
                        acc += ws.w_out[i * s + k] * b[k * s + j];
                    }
                    assert!(
                        (acc - a[i * s + j]).abs() < 1e-3,
                        "s={s} ({i},{j}): {acc} vs {}",
                        a[i * s + j]
                    );
                }
            }
        }
    }

    #[test]
    fn op_counts_match_table3_naive() {
        let s = 20u64;
        let ny = 3u64;
        let b = random_spd(s as usize, 1.0, &mut Pcg32::seed(3));
        let a = vec![0.5f32; (ny * s) as usize];
        let mut ws = GaussianWorkspace::new(s as usize, ny as usize);
        let mut ops = OpCount::default();
        ridge_gaussian(&a, &b, &mut ws, &mut ops);
        let expect = super::super::counters::ops_naive(s, ny);
        assert_eq!(ops.div, expect.div);
        assert_eq!(ops.mul, expect.mul);
        assert_eq!(ops.add, expect.add);
    }

    #[test]
    fn memory_words_match_table2() {
        let ws = GaussianWorkspace::new(931, 9);
        assert_eq!(
            ws.memory_words(),
            super::super::counters::memory_words_naive(931, 9)
        );
    }
}
