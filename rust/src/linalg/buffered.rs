//! Algorithm 5: write-buffered substitution for HLS pipelining.
//!
//! On the FPGA, line 4 of Algorithm 3 (`Q[i][j] -= Q[i][k] * P[..]`)
//! re-reads the address written on the previous iteration, forcing the
//! multiply+subtract+write to fit one clock period and blocking II=1
//! pipelining. The paper inserts a small shift-register write buffer
//! (`RegSize = 4`): partial products accumulate round-robin into
//! `RegSize` independent registers — breaking the loop-carried dependence
//! to distance `RegSize` — and are folded into `Q[i][j]` afterwards
//! (lines 18–20). Fig. 10 shows the relaxed timing.
//!
//! Numerically this only reassociates the subtraction order; this module
//! reproduces the exact buffered association so the software result is
//! bit-identical to what the FPGA computes, and the `fpga::schedule`
//! model uses `RegSize` to derive the achievable II and clock.

use super::counters::Ops;
use super::tri;

/// Default buffer depth chosen in the paper after balancing parallelism
/// against the fold-up cost and memory conflicts.
pub const REG_SIZE: usize = 4;

/// Algorithm 5: `D = A C⁻ᵀ` with a `REG`-deep write buffer.
///
/// Semantics match [`super::cholesky1d::solve_ct_inplace`] up to fp32
/// reassociation: term k of the inner reduction lands in register
/// `k % REG`, and the registers are subtracted from `Q[i][j]` in order.
pub fn solve_ct_buffered<O: Ops, const REG: usize>(
    q: &mut [f32],
    p: &[f32],
    s: usize,
    ny: usize,
    ops: &mut O,
) {
    debug_assert_eq!(q.len(), ny * s);
    let mut reg = [0.0f32; REG];
    for i in 0..ny {
        let row = &mut q[i * s..(i + 1) * s];
        for j in 0..s {
            let row_j = tri(j, 0);
            reg.fill(0.0);
            // lines 3-17: round-robin partial accumulation (pipelined at
            // II=1 on the FPGA because each register is touched every
            // REG-th iteration)
            for k in 0..j {
                reg[k % REG] += row[k] * p[row_j + k];
            }
            // lines 18-20: fold the buffer into Q[i][j]
            let mut acc = row[j];
            for r in reg.iter() {
                acc -= *r;
            }
            row[j] = acc / p[row_j + j];
            ops.add((j + REG) as u64);
            ops.mul(j as u64);
            ops.div(1);
        }
    }
}

/// The "similar optimization applied to Algorithm 4": buffered forward
/// substitution `W̃_out = D C⁻¹`.
pub fn solve_c_buffered<O: Ops, const REG: usize>(
    q: &mut [f32],
    p: &[f32],
    s: usize,
    ny: usize,
    ops: &mut O,
) {
    debug_assert_eq!(q.len(), ny * s);
    let mut reg = [0.0f32; REG];
    for i in 0..ny {
        let row = &mut q[i * s..(i + 1) * s];
        for j in (0..s).rev() {
            reg.fill(0.0);
            for (t, k) in (j + 1..s).rev().enumerate() {
                reg[t % REG] += row[k] * p[tri(k, j)];
            }
            let mut acc = row[j];
            for r in reg.iter() {
                acc -= *r;
            }
            row[j] = acc / p[tri(j, j)];
            ops.add((s - 1 - j + REG) as u64);
            ops.mul((s - 1 - j) as u64);
            ops.div(1);
        }
    }
}

/// Full buffered pipeline (Algorithm 2 is already conflict-free and is
/// reused unchanged, as in the paper).
pub fn ridge_cholesky_buffered<O: Ops>(
    p: &mut [f32],
    q: &mut [f32],
    s: usize,
    ny: usize,
    ops: &mut O,
) {
    super::cholesky1d::cholesky_1d(p, s, ops);
    solve_ct_buffered::<O, REG_SIZE>(q, p, s, ny, ops);
    solve_c_buffered::<O, REG_SIZE>(q, p, s, ny, ops);
}

#[cfg(test)]
mod tests {
    use super::super::counters::NoCount;
    use super::super::pack_lower;
    use super::*;
    use crate::util::prng::Pcg32;

    fn random_spd_dense(s: usize, beta: f32, rng: &mut Pcg32) -> Vec<f32> {
        let g: Vec<f32> = (0..s * s).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let mut acc = 0.0;
                for k in 0..s {
                    acc += g[i * s + k] * g[j * s + k];
                }
                b[i * s + j] = acc / s as f32 + if i == j { beta } else { 0.0 };
            }
        }
        b
    }

    #[test]
    fn buffered_matches_unbuffered_closely() {
        let mut rng = Pcg32::seed(31);
        for s in [3, 10, 27] {
            let ny = 2;
            let b = random_spd_dense(s, 0.8, &mut rng);
            let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();

            let mut p1 = pack_lower(&b, s);
            let mut q1 = a.clone();
            super::super::cholesky1d::ridge_cholesky_1d(&mut p1, &mut q1, s, ny, &mut NoCount);

            let mut p2 = pack_lower(&b, s);
            let mut q2 = a.clone();
            ridge_cholesky_buffered(&mut p2, &mut q2, s, ny, &mut NoCount);

            for (x, y) in q1.iter().zip(&q2) {
                assert!(
                    (x - y).abs() < 1e-3 * y.abs().max(1.0),
                    "s={s}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn buffered_solution_satisfies_system() {
        let mut rng = Pcg32::seed(32);
        let s = 21;
        let ny = 3;
        let b = random_spd_dense(s, 1.0, &mut rng);
        let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();
        let mut p = pack_lower(&b, s);
        let mut q = a.clone();
        ridge_cholesky_buffered(&mut p, &mut q, s, ny, &mut NoCount);
        for i in 0..ny {
            for j in 0..s {
                let mut acc = 0.0f32;
                for k in 0..s {
                    acc += q[i * s + k] * b[k * s + j];
                }
                assert!(
                    (acc - a[i * s + j]).abs() < 2e-3,
                    "({i},{j}): {acc} vs {}",
                    a[i * s + j]
                );
            }
        }
    }

    #[test]
    fn regsize_one_is_bitwise_equal_to_sequential() {
        // with REG = 1 the buffered association degenerates to... a single
        // accumulator, which still reassociates (sum then subtract) — so
        // check exact agreement only on short reductions where both orders
        // coincide for j <= 1.
        let s = 2;
        let ny = 1;
        let b = [[4.0f32, 1.0], [1.0, 3.0]];
        let dense: Vec<f32> = b.iter().flatten().copied().collect();
        let a = vec![1.0f32, 2.0];

        let mut p1 = pack_lower(&dense, s);
        let mut q1 = a.clone();
        super::super::cholesky1d::ridge_cholesky_1d(&mut p1, &mut q1, s, ny, &mut NoCount);

        let mut p2 = pack_lower(&dense, s);
        let mut q2 = a.clone();
        super::super::cholesky1d::cholesky_1d(&mut p2, s, &mut NoCount);
        solve_ct_buffered::<NoCount, 1>(&mut q2, &p2, s, ny, &mut NoCount);
        solve_c_buffered::<NoCount, 1>(&mut q2, &p2, s, ny, &mut NoCount);

        assert_eq!(q1, q2);
    }

    #[test]
    fn various_regsizes_agree() {
        let mut rng = Pcg32::seed(33);
        let s = 15;
        let ny = 2;
        let b = random_spd_dense(s, 1.0, &mut rng);
        let a: Vec<f32> = (0..ny * s).map(|_| rng.normal()).collect();
        let mut outs = Vec::new();
        macro_rules! run {
            ($reg:literal) => {{
                let mut p = pack_lower(&b, s);
                let mut q = a.clone();
                super::super::cholesky1d::cholesky_1d(&mut p, s, &mut NoCount);
                solve_ct_buffered::<NoCount, $reg>(&mut q, &p, s, ny, &mut NoCount);
                solve_c_buffered::<NoCount, $reg>(&mut q, &p, s, ny, &mut NoCount);
                outs.push(q);
            }};
        }
        run!(2);
        run!(4);
        run!(8);
        for o in &outs[1..] {
            for (x, y) in o.iter().zip(&outs[0]) {
                assert!((x - y).abs() < 1e-4 * y.abs().max(1.0));
            }
        }
    }
}
