//! Rank-1 Cholesky **update** and **downdate** over the packed 1-D
//! triangle — the streaming-online extension of Algorithm 2.
//!
//! Given `C` with `B = C Cᵀ` stored exactly as [`super::cholesky1d`]
//! leaves it (lower triangle packed row-sequentially, Eq. 41), these
//! routines produce in place the factor of `B ± x xᵀ` in O(s²)
//! operations — against O(s³/6) for re-running the decomposition. The
//! update sweeps a Givens rotation per column; the downdate sweeps the
//! *hyperbolic* counterpart (same recurrence with the sign of `x[k]²`
//! flipped), which is the numerically delicate one: when `B − x xᵀ`
//! grazes the positive-definite boundary the pivot `C[k][k]² − x[k]²`
//! goes non-positive and the routine reports [`DowndateError`] instead
//! of emitting a poisoned factor. Callers (see `ridge::OnlineRidge`)
//! respond by re-factorizing from their exact Gram shadow.
//!
//! Both routines destroy the caller's `x` (it carries the rotated
//! residual between columns), which is what makes them allocation-free:
//! the only state is `P` and `x` itself.
//!
//! The column walk over the packed layout is strided — element `(i, k)`
//! lives at `i(i+1)/2 + k`, so consecutive column entries are `i + 1`
//! apart. The stride grows row by row, but every iteration still
//! touches each triangle word exactly once, so the O(s²) bound is also
//! the memory-traffic bound.

use super::counters::Ops;
use super::tri;

/// Downdate left the matrix indefinite: `B − x xᵀ` has no real Cholesky
/// factor (or sits too close to the boundary for f32). The packed
/// factor is left partially rotated and must be restored by the caller
/// (refactor from the Gram, or discard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DowndateError {
    /// column at which the pivot went non-positive
    pub column: usize,
}

impl std::fmt::Display for DowndateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rank-1 downdate lost positive definiteness at column {}",
            self.column
        )
    }
}

impl std::error::Error for DowndateError {}

/// Rank-1 **update**: replace the packed factor `C` of `B` with the
/// factor of `B + x xᵀ`. `x` is destroyed (used as the rotation
/// residual). O(s²) mul/add, `s` div/sqrt.
pub fn chol_update_1d<O: Ops>(p: &mut [f32], s: usize, x: &mut [f32], ops: &mut O) {
    debug_assert_eq!(p.len(), s * (s + 1) / 2);
    debug_assert_eq!(x.len(), s);
    for k in 0..s {
        let dk = tri(k, k);
        let ckk = p[dk];
        let xk = x[k];
        // Givens: r = √(C[k][k]² + x[k]²), c = r/C[k][k], s = x[k]/C[k][k]
        let r = (ckk * ckk + xk * xk).sqrt();
        let c = r / ckk;
        let inv_c = ckk / r; // 1/c — multiply instead of dividing per row
        let sn = xk / ckk;
        p[dk] = r;
        ops.mul(2);
        ops.add(1);
        ops.sqrt(1);
        ops.div(3);
        // column k below the diagonal: stride i+1 in the packed layout
        let mut idx = tri(k + 1, k);
        for i in k + 1..s {
            let lik = (p[idx] + sn * x[i]) * inv_c;
            p[idx] = lik;
            // rotated residual reads the NEW C[i][k]
            x[i] = c * x[i] - sn * lik;
            idx += i + 1;
        }
        // per inner iteration: sn·x, ·inv_c, c·x, sn·lik = 4 muls, 2 adds
        ops.mul(4 * (s - k - 1) as u64);
        ops.add(2 * (s - k - 1) as u64);
    }
}

/// Rank-1 **downdate**: replace the packed factor `C` of `B` with the
/// factor of `B − x xᵀ`, via hyperbolic rotations. `x` is destroyed.
///
/// Errors when a pivot `C[k][k]² − x[k]²` is not comfortably positive —
/// the caller must then re-factorize (the triangle's columns `0..k` have
/// already been rotated). The guard uses a relative margin rather than
/// `> 0.0`: an f32 pivot that survives at `1e-12·C[k][k]²` produces a
/// factor whose forward error is unbounded, which is worse than the
/// honest refusal.
pub fn chol_downdate_1d<O: Ops>(
    p: &mut [f32],
    s: usize,
    x: &mut [f32],
    ops: &mut O,
) -> Result<(), DowndateError> {
    debug_assert_eq!(p.len(), s * (s + 1) / 2);
    debug_assert_eq!(x.len(), s);
    // minimum surviving fraction of the squared pivot (f32: ~2⁻¹² of the
    // original magnitude keeps ~half the mantissa in the new pivot)
    const PIVOT_FLOOR: f32 = 2.44e-4;
    for k in 0..s {
        let dk = tri(k, k);
        let ckk = p[dk];
        let xk = x[k];
        let d = ckk * ckk - xk * xk;
        ops.mul(2);
        ops.add(1);
        if !(d > PIVOT_FLOOR * ckk * ckk) {
            return Err(DowndateError { column: k });
        }
        let r = d.sqrt();
        let c = r / ckk;
        let inv_c = ckk / r;
        let sn = xk / ckk;
        p[dk] = r;
        ops.sqrt(1);
        ops.div(3);
        let mut idx = tri(k + 1, k);
        for i in k + 1..s {
            let lik = (p[idx] - sn * x[i]) * inv_c;
            p[idx] = lik;
            x[i] = c * x[i] - sn * lik;
            idx += i + 1;
        }
        // same 4-mul/2-add inner kernel as the update
        ops.mul(4 * (s - k - 1) as u64);
        ops.add(2 * (s - k - 1) as u64);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::counters::{NoCount, OpCount};
    use super::super::{cholesky1d::cholesky_1d, pack_lower, tri, tri_len};
    use super::*;
    use crate::util::prng::Pcg32;

    fn random_spd_packed(s: usize, beta: f32, rng: &mut Pcg32) -> Vec<f32> {
        let g: Vec<f32> = (0..s * s).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let mut acc = 0.0;
                for k in 0..s {
                    acc += g[i * s + k] * g[j * s + k];
                }
                b[i * s + j] = acc / s as f32 + if i == j { beta } else { 0.0 };
            }
        }
        pack_lower(&b, s)
    }

    /// C Cᵀ on the packed factor, densified lower triangle.
    fn reconstruct(p: &[f32], s: usize) -> Vec<f32> {
        let mut b = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..=i {
                let mut acc = 0.0f32;
                for k in 0..=j {
                    acc += p[tri(i, k)] * p[tri(j, k)];
                }
                b[i * s + j] = acc;
            }
        }
        b
    }

    #[test]
    fn update_matches_refactorization() {
        let mut rng = Pcg32::seed(61);
        // sizes straddling the dot-kernel quad boundary
        for s in [1usize, 2, 3, 5, 8, 13] {
            let b0 = random_spd_packed(s, 0.4, &mut rng);
            let mut factor = b0.clone();
            cholesky_1d(&mut factor, s, &mut NoCount);
            let mut b_exact = b0;
            for round in 0..4 {
                let x: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
                for i in 0..s {
                    for j in 0..=i {
                        b_exact[tri(i, j)] += x[i] * x[j];
                    }
                }
                let mut xr = x;
                chol_update_1d(&mut factor, s, &mut xr, &mut NoCount);
                let got = reconstruct(&factor, s);
                for i in 0..s {
                    for j in 0..=i {
                        let want = b_exact[tri(i, j)];
                        let g = got[i * s + j];
                        assert!(
                            (g - want).abs() < 5e-4 * want.abs().max(1.0),
                            "s={s} round={round} ({i},{j}): {g} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn downdate_inverts_update() {
        let mut rng = Pcg32::seed(62);
        for s in [1usize, 4, 7, 11] {
            let b0 = random_spd_packed(s, 1.0, &mut rng);
            let mut factor = b0.clone();
            cholesky_1d(&mut factor, s, &mut NoCount);
            let reference = factor.clone();
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..s).map(|_| rng.normal()).collect())
                .collect();
            for x in &xs {
                let mut xr = x.clone();
                chol_update_1d(&mut factor, s, &mut xr, &mut NoCount);
            }
            for x in xs.iter().rev() {
                let mut xr = x.clone();
                chol_downdate_1d(&mut factor, s, &mut xr, &mut NoCount).unwrap();
            }
            for (i, (a, b)) in factor.iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "s={s} elem {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn downdate_of_foreign_vector_errors() {
        let mut rng = Pcg32::seed(63);
        let s = 6;
        // B = 0.01 I: subtracting any O(1) x xᵀ leaves it indefinite
        let mut factor = vec![0.0f32; tri_len(s)];
        for i in 0..s {
            factor[tri(i, i)] = 0.1; // C = 0.1 I → B = 0.01 I
        }
        let mut x: Vec<f32> = (0..s).map(|_| 1.0 + rng.uniform()).collect();
        let err = chol_downdate_1d(&mut factor, s, &mut x, &mut NoCount).unwrap_err();
        assert_eq!(err.column, 0);
        assert!(err.to_string().contains("positive definiteness"));
    }

    #[test]
    fn update_is_quadratic_not_cubic() {
        // op counts: the whole point is O(s²) per rank-1 fold
        let mut rng = Pcg32::seed(64);
        let s = 24;
        let mut factor = random_spd_packed(s, 0.5, &mut rng);
        cholesky_1d(&mut factor, s, &mut NoCount);
        let mut x: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
        let mut ops = OpCount::default();
        chol_update_1d(&mut factor, s, &mut x, &mut ops);
        let su = s as u64;
        // ≤ c·s² with a small constant, and ≫ below the s³/6 refactor
        assert!(ops.mul <= 3 * su * su, "mul {}", ops.mul);
        assert!(ops.sqrt == su);
        let refactor = super::super::counters::ops_proposed(su, 1);
        assert!(ops.total() * 2 < refactor.total(), "{} vs {}", ops.total(), refactor.total());
    }
}
