//! Ridge-regression linear algebra — the paper's memory contribution.
//!
//! The output layer of the DFR is trained by Ridge regression
//! `W̃_out = A B⁻¹` with `A = E R̃ᵀ` and `B = R̃ R̃ᵀ + βI` (Eqs. 19–23).
//! The paper proves `B` symmetric positive definite (Eqs. 37–39) and
//! replaces the conventional Gaussian-elimination inversion
//! ([`gaussian`], Algorithm 1) with an **in-place Cholesky decomposition
//! over a packed 1-D array** ([`cholesky1d`], Algorithms 2–4), cutting
//! memory ≈4× (Table 2/8) and multiplies/adds ≈12× (Table 3) at the cost
//! of `s` square roots, and adds a small **write buffer** that breaks the
//! read-modify-write recurrence for HLS pipelining ([`buffered`],
//! Algorithm 5 / Fig. 10).
//!
//! Beyond the paper: [`cholupdate`] advances the packed factor by
//! rank-1 updates/downdates in O(s²), and [`ridge::OnlineRidge`] builds
//! on it to keep a **solved** output layer current sample-by-sample —
//! the streaming Serve-phase path (DESIGN.md §11).
//!
//! All routines are f32 (the FPGA word) and are generic over an [`Ops`]
//! counter so the same code path yields Table 3's operation counts.

pub mod buffered;
pub mod cholesky1d;
pub mod cholupdate;
pub mod counters;
pub mod gaussian;
pub mod ridge;

pub use cholupdate::{chol_downdate_1d, chol_update_1d, DowndateError};
pub use counters::{NoCount, OpCount, Ops};
pub use ridge::{
    OnlineRidge, OnlineRidgeConfig, RidgeAccumulator, RidgeMethod, RidgeSolution, SolveWorkspace,
};

/// Index into the packed lower-triangular 1-D array: element (i, j), i ≥ j,
/// lives at `P[i(i+1)/2 + j]` (paper Eq. 41).
#[inline(always)]
pub fn tri(i: usize, j: usize) -> usize {
    debug_assert!(j <= i);
    i * (i + 1) / 2 + j
}

/// Number of words in the packed representation of an s×s symmetric matrix.
#[inline]
pub fn tri_len(s: usize) -> usize {
    s * (s + 1) / 2
}

/// Pack a dense symmetric matrix (row-major s×s) into the 1-D lower
/// triangle (Eq. 41).
pub fn pack_lower(dense: &[f32], s: usize) -> Vec<f32> {
    let mut p = vec![0.0f32; tri_len(s)];
    for i in 0..s {
        for j in 0..=i {
            p[tri(i, j)] = dense[i * s + j];
        }
    }
    p
}

/// Expand a packed lower triangle back to a dense symmetric matrix.
pub fn unpack_symmetric(p: &[f32], s: usize) -> Vec<f32> {
    let mut d = vec![0.0f32; s * s];
    for i in 0..s {
        for j in 0..=i {
            d[i * s + j] = p[tri(i, j)];
            d[j * s + i] = p[tri(i, j)];
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tri_indexing_row_major_sequential() {
        // paper: "components of the lower triangle are stored sequentially
        // in the row direction"
        let mut expect = 0;
        for i in 0..10 {
            for j in 0..=i {
                assert_eq!(tri(i, j), expect);
                expect += 1;
            }
        }
        assert_eq!(tri_len(10), expect);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let s = 5;
        let mut dense = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let v = (1 + i.min(j) * s + i.max(j)) as f32;
                dense[i * s + j] = v;
            }
        }
        let p = pack_lower(&dense, s);
        assert_eq!(p.len(), 15);
        assert_eq!(unpack_symmetric(&p, s), dense);
    }
}
