//! dfr-edge CLI: the leader entry point for the online edge DFR system.
//!
//! Subcommands
//!   train       — run the §4.1 protocol on a synthetic dataset (native engine)
//!   serve       — online demo: stream a dataset through the coordinator
//!   grid        — grid-search baseline (Table 5 comparison)
//!   fpga        — print the co-design simulator reports (Tables 9-12)
//!   gen-data    — export a synthetic dataset as npz
//!   artifacts   — check the AOT artifact manifest / compile smoke test

use std::process::ExitCode;

use dfr_edge::coordinator::{
    NativeEngine, PjrtEngine, Request, Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::{profiles::Profile, synth};
use dfr_edge::dfr::grid;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::fpga::schedule::ShapeParams;
use dfr_edge::log_info;
use dfr_edge::report;
use dfr_edge::runtime::{DfrExecutor, Manifest};
use dfr_edge::util::args::Command;
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::timer::fmt_secs;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        eprintln!("{}", top_usage());
        return ExitCode::FAILURE;
    };
    let rest = &argv[1..];
    let result = match cmd {
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "grid" => cmd_grid(rest),
        "fpga" => cmd_fpga(rest),
        "gen-data" => cmd_gen_data(rest),
        "artifacts" => cmd_artifacts(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", top_usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn top_usage() -> String {
    "dfr-edge — online training and inference system for delayed feedback reservoirs\n\
     \n\
     commands:\n\
       train      run the paper's training protocol on a synthetic dataset\n\
       serve      stream a dataset through the online coordinator\n\
       grid       grid-search baseline over (p, q, beta)\n\
       fpga       FPGA co-design simulator reports (Tables 9-12)\n\
       gen-data   export a synthetic dataset as npz\n\
       artifacts  verify the AOT artifact manifest (PJRT smoke test)\n\
     \n\
     run `dfr-edge <command> --help` for options"
        .to_string()
}

fn profile_arg(p: &dfr_edge::util::args::Parsed) -> Result<&'static Profile, String> {
    let name = p.get("dataset");
    Profile::by_name(name).ok_or_else(|| format!("unknown dataset '{name}' (see Table 4 names)"))
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("train", "run the §4.1 protocol (truncated-BP SGD + in-place Cholesky ridge)")
        .opt("dataset", "jpvow", "Table 4 dataset profile")
        .opt("seed", "42", "dataset + protocol seed")
        .opt("epochs", "25", "SGD epochs")
        .opt("nx", "30", "reservoir size");
    let p = cmd.parse(argv)?;
    let prof = profile_arg(&p)?;
    let ds = synth::generate(prof, p.get_u64("seed")?);
    let cfg = TrainConfig {
        epochs: p.get_usize("epochs")?,
        nx: p.get_usize("nx")?,
        seed: p.get_u64("seed")?,
        ..Default::default()
    };
    log_info!("training on {} (train={}, test={})", prof.name, ds.train.len(), ds.test.len());
    let model = train(&ds, &cfg);
    println!(
        "p={:.4} q={:.4} beta={:.0e} | bp {} + ridge {} | test accuracy {:.3}",
        model.reservoir.p,
        model.reservoir.q,
        model.solution.beta,
        fmt_secs(model.bp_seconds),
        fmt_secs(model.ridge_seconds),
        model.test_accuracy(&ds)
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("serve", "online demo: collect -> train -> serve over the coordinator")
        .opt("dataset", "jpvow", "Table 4 dataset profile")
        .opt("seed", "42", "seed")
        .opt("epochs", "25", "SGD epochs")
        .opt("engine", "native", "compute engine: native | simd | quant | pjrt (simd = native on the runtime-dispatched AVX2 kernel table)")
        .opt(
            "simd",
            "",
            "kernel table selection: auto (benchmark probe) | force (error without AVX2+FMA) | \
             off (empty = auto for --engine simd, DFR_SIMD env / scalar otherwise)",
        )
        .opt("qformat", "q4.12", "fixed-point word for the quant engine (q4.12 | q6.10 | q8.8 | qI.F)")
        .opt("artifacts", "artifacts", "artifact dir (pjrt engine)")
        .opt("collect", "0", "collect target (0 = whole training split)")
        .opt("shards", "0", "coordinator worker shards (0 = one per core)")
        .opt("window", "0", "streaming-ridge sliding window for labelled Serve samples (0 = off)")
        .opt("forgetting", "0", "streaming-ridge λ-forgetting factor in (0, 1) (0 = off)")
        .flag(
            "adapt-reservoir",
            "online reservoir adaptation: labelled Serve samples drive truncated-BP steps on (p, q)",
        )
        .opt("adapt-lr", "0.01", "adaptation SGD learning rate")
        .opt(
            "adapt-drift-eps",
            "0.02",
            "accumulated |Δp|+|Δq| that triggers re-featurization + quant recalibration",
        )
        .opt(
            "checkpoint-dir",
            "",
            "durable session checkpoints: shards snapshot to <dir>/shard-<i>.ckpt and \
             restarts rehydrate from it (empty = off)",
        )
        .opt(
            "checkpoint-every",
            "64",
            "snapshot cadence in state-mutating requests per shard (with --checkpoint-dir)",
        )
        .opt(
            "call-timeout-ms",
            "0",
            "per-request deadline: retry a saturated/respawning shard with backoff and give \
             up after this many ms (0 = block indefinitely)",
        )
        .opt(
            "listen",
            "",
            "after the demo loop, keep serving the framed TCP protocol on this address \
             (e.g. 127.0.0.1:7077; empty = exit after the demo)",
        )
        .opt(
            "max-resident",
            "0",
            "hibernation: per-shard cap on resident sessions — the coldest park to \
             --hibernate-dir and rehydrate on their next request (0 = unlimited)",
        )
        .opt(
            "hibernate-after",
            "0",
            "hibernation: idle seconds after which a quiet session is parked (0 = off)",
        )
        .opt(
            "hibernate-dir",
            "hibernate",
            "hibernation store root (used with --max-resident / --hibernate-after)",
        )
        .opt(
            "metrics-listen",
            "",
            "observability HTTP endpoint serving /metrics (Prometheus text 0.0.4), \
             /healthz and /readyz (e.g. 127.0.0.1:9091; empty = off)",
        )
        .opt(
            "slow-request-ms",
            "0",
            "log a WARN with the per-stage span breakdown for any request slower than \
             this many ms end-to-end (0 = off)",
        );
    let p = cmd.parse(argv)?;
    let prof = profile_arg(&p)?;
    let ds = synth::generate(prof, p.get_u64("seed")?);
    let collect = match p.get_usize("collect")? {
        0 => ds.train.len(),
        n => n,
    };
    let mut scfg = SessionConfig::new(prof.n_v, prof.n_c, collect);
    scfg.train.epochs = p.get_usize("epochs")?;
    match p.get_usize("window")? {
        0 => {}
        n => scfg.train.window = Some(n),
    }
    let forgetting = p.get_f32("forgetting")?;
    if forgetting > 0.0 {
        if scfg.train.window.is_some() {
            return Err(
                "--window and --forgetting are mutually exclusive (an evicted sample's \
                 decayed weight cannot be downdated exactly) — pick one streaming mode"
                    .to_string(),
            );
        }
        scfg.train.forgetting = Some(forgetting);
    }
    if p.has_flag("adapt-reservoir") {
        scfg.adapt_reservoir = true;
        scfg.adapt_lr = p.get_f32("adapt-lr")?;
        scfg.adapt_drift_eps = p.get_f32("adapt-drift-eps")?;
        if scfg.train.window.is_none() && scfg.train.forgetting.is_none() {
            // adaptation rides the streaming ridge (the reseed needs the
            // online factor + sample ring) — default a window in
            log_info!("adapt-reservoir: no streaming mode set, defaulting --window {}", collect.min(256));
            scfg.train.window = Some(collect.min(256));
        }
    }

    // Resolve the kernel table before any engine / accumulator is
    // constructed, and pin it process-wide: every shard replica, online
    // ridge and batch trainer then folds on the same table, which is
    // what keeps checkpoint/hibernate round-trips bitwise.
    let engine_name = p.get("engine");
    let simd_mode = match p.get("simd") {
        "" if engine_name == "simd" => Some(dfr_edge::simd::SimdMode::Auto),
        "" => None, // keep the DFR_SIMD env / scalar process default
        s => Some(dfr_edge::simd::SimdMode::parse(s).map_err(|e| e.to_string())?),
    };
    let kernels = match simd_mode {
        Some(m) => {
            let k = dfr_edge::simd::Kernels::try_select(m).map_err(|e| e.to_string())?;
            if !dfr_edge::simd::set_global_kernels(k) {
                log_info!("simd: process kernel table already pinned; engine uses {}", k.name);
            }
            k
        }
        None => dfr_edge::simd::global_kernels(),
    };
    if engine_name == "simd" || simd_mode.is_some() {
        log_info!("simd kernel table: {}", kernels.name);
    }

    let engine: Box<dyn dfr_edge::coordinator::Engine> = match engine_name {
        "native" | "simd" => Box::new(NativeEngine::with_kernels(
            scfg.train.nx,
            prof.n_c,
            dfr_edge::dfr::reservoir::Nonlinearity::Linear { alpha: 1.0 },
            kernels,
        )),
        "quant" => {
            let fmt = dfr_edge::quant::QFormat::parse(p.get("qformat"))
                .ok_or_else(|| format!("bad --qformat '{}' (try q4.12)", p.get("qformat")))?;
            log_info!("quant engine: {} datapath (PWL-LUT nonlinearity)", fmt.name());
            Box::new(dfr_edge::quant::QuantEngine::with_config(
                scfg.train.nx,
                prof.n_c,
                scfg.train.f,
                dfr_edge::quant::QuantConfig::with_format(fmt),
            ))
        }
        "pjrt" => {
            let manifest = Manifest::load(p.get("artifacts")).map_err(|e| format!("{e:#}"))?;
            let pa = manifest.profile(prof.name).map_err(|e| format!("{e:#}"))?;
            let exec = DfrExecutor::new(pa).map_err(|e| format!("{e:#}"))?;
            log_info!("PJRT platform: {}", exec.platform());
            Box::new(PjrtEngine::new(exec))
        }
        other => return Err(format!("unknown engine '{other}'")),
    };

    let mut server_cfg = ServerConfig::new(scfg);
    server_cfg.seed = p.get_u64("seed")?;
    match p.get_usize("shards")? {
        0 => {} // keep the one-shard-per-core default
        n => server_cfg.shards = n,
    }
    match p.get("checkpoint-dir") {
        "" => {}
        dir => {
            let mut ck = dfr_edge::coordinator::CheckpointConfig::new(dir);
            ck.every = p.get_u64("checkpoint-every")?.max(1);
            log_info!("checkpointing to {dir} every {} mutations/shard", ck.every);
            server_cfg.checkpoint = Some(ck);
        }
    }
    let max_resident = p.get_usize("max-resident")?;
    let hibernate_after = p.get_u64("hibernate-after")?;
    if max_resident > 0 || hibernate_after > 0 {
        let mut hib = dfr_edge::coordinator::HibernateConfig::new(p.get("hibernate-dir"));
        if max_resident > 0 {
            hib.max_resident = max_resident;
        }
        if hibernate_after > 0 {
            hib.hibernate_after = Some(std::time::Duration::from_secs(hibernate_after));
        }
        log_info!(
            "hibernation: dir={} max_resident/shard={} idle_after={}",
            hib.dir.display(),
            if max_resident > 0 { max_resident.to_string() } else { "unlimited".to_string() },
            if hibernate_after > 0 { format!("{hibernate_after}s") } else { "off".to_string() },
        );
        server_cfg.hibernate = Some(hib);
    }
    match p.get_u64("slow-request-ms")? {
        0 => {}
        ms => server_cfg.slow_request_ms = Some(ms),
    }
    let call_timeout = match p.get_u64("call-timeout-ms")? {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let srv = std::sync::Arc::new(Server::spawn(engine, server_cfg));
    log_info!("coordinator: {} shard(s)", srv.shards());
    let mut exporter = match p.get("metrics-listen") {
        "" => None,
        addr => {
            let ex =
                dfr_edge::coordinator::MetricsExporter::bind(std::sync::Arc::clone(&srv), addr)
                    .map_err(|e| format!("metrics: bind {addr} failed: {e}"))?;
            log_info!(
                "observability endpoint on http://{}/ (/metrics /healthz /readyz)",
                ex.local_addr()
            );
            Some(ex)
        }
    };
    // one call surface for the demo loop: bounded when a deadline is
    // set (survives a shard respawn), blocking otherwise
    let rpc = |req: Request| -> Result<Response, String> {
        match call_timeout {
            Some(t) => srv.call_timeout(req, t).map_err(|e| e.to_string()),
            None => srv.call(req).map_err(|e| e.to_string()),
        }
    };
    let sw = dfr_edge::util::timer::Stopwatch::start();
    let mut trained = false;
    for s in &ds.train {
        match rpc(Request::Labelled { session: 1, sample: s.clone() })? {
            Response::Trained { p, q, beta, train_seconds } => {
                trained = true;
                println!(
                    "trained: p={p:.4} q={q:.4} beta={beta:.0e} in {}",
                    fmt_secs(train_seconds)
                );
            }
            Response::Rejected(m) => return Err(format!("rejected: {m}")),
            _ => {}
        }
    }
    if !trained {
        match rpc(Request::Finalize { session: 1 })? {
            Response::Trained { p, q, beta, train_seconds } => println!(
                "trained: p={p:.4} q={q:.4} beta={beta:.0e} in {}",
                fmt_secs(train_seconds)
            ),
            other => return Err(format!("finalize failed: {other:?}")),
        }
    }
    let mut correct = 0;
    for s in &ds.test {
        if let Response::Prediction { class, .. } =
            rpc(Request::Infer { session: 1, sample: s.clone() })?
        {
            if class == s.label {
                correct += 1;
            }
        }
    }
    println!(
        "served {} inferences, accuracy {:.3}, wall {}",
        ds.test.len(),
        correct as f64 / ds.test.len() as f64,
        fmt_secs(sw.elapsed_secs())
    );
    if let Response::StatsText(t) = srv.call(Request::Stats).map_err(|e| e.to_string())? {
        print!("{t}");
    }
    match p.get("listen") {
        "" => {
            // stop the scrape endpoint first so its Arc clone is gone
            // and the coordinator can be unwrapped for a clean drain
            if let Some(ex) = exporter.as_mut() {
                ex.shutdown();
            }
            drop(exporter);
            if let Ok(owned) = std::sync::Arc::try_unwrap(srv) {
                owned.shutdown();
            }
        }
        addr => {
            // hand the trained coordinator to the TCP edge and serve
            // remote sessions until the process is killed (the metrics
            // endpoint, when bound, keeps serving alongside)
            let net_cfg = dfr_edge::coordinator::NetConfig {
                addr: addr.to_string(),
                call_timeout: call_timeout.unwrap_or(std::time::Duration::from_secs(5)),
                ..dfr_edge::coordinator::NetConfig::default()
            };
            let net = dfr_edge::coordinator::NetServer::bind(std::sync::Arc::clone(&srv), net_cfg)
                .map_err(|e| format!("net: bind {addr} failed: {e}"))?;
            log_info!("net edge listening on {} (kill the process to stop)", net.local_addr());
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
    }
    Ok(())
}

fn cmd_grid(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("grid", "grid search over (p, q, beta) — the Table 5 baseline")
        .opt("dataset", "jpvow", "Table 4 dataset profile")
        .opt("seed", "42", "seed")
        .opt("divs", "4", "grid divisions per axis")
        .opt("threads", "8", "worker threads");
    let p = cmd.parse(argv)?;
    let prof = profile_arg(&p)?;
    let ds = synth::generate(prof, p.get_u64("seed")?);
    let cfg = TrainConfig::default();
    let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(p.get_u64("seed")?));
    let r = grid::search(&ds, &mask, &cfg, p.get_usize("divs")?, p.get_usize("threads")?);
    println!(
        "grid {}x{} best: p={:.4} q={:.4} beta={:.0e} accuracy={:.3} in {}",
        r.divs,
        r.divs,
        r.best.p,
        r.best.q,
        r.best.beta,
        r.best.accuracy,
        fmt_secs(r.seconds)
    );
    Ok(())
}

fn cmd_fpga(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("fpga", "co-design simulator reports (Tables 9-12)")
        .opt("dataset", "jpvow", "Table 4 dataset profile")
        .opt("epochs", "25", "training epochs in the workload");
    let p = cmd.parse(argv)?;
    let prof = profile_arg(&p)?;
    let shape = ShapeParams::new(30, prof.n_v as u64, prof.n_c as u64, prof.t_max as u64);
    let epochs = p.get_usize("epochs")? as u64;
    println!("## Table 9 — SW vs HW ({} workload)\n", prof.name);
    println!("{}", report::table9_markdown(shape, prof.train as u64, epochs, 4, prof.test as u64));
    println!("## Table 11 — configurations\n");
    println!("{}", report::table11_markdown(shape, prof.train as u64, epochs, 4, prof.test as u64));
    println!("## Table 12 — existing FPGA DFR systems\n");
    println!("{}", report::table12_markdown());
    Ok(())
}

fn cmd_gen_data(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("gen-data", "export a synthetic dataset as npz (train/test splits)")
        .opt("dataset", "jpvow", "Table 4 dataset profile")
        .opt("seed", "42", "seed")
        .req("out", "output .npz path");
    let p = cmd.parse(argv)?;
    let prof = profile_arg(&p)?;
    let ds = synth::generate(prof, p.get_u64("seed")?);
    let mut arrays = std::collections::BTreeMap::new();
    for (split, samples) in [("train", &ds.train), ("test", &ds.test)] {
        let t_max = prof.t_max;
        let mut x = Vec::with_capacity(samples.len() * t_max * prof.n_v);
        let mut labels = Vec::with_capacity(samples.len());
        let mut lengths = Vec::with_capacity(samples.len());
        for s in samples.iter() {
            x.extend_from_slice(&s.padded(prof.n_v, t_max));
            labels.push(s.label as f32);
            lengths.push(s.t as f32);
        }
        arrays.insert(
            format!("{split}_x"),
            (vec![samples.len(), t_max, prof.n_v], x),
        );
        arrays.insert(format!("{split}_y"), (vec![samples.len()], labels));
        arrays.insert(format!("{split}_len"), (vec![samples.len()], lengths));
    }
    dfr_edge::data::npz::write_npz(p.get("out"), &arrays).map_err(|e| format!("{e:#}"))?;
    println!("wrote {}", p.get("out"));
    Ok(())
}

fn cmd_artifacts(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("artifacts", "verify the AOT manifest and compile one profile on PJRT")
        .opt("artifacts", "artifacts", "artifact dir")
        .opt("dataset", "jpvow", "profile to smoke-test");
    let p = cmd.parse(argv)?;
    let manifest = Manifest::load(p.get("artifacts")).map_err(|e| format!("{e:#}"))?;
    println!("profiles: {:?}", manifest.profiles.keys().collect::<Vec<_>>());
    let pa = manifest.profile(p.get("dataset")).map_err(|e| format!("{e:#}"))?;
    let exec = DfrExecutor::new(pa).map_err(|e| format!("{e:#}"))?;
    println!(
        "compiled 5 entry points for '{}' on {} (V={}, C={}, T_pad={}, s={})",
        pa.name,
        exec.platform(),
        pa.n_v,
        pa.n_c,
        pa.t_pad,
        pa.s
    );
    Ok(())
}
