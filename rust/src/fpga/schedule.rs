//! Loop-nest cycle model: initiation intervals, pipeline fill, and the
//! Algorithm-5 write buffer (Fig. 10).
//!
//! A pipelined loop of `n` iterations at initiation interval `II` with
//! body depth `D` takes `D + II·(n-1)` cycles; a non-pipelined loop takes
//! `D·n`. A loop-carried read-modify-write dependence through a floating
//! add forces `II ≥ add_latency` — that is exactly the bottleneck the
//! paper's `RegSize`-deep shift-register buffer removes: the accumulation
//! round-robins across `RegSize` independent registers, legalising
//! `II = ceil(add_latency / RegSize)` (II=1 once RegSize ≥ latency is not
//! needed because HLS also rebalances; the paper reached II=1 with
//! RegSize=4 and a 2-stage add at 100 MHz — we model the achieved II as
//! `ceil(dep_latency / RegSize)`).

use super::resource::FpOp;

/// A loop nest annotated for the cycle model.
#[derive(Clone, Debug)]
pub struct Loop {
    pub name: &'static str,
    /// iteration count
    pub trip: u64,
    /// pipeline body depth in cycles (sum of operator latencies on the
    /// critical path of one iteration)
    pub depth: u32,
    /// initiation interval (1 = fully pipelined; = depth if unpipelined)
    pub ii: u32,
    /// HLS unroll factor: parallel datapath instances working the loop
    /// (must match the module's operator-instance count in
    /// `design::SystemModel::modules`, which is what the DSPs pay for)
    pub unroll: u32,
}

impl Loop {
    /// Cycles for the whole loop, pipeline fill included.
    pub fn cycles(&self) -> u64 {
        if self.trip == 0 {
            return 0;
        }
        let eff_trip = self.trip.div_ceil(u64::from(self.unroll.max(1)));
        u64::from(self.depth) + u64::from(self.ii) * (eff_trip - 1)
    }
}

/// Dependence-limited II of a read-modify-write accumulation through an
/// f32 adder with an optional write buffer of depth `reg_size`
/// (Algorithm 5; `reg_size = 1` models the naive Algorithm 3/4 loop).
pub fn accumulation_ii(reg_size: u32) -> u32 {
    accumulation_ii_arith(reg_size, super::resource::Arith::F32)
}

/// [`accumulation_ii`] on an explicit datapath. A fixed-point add closes
/// in one cycle, so the loop-carried dependence that motivates the
/// paper's Algorithm-5 write buffer disappears (II = 1 at RegSize = 1) —
/// the quantized datapath gets the Fig. 10 speedup for free.
pub fn accumulation_ii_arith(reg_size: u32, a: super::resource::Arith) -> u32 {
    let dep = FpOp::Add.latency_arith(a); // the loop-carried add
    dep.div_ceil(reg_size.max(1))
}

/// Critical-path depth of a multiply-accumulate body (mul feeding add).
pub fn mac_depth() -> u32 {
    FpOp::Mul.latency() + FpOp::Add.latency()
}

/// Cycle model of the whole per-sample DFR pipeline for one dataset
/// shape, mirroring the modules of Table 10. All loops derive their trip
/// counts from the paper's own loop structures.
#[derive(Clone, Copy, Debug)]
pub struct ShapeParams {
    pub nx: u64,
    pub v: u64,
    pub ny: u64,
    pub t: u64,
    /// s = Nx² + Nx + 1
    pub s: u64,
}

impl ShapeParams {
    pub fn new(nx: u64, v: u64, ny: u64, t: u64) -> Self {
        ShapeParams {
            nx,
            v,
            ny,
            t,
            s: nx * nx + nx + 1,
        }
    }
}

/// Schedule knobs (the Table 11 configurations toggle these).
#[derive(Clone, Copy, Debug)]
pub struct ScheduleConfig {
    /// pipeline the inner loops (ELSE II = depth)
    pub pipelined: bool,
    /// Algorithm-5 write buffer depth (1 = no buffer)
    pub reg_size: u32,
    /// inline the reservoir state update (removes the per-call module
    /// handshake overhead; costs duplicated resources)
    pub inline_state_update: bool,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            pipelined: true,
            reg_size: 4,
            inline_state_update: true,
        }
    }
}

/// Per-call handshake overhead of a non-inlined HLS sub-module (cycles).
const CALL_OVERHEAD: u64 = 40;

fn ii_or_depth(cfg: &ScheduleConfig, ii: u32, depth: u32) -> u32 {
    if cfg.pipelined {
        ii
    } else {
        depth
    }
}

/// Cycles for one reservoir time step (mask matvec + Eq. 14 cascade).
///
/// The node cascade is a true recurrence through `q·x_{n-1}` — II is
/// dependence-limited (mul+add) and pipelining cannot fix it; inlining
/// removes the call overhead (this is the bottleneck the paper's
/// "inlined" configuration targets after ridge is buffered).
pub fn reservoir_step_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    // masking: j = M u(k) — Nx independent dot products of length V
    let mask = Loop {
        name: "mask_matvec",
        trip: p.nx * p.v,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 2,
    };
    // cascade: x_n = p·f(...) + q·x_{n-1}; dependence distance 1 through
    // mul+add
    let dep_ii = mac_depth();
    let cascade = Loop {
        name: "node_cascade",
        trip: p.nx,
        depth: 2 * mac_depth(),
        ii: ii_or_depth(cfg, dep_ii, 2 * mac_depth()),
        unroll: 1, // true recurrence: cannot unroll
    };
    let call = if cfg.inline_state_update {
        0
    } else {
        CALL_OVERHEAD
    };
    mask.cycles() + cascade.cycles() + call
}

/// Cycles for the DPRR rank-1 update of one time step (Nx(Nx+1) MACs,
/// independent across entries → II=1 when pipelined).
pub fn dprr_step_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    Loop {
        name: "dprr_rank1",
        trip: p.nx * (p.nx + 1),
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 6, // dprr_and_io MACs
    }
    .cycles()
}

/// Cycles for the full forward pass of one sample.
pub fn forward_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    p.t * (reservoir_step_cycles(p, cfg) + dprr_step_cycles(p, cfg))
}

/// Cycles for one truncated-BP training step (forward + Eqs. 33-36 +
/// SGD update of W, b).
pub fn train_step_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    let nr = p.nx * (p.nx + 1);
    let fwd = forward_cycles(p, cfg);
    // output layer fwd + dz + dW outer product + dr = Wᵀdz
    let out = Loop {
        name: "output_and_grads",
        trip: 3 * p.ny * nr,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 6, // backprop module MACs
    };
    // bpv (Eq. 33): Nx dot products of length Nx+1
    let bpv = Loop {
        name: "bpv",
        trip: p.nx * (p.nx + 1),
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 3,
    };
    // Eq. 34 reverse cascade: dependence-limited like the forward one
    let rev = Loop {
        name: "dx_reverse",
        trip: p.nx,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, mac_depth(), mac_depth()),
        unroll: 1, // recurrence
    };
    // Eqs. 35-36 reductions + parameter update
    let red = Loop {
        name: "dp_dq_reduce",
        trip: 2 * p.nx,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, accumulation_ii(cfg.reg_size), mac_depth()),
        unroll: 1,
    };
    fwd + out.cycles() + bpv.cycles() + rev.cycles() + red.cycles()
}

/// Cycles for the ridge accumulation of one sample (packed rank-1 +
/// A row update): s(s+1)/2 + s MACs, II dependence-free.
pub fn ridge_accumulate_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    Loop {
        name: "ridge_rank1",
        trip: p.s * (p.s + 1) / 2 + p.s,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 6, // shared dprr/io MACs
    }
    .cycles()
}

/// Cycles for the in-place Cholesky ridge solve (Algorithms 2 + 5),
/// using the measured trip counts of `linalg::counters::ops_proposed`.
///
/// The substitution inner loops carry the read-modify-write dependence:
/// their II is `accumulation_ii(reg_size)` — the paper's Fig. 10 story.
pub fn ridge_solve_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    let ops = crate::linalg::counters::ops_proposed(p.s, p.ny);
    // decomposition: diag + column updates, accumulation-limited
    let chol_macs = ops.add; // ≈ fused mul-sub count of Alg. 2 + 3 + 4
    let acc_ii = ii_or_depth(cfg, accumulation_ii(cfg.reg_size), mac_depth());
    let macs = Loop {
        name: "cholesky_macs",
        trip: chol_macs,
        depth: mac_depth(),
        ii: acc_ii,
        unroll: cfg.reg_size, // Alg. 5 buffer lanes
    };
    // divisions and square roots are sequential scalar cores
    let divs = Loop {
        name: "div",
        trip: ops.div,
        depth: FpOp::Div.latency(),
        ii: ii_or_depth(cfg, 1, FpOp::Div.latency()),
        unroll: 1,
    };
    let sqrts = Loop {
        name: "sqrt",
        trip: ops.sqrt,
        depth: FpOp::Sqrt.latency(),
        ii: ii_or_depth(cfg, 1, FpOp::Sqrt.latency()),
        unroll: 1,
    };
    macs.cycles() + divs.cycles() + sqrts.cycles()
}

/// Cycles for the naive Gaussian-elimination ridge solve (Algorithm 1)
/// under the same schedule rules — the Fig. 9 numerator.
pub fn ridge_solve_gaussian_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    let ops = crate::linalg::counters::ops_naive(p.s, p.ny);
    let acc_ii = ii_or_depth(cfg, accumulation_ii(cfg.reg_size), mac_depth());
    let macs = Loop {
        name: "gauss_macs",
        trip: ops.add.max(ops.mul),
        depth: mac_depth(),
        ii: acc_ii,
        unroll: cfg.reg_size,
    };
    let divs = Loop {
        name: "div",
        trip: ops.div,
        depth: FpOp::Div.latency(),
        ii: ii_or_depth(cfg, 1, FpOp::Div.latency()),
        unroll: 1,
    };
    macs.cycles() + divs.cycles()
}

/// Inference cycles for one sample: forward + output layer (W̃ r̃).
pub fn infer_cycles(p: &ShapeParams, cfg: &ScheduleConfig) -> u64 {
    let out = Loop {
        name: "wout_matvec",
        trip: p.ny * p.s,
        depth: mac_depth(),
        ii: ii_or_depth(cfg, 1, mac_depth()),
        unroll: 6,
    };
    forward_cycles(p, cfg) + out.cycles()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ShapeParams {
        ShapeParams::new(30, 12, 9, 29) // JPVOW
    }

    #[test]
    fn loop_cycles_formula() {
        let l = Loop {
            name: "t",
            trip: 10,
            depth: 5,
            ii: 1,
            unroll: 1,
        };
        assert_eq!(l.cycles(), 5 + 9);
        let l0 = Loop {
            name: "t",
            trip: 0,
            depth: 5,
            ii: 1,
            unroll: 1,
        };
        assert_eq!(l0.cycles(), 0);
        let lu = Loop {
            name: "t",
            trip: 12,
            depth: 5,
            ii: 1,
            unroll: 4,
        };
        assert_eq!(lu.cycles(), 5 + 2);
    }

    #[test]
    fn write_buffer_lowers_ii() {
        assert_eq!(accumulation_ii(1), FpOp::Add.latency());
        assert!(accumulation_ii(4) < accumulation_ii(1));
        assert_eq!(accumulation_ii(8), 1);
        // fixed-point 1-cycle add: no buffer needed for II=1
        let fx = super::super::resource::Arith::Fixed { bits: 16 };
        assert_eq!(accumulation_ii_arith(1, fx), 1);
    }

    #[test]
    fn pipelining_helps_everywhere() {
        let p = shape();
        let pipe = ScheduleConfig::default();
        let nopipe = ScheduleConfig {
            pipelined: false,
            ..Default::default()
        };
        assert!(forward_cycles(&p, &pipe) < forward_cycles(&p, &nopipe));
        assert!(ridge_solve_cycles(&p, &pipe) < ridge_solve_cycles(&p, &nopipe));
        assert!(train_step_cycles(&p, &pipe) < train_step_cycles(&p, &nopipe));
    }

    #[test]
    fn reg_size_speeds_up_solve() {
        let p = shape();
        let buf1 = ScheduleConfig {
            reg_size: 1,
            ..Default::default()
        };
        let buf4 = ScheduleConfig::default();
        let c1 = ridge_solve_cycles(&p, &buf1);
        let c4 = ridge_solve_cycles(&p, &buf4);
        assert!(
            c1 > 3 * c4,
            "RegSize=4 should cut the solve ~4x: {c1} vs {c4}"
        );
    }

    #[test]
    fn cholesky_beats_gaussian_in_cycles() {
        // Fig. 9's conclusion must hold in the cycle model too
        let p = shape();
        let cfg = ScheduleConfig::default();
        let g = ridge_solve_gaussian_cycles(&p, &cfg);
        let c = ridge_solve_cycles(&p, &cfg);
        let ratio = g as f64 / c as f64;
        assert!(ratio > 4.0, "ratio {ratio}");
    }

    #[test]
    fn inline_removes_call_overhead() {
        let p = shape();
        let inl = ScheduleConfig::default();
        let shared = ScheduleConfig {
            inline_state_update: false,
            ..Default::default()
        };
        assert!(reservoir_step_cycles(&p, &inl) < reservoir_step_cycles(&p, &shared));
    }

    #[test]
    fn forward_scales_linearly_in_t() {
        let cfg = ScheduleConfig::default();
        let a = forward_cycles(&ShapeParams::new(30, 12, 9, 10), &cfg);
        let b = forward_cycles(&ShapeParams::new(30, 12, 9, 20), &cfg);
        assert_eq!(2 * a, b);
    }
}
