//! HLS-like FPGA co-design simulator — the substitute for the paper's
//! Zynq-7000 (xc7z020clg400-1) + Vitis HLS 2021.1 testbed (DESIGN.md §3).
//!
//! The paper's hardware results (Tables 9–11, Fig. 10) compare
//! *schedules*: pipelined vs non-pipelined loops, inlined vs shared
//! modules, write-buffered vs memory-conflicting substitutions, and an
//! ARM Cortex-A9 software reference. This module models exactly those
//! quantities:
//!
//! * [`resource`] — the xc7z020 budget (LUT/FF/BRAM/DSP) and per-operator
//!   costs of the f32 datapath HLS instantiates;
//! * [`schedule`] — loop-nest cycle models with initiation intervals,
//!   pipeline fill, the `RegSize` write buffer of Algorithm 5, and the
//!   dependence-limited IIs Fig. 10 illustrates;
//! * [`power`] — static + activity-based dynamic power calibrated to the
//!   paper's Vivado reports (0.734 W HW @ 100 MHz, 1.53 W A9);
//! * [`design`] — the three synthesis configurations of Tables 9/11
//!   (standard pipelined, non-pipelined, inlined) assembled from the
//!   per-module schedules, plus the SW-only reference model.
//!
//! Absolute seconds are a model, not a measurement; the deliverable is
//! the *shape*: who wins, by what factor, and how the Pareto frontier of
//! Table 11 moves with the configuration.

pub mod design;
pub mod power;
pub mod resource;
pub mod schedule;

pub use design::{DesignConfig, DesignReport, SystemModel};
pub use resource::{ResourceBudget, ResourceUsage, XC7Z020};
