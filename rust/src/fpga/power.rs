//! Power model: static + activity-based dynamic power, calibrated to the
//! paper's Vivado and board reports (Table 9: 0.734 W HW @100 MHz,
//! 1.530 W for the Cortex-A9 SW run; Table 11: 0.704/0.864 W).
//!
//! Dynamic power scales with clock frequency and switched capacitance,
//! which we proxy by resource usage (DSP-heavy datapaths dominate);
//! static power is the 7-series leakage floor. The model is fitted so the
//! paper's three HW design points land within a few percent, then used
//! to extrapolate across configurations.

use super::resource::ResourceUsage;

/// 7-series leakage + PS idle floor (W) — Vivado reports ~0.12-0.16 W
/// for xc7z020 designs of this size.
const STATIC_W: f32 = 0.140;

/// Dynamic power coefficients (W per resource-unit at 100 MHz),
/// least-squares fitted to the paper's three design points
/// (standard 0.734 W / non-pipelined 0.704 W / inlined 0.864 W).
const W_PER_DSP: f32 = 2.4e-3;
const W_PER_KLUT: f32 = 5.6e-3;
const W_PER_KFF: f32 = 1.9e-3;
const W_PER_BRAM: f32 = 1.1e-3;

/// FPGA power at a clock frequency (Hz) for a synthesized design.
pub fn fpga_power_w(usage: &ResourceUsage, clock_hz: f64) -> f32 {
    let f_scale = (clock_hz / 100e6) as f32;
    let dynamic = W_PER_DSP * usage.dsp as f32
        + W_PER_KLUT * usage.lut as f32 / 1000.0
        + W_PER_KFF * usage.ff as f32 / 1000.0
        + W_PER_BRAM * usage.bram36;
    STATIC_W + dynamic * f_scale
}

/// Fractional power saving of a narrower datapath vs a baseline at the
/// same clock — what `quant::sweep` reports per candidate width (the
/// dynamic term scales with the width-dependent resource usage; the
/// static floor is shared, so savings saturate below 1).
pub fn power_saving_fraction(base: &ResourceUsage, narrow: &ResourceUsage, clock_hz: f64) -> f32 {
    1.0 - fpga_power_w(narrow, clock_hz) / fpga_power_w(base, clock_hz)
}

/// Cortex-A9 (dual-core, 667 MHz) active power running the SW pipeline —
/// the paper measures 1.530 W processor power.
pub const CORTEX_A9_POWER_W: f32 = 1.530;

/// Energy in joules.
pub fn energy_j(power_w: f32, seconds: f64) -> f64 {
    f64::from(power_w) * seconds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn usage(lut: u32, ff: u32, dsp: u32, bram: f32) -> ResourceUsage {
        ResourceUsage {
            lut,
            ff,
            dsp,
            bram36: bram,
            ..Default::default()
        }
    }

    #[test]
    fn calibration_near_table9() {
        // the paper's standard design: 33,674 LUT / 49,596 FF / 143 DSP /
        // 26.5 BRAM at 100 MHz → 0.734 W
        let p = fpga_power_w(&usage(33_674, 49_596, 143, 26.5), 100e6);
        assert!((p - 0.734).abs() < 0.08, "standard {p}");
        // non-pipelined (Table 11): 22,680 / 31,953 / 121 → 0.704 W
        let p = fpga_power_w(&usage(22_680, 31_953, 121, 25.5), 100e6);
        assert!((p - 0.704).abs() < 0.08, "non-pipelined {p}");
        // inlined: 44,237 / 59,726 / 136 → 0.864 W
        let p = fpga_power_w(&usage(44_237, 59_726, 136, 27.5), 100e6);
        assert!((p - 0.864).abs() < 0.08, "inlined {p}");
    }

    #[test]
    fn power_monotone_in_resources_and_clock() {
        let small = fpga_power_w(&usage(10_000, 15_000, 50, 10.0), 100e6);
        let big = fpga_power_w(&usage(40_000, 60_000, 150, 30.0), 100e6);
        assert!(big > small);
        let fast = fpga_power_w(&usage(10_000, 15_000, 50, 10.0), 200e6);
        assert!(fast > small);
    }

    #[test]
    fn hw_beats_a9_by_about_2x_power() {
        let p = fpga_power_w(&usage(33_674, 49_596, 143, 26.5), 100e6);
        assert!(CORTEX_A9_POWER_W / p > 1.7);
    }

    #[test]
    fn energy_product() {
        assert_eq!(energy_j(2.0, 3.0), 6.0);
    }

    #[test]
    fn narrower_datapath_saves_power_but_not_the_static_floor() {
        let base = usage(33_674, 49_596, 143, 26.5);
        let narrow = usage(12_000, 18_000, 40, 14.0);
        let s = power_saving_fraction(&base, &narrow, 100e6);
        assert!(s > 0.0 && s < 1.0, "{s}");
        assert_eq!(power_saving_fraction(&base, &base, 100e6), 0.0);
    }
}
