//! FPGA resource model: the xc7z020 budget and the LUT/FF/DSP/BRAM cost
//! of the f32 operators and memories HLS instantiates.
//!
//! Operator costs follow Xilinx 7-series floating-point IP synthesis
//! (the same cores Vitis HLS 2021.1 instantiates at 100 MHz): an f32
//! adder ≈ 2 DSP + ~360 LUT, multiplier ≈ 3 DSP + ~130 LUT, divider and
//! square root are LUT-heavy iterative cores. BRAM is counted in 36 kb
//! blocks (the paper's unit; a half block counts 0.5).

/// Device budget (what 100% means in Tables 9/11).
#[derive(Clone, Copy, Debug)]
pub struct ResourceBudget {
    pub lut: u32,
    pub lutram: u32,
    pub ff: u32,
    /// 36 kb BRAM blocks
    pub bram36: f32,
    pub dsp: u32,
    pub bufg: u32,
}

/// Zynq-7000 xc7z020clg400-1 (Zedboard/Pynq-Z1 class), the paper's part.
pub const XC7Z020: ResourceBudget = ResourceBudget {
    lut: 53_200,
    lutram: 17_400,
    ff: 106_400,
    bram36: 140.0,
    dsp: 220,
    bufg: 32,
};

/// Aggregate usage of a module or a whole design.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceUsage {
    pub lut: u32,
    pub lutram: u32,
    pub ff: u32,
    pub bram36: f32,
    pub dsp: u32,
    pub bufg: u32,
}

impl ResourceUsage {
    pub fn add(&mut self, other: &ResourceUsage) {
        self.lut += other.lut;
        self.lutram += other.lutram;
        self.ff += other.ff;
        self.bram36 += other.bram36;
        self.dsp += other.dsp;
        self.bufg = self.bufg.max(other.bufg);
    }

    pub fn scaled(&self, n: u32) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut * n,
            lutram: self.lutram * n,
            ff: self.ff * n,
            bram36: self.bram36 * n as f32,
            dsp: self.dsp * n,
            bufg: self.bufg,
        }
    }

    /// Utilisation fractions against a budget (Tables 9/11 percentages).
    pub fn utilization(&self, b: &ResourceBudget) -> Utilization {
        Utilization {
            lut: self.lut as f32 / b.lut as f32,
            lutram: self.lutram as f32 / b.lutram as f32,
            ff: self.ff as f32 / b.ff as f32,
            bram36: self.bram36 / b.bram36,
            dsp: self.dsp as f32 / b.dsp as f32,
        }
    }

    pub fn fits(&self, b: &ResourceBudget) -> bool {
        let u = self.utilization(b);
        u.lut <= 1.0 && u.lutram <= 1.0 && u.ff <= 1.0 && u.bram36 <= 1.0 && u.dsp <= 1.0
    }
}

/// Utilisation fractions.
#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub lut: f32,
    pub lutram: f32,
    pub ff: f32,
    pub bram36: f32,
    pub dsp: f32,
}

/// f32 operator cores (per parallel instance).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FpOp {
    Add,
    Mul,
    Div,
    Sqrt,
    /// fused compare/select & control (cheap)
    Cmp,
}

impl FpOp {
    /// Synthesis cost of one pipelined instance.
    pub fn cost(self) -> ResourceUsage {
        match self {
            FpOp::Add => ResourceUsage {
                lut: 360,
                ff: 400,
                dsp: 2,
                ..Default::default()
            },
            FpOp::Mul => ResourceUsage {
                lut: 130,
                ff: 150,
                dsp: 3,
                ..Default::default()
            },
            FpOp::Div => ResourceUsage {
                lut: 780,
                ff: 1_450,
                dsp: 0,
                ..Default::default()
            },
            FpOp::Sqrt => ResourceUsage {
                lut: 420,
                ff: 820,
                dsp: 0,
                ..Default::default()
            },
            FpOp::Cmp => ResourceUsage {
                lut: 70,
                ff: 90,
                dsp: 0,
                ..Default::default()
            },
        }
    }

    /// Pipeline latency in cycles at 100 MHz (7-series FP IP defaults).
    pub fn latency(self) -> u32 {
        match self {
            // 4-stage adder (medium-latency 7-series FP config at
            // 100 MHz) — chosen so RegSize=4 legalises II=1, which is
            // what the paper reports achieving with its write buffer
            FpOp::Add => 4,
            FpOp::Mul => 4,
            FpOp::Div => 28,
            FpOp::Sqrt => 28,
            FpOp::Cmp => 1,
        }
    }
}

/// BRAM blocks needed for `words` f32 words (36 kb block = 1024 words,
/// used in true-dual-port 18 kb halves like HLS does → count halves).
pub fn bram_for_words(words: usize) -> f32 {
    // one 18 kb half holds 512 f32 words
    let halves = words.div_ceil(512);
    halves as f32 * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_xc7z020() {
        assert_eq!(XC7Z020.lut, 53_200);
        assert_eq!(XC7Z020.dsp, 220);
        assert_eq!(XC7Z020.bram36, 140.0);
    }

    #[test]
    fn usage_accumulates() {
        let mut u = ResourceUsage::default();
        u.add(&FpOp::Add.cost());
        u.add(&FpOp::Mul.cost());
        assert_eq!(u.dsp, 5);
        assert_eq!(u.lut, 490);
    }

    #[test]
    fn utilization_fractions() {
        let u = ResourceUsage {
            lut: 26_600,
            dsp: 110,
            ..Default::default()
        };
        let f = u.utilization(&XC7Z020);
        assert!((f.lut - 0.5).abs() < 1e-6);
        assert!((f.dsp - 0.5).abs() < 1e-6);
        assert!(u.fits(&XC7Z020));
    }

    #[test]
    fn overbudget_detected() {
        let u = ResourceUsage {
            dsp: 221,
            ..Default::default()
        };
        assert!(!u.fits(&XC7Z020));
    }

    #[test]
    fn bram_sizing() {
        assert_eq!(bram_for_words(0), 0.0);
        assert_eq!(bram_for_words(512), 0.5);
        assert_eq!(bram_for_words(513), 1.0);
        assert_eq!(bram_for_words(1024), 1.0);
        // packed B for Nx=30: s(s+1)/2 = 433,846 words → ~424 blocks
        // (exceeds the chip: the design must keep it in DDR; the paper's
        // 26.5 BRAM confirms the ridge arrays are partially streamed)
        assert!(bram_for_words(433_846) > 140.0);
    }

    #[test]
    fn div_sqrt_are_lut_heavy_not_dsp() {
        assert_eq!(FpOp::Div.cost().dsp, 0);
        assert!(FpOp::Div.cost().lut > FpOp::Mul.cost().lut);
        assert!(FpOp::Sqrt.latency() > FpOp::Mul.latency());
    }
}
