//! FPGA resource model: the xc7z020 budget and the LUT/FF/DSP/BRAM cost
//! of the f32 operators and memories HLS instantiates.
//!
//! Operator costs follow Xilinx 7-series floating-point IP synthesis
//! (the same cores Vitis HLS 2021.1 instantiates at 100 MHz): an f32
//! adder ≈ 2 DSP + ~360 LUT, multiplier ≈ 3 DSP + ~130 LUT, divider and
//! square root are LUT-heavy iterative cores. BRAM is counted in 36 kb
//! blocks (the paper's unit; a half block counts 0.5).

/// Device budget (what 100% means in Tables 9/11).
#[derive(Clone, Copy, Debug)]
pub struct ResourceBudget {
    pub lut: u32,
    pub lutram: u32,
    pub ff: u32,
    /// 36 kb BRAM blocks
    pub bram36: f32,
    pub dsp: u32,
    pub bufg: u32,
}

/// Zynq-7000 xc7z020clg400-1 (Zedboard/Pynq-Z1 class), the paper's part.
pub const XC7Z020: ResourceBudget = ResourceBudget {
    lut: 53_200,
    lutram: 17_400,
    ff: 106_400,
    bram36: 140.0,
    dsp: 220,
    bufg: 32,
};

/// Aggregate usage of a module or a whole design.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceUsage {
    pub lut: u32,
    pub lutram: u32,
    pub ff: u32,
    pub bram36: f32,
    pub dsp: u32,
    pub bufg: u32,
}

impl ResourceUsage {
    pub fn add(&mut self, other: &ResourceUsage) {
        self.lut += other.lut;
        self.lutram += other.lutram;
        self.ff += other.ff;
        self.bram36 += other.bram36;
        self.dsp += other.dsp;
        self.bufg = self.bufg.max(other.bufg);
    }

    pub fn scaled(&self, n: u32) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut * n,
            lutram: self.lutram * n,
            ff: self.ff * n,
            bram36: self.bram36 * n as f32,
            dsp: self.dsp * n,
            bufg: self.bufg,
        }
    }

    /// Utilisation fractions against a budget (Tables 9/11 percentages).
    pub fn utilization(&self, b: &ResourceBudget) -> Utilization {
        Utilization {
            lut: self.lut as f32 / b.lut as f32,
            lutram: self.lutram as f32 / b.lutram as f32,
            ff: self.ff as f32 / b.ff as f32,
            bram36: self.bram36 / b.bram36,
            dsp: self.dsp as f32 / b.dsp as f32,
        }
    }

    pub fn fits(&self, b: &ResourceBudget) -> bool {
        let u = self.utilization(b);
        u.lut <= 1.0 && u.lutram <= 1.0 && u.ff <= 1.0 && u.bram36 <= 1.0 && u.dsp <= 1.0
    }
}

/// Utilisation fractions.
#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub lut: f32,
    pub lutram: f32,
    pub ff: f32,
    pub bram36: f32,
    pub dsp: f32,
}

/// Datapath arithmetic: the f32 IP cores the seed model assumed, or a
/// W-bit fixed-point word (`quant::QFormat::bits`) — what the paper's
/// actual FPGA datapath uses and what `quant::sweep` selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arith {
    F32,
    /// two's-complement fixed point, `bits` total width
    Fixed { bits: u32 },
}

impl Arith {
    pub fn bits(self) -> u32 {
        match self {
            Arith::F32 => 32,
            Arith::Fixed { bits } => bits,
        }
    }

    pub fn name(self) -> String {
        match self {
            Arith::F32 => "f32".to_string(),
            Arith::Fixed { bits } => format!("fx{bits}"),
        }
    }
}

/// Operator cores (per parallel instance). Costs/latencies depend on the
/// datapath [`Arith`]; the argument-less accessors keep the seed model's
/// f32 numbers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FpOp {
    Add,
    Mul,
    Div,
    Sqrt,
    /// fused compare/select & control (cheap)
    Cmp,
}

impl FpOp {
    /// Synthesis cost of one pipelined f32 instance.
    pub fn cost(self) -> ResourceUsage {
        self.cost_arith(Arith::F32)
    }

    /// Synthesis cost of one pipelined instance on the given datapath.
    ///
    /// Fixed-point numbers follow 7-series synthesis practice: an add is
    /// a W-bit carry chain (no DSP), a W×W multiply maps onto DSP48E1
    /// slices (25×18 — one slice up to 18 bits, two to 25, four beyond),
    /// and div/sqrt are W-stage non-restoring arrays whose area grows
    /// ~W² (still far below the iterative f32 cores at W ≤ 18).
    pub fn cost_arith(self, a: Arith) -> ResourceUsage {
        if let Arith::Fixed { bits } = a {
            let w = bits;
            return match self {
                FpOp::Add => ResourceUsage {
                    lut: w,
                    ff: w,
                    dsp: 0,
                    ..Default::default()
                },
                FpOp::Mul => ResourceUsage {
                    lut: 30,
                    ff: w,
                    dsp: if w <= 18 {
                        1
                    } else if w <= 25 {
                        2
                    } else {
                        4
                    },
                    ..Default::default()
                },
                FpOp::Div => ResourceUsage {
                    lut: w * w / 2,
                    ff: w * w / 2,
                    dsp: 0,
                    ..Default::default()
                },
                FpOp::Sqrt => ResourceUsage {
                    lut: w * w / 4,
                    ff: w * w / 4,
                    dsp: 0,
                    ..Default::default()
                },
                FpOp::Cmp => ResourceUsage {
                    lut: w / 2 + 4,
                    ff: w / 2,
                    dsp: 0,
                    ..Default::default()
                },
            };
        }
        match self {
            FpOp::Add => ResourceUsage {
                lut: 360,
                ff: 400,
                dsp: 2,
                ..Default::default()
            },
            FpOp::Mul => ResourceUsage {
                lut: 130,
                ff: 150,
                dsp: 3,
                ..Default::default()
            },
            FpOp::Div => ResourceUsage {
                lut: 780,
                ff: 1_450,
                dsp: 0,
                ..Default::default()
            },
            FpOp::Sqrt => ResourceUsage {
                lut: 420,
                ff: 820,
                dsp: 0,
                ..Default::default()
            },
            FpOp::Cmp => ResourceUsage {
                lut: 70,
                ff: 90,
                dsp: 0,
                ..Default::default()
            },
        }
    }

    /// Pipeline latency in cycles at 100 MHz (7-series FP IP defaults).
    pub fn latency(self) -> u32 {
        self.latency_arith(Arith::F32)
    }

    /// Latency on the given datapath. Fixed-point adds close in one
    /// cycle (this is what collapses the read-modify-write II that the
    /// paper's Algorithm-5 write buffer exists to hide — see
    /// `schedule::accumulation_ii_arith`); multiplies take the DSP48
    /// pipeline, div/sqrt one cycle per result bit.
    pub fn latency_arith(self, a: Arith) -> u32 {
        if let Arith::Fixed { bits } = a {
            return match self {
                FpOp::Add | FpOp::Cmp => 1,
                FpOp::Mul => {
                    if bits <= 18 {
                        3
                    } else {
                        4
                    }
                }
                FpOp::Div => bits + 3,
                FpOp::Sqrt => bits / 2 + 3,
            };
        }
        match self {
            // 4-stage adder (medium-latency 7-series FP config at
            // 100 MHz) — chosen so RegSize=4 legalises II=1, which is
            // what the paper reports achieving with its write buffer
            FpOp::Add => 4,
            FpOp::Mul => 4,
            FpOp::Div => 28,
            FpOp::Sqrt => 28,
            FpOp::Cmp => 1,
        }
    }
}

/// BRAM blocks needed for `words` f32 words (36 kb block = 1024 words,
/// used in true-dual-port 18 kb halves like HLS does → count halves).
pub fn bram_for_words(words: usize) -> f32 {
    bram_for_words_arith(words, Arith::F32)
}

/// BRAM blocks for `words` datapath words of the given [`Arith`]. A
/// 7-series 18 kb half provides 18 432 bits in 9-bit parity lanes, so a
/// word occupies its width rounded up to a multiple of 9: 512 f32 words
/// per half (32→36 bits), 1024 16-bit words (16→18), 2048 8-bit words —
/// narrower datapaths halve the memory footprint alongside the logic.
pub fn bram_for_words_arith(words: usize, a: Arith) -> f32 {
    if words == 0 {
        return 0.0;
    }
    let phys_bits = a.bits().div_ceil(9).max(1) * 9;
    let words_per_half = (18_432 / phys_bits).max(1) as usize;
    let halves = words.div_ceil(words_per_half);
    halves as f32 * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_xc7z020() {
        assert_eq!(XC7Z020.lut, 53_200);
        assert_eq!(XC7Z020.dsp, 220);
        assert_eq!(XC7Z020.bram36, 140.0);
    }

    #[test]
    fn usage_accumulates() {
        let mut u = ResourceUsage::default();
        u.add(&FpOp::Add.cost());
        u.add(&FpOp::Mul.cost());
        assert_eq!(u.dsp, 5);
        assert_eq!(u.lut, 490);
    }

    #[test]
    fn utilization_fractions() {
        let u = ResourceUsage {
            lut: 26_600,
            dsp: 110,
            ..Default::default()
        };
        let f = u.utilization(&XC7Z020);
        assert!((f.lut - 0.5).abs() < 1e-6);
        assert!((f.dsp - 0.5).abs() < 1e-6);
        assert!(u.fits(&XC7Z020));
    }

    #[test]
    fn overbudget_detected() {
        let u = ResourceUsage {
            dsp: 221,
            ..Default::default()
        };
        assert!(!u.fits(&XC7Z020));
    }

    #[test]
    fn bram_sizing() {
        assert_eq!(bram_for_words(0), 0.0);
        assert_eq!(bram_for_words(512), 0.5);
        assert_eq!(bram_for_words(513), 1.0);
        assert_eq!(bram_for_words(1024), 1.0);
        // packed B for Nx=30: s(s+1)/2 = 433,846 words → ~424 blocks
        // (exceeds the chip: the design must keep it in DDR; the paper's
        // 26.5 BRAM confirms the ridge arrays are partially streamed)
        assert!(bram_for_words(433_846) > 140.0);
    }

    #[test]
    fn div_sqrt_are_lut_heavy_not_dsp() {
        assert_eq!(FpOp::Div.cost().dsp, 0);
        assert!(FpOp::Div.cost().lut > FpOp::Mul.cost().lut);
        assert!(FpOp::Sqrt.latency() > FpOp::Mul.latency());
    }

    #[test]
    fn fixed_point_is_cheaper_than_f32_at_16_bits() {
        let fx = Arith::Fixed { bits: 16 };
        for op in [FpOp::Add, FpOp::Mul, FpOp::Div, FpOp::Sqrt, FpOp::Cmp] {
            let f = op.cost_arith(Arith::F32);
            let q = op.cost_arith(fx);
            assert!(q.lut <= f.lut, "{op:?} lut {} vs {}", q.lut, f.lut);
            assert!(q.dsp <= f.dsp, "{op:?} dsp");
            assert!(op.latency_arith(fx) <= op.latency_arith(Arith::F32), "{op:?}");
        }
        // the add is a 1-cycle carry chain: no RMW recurrence to buffer
        assert_eq!(FpOp::Add.latency_arith(fx), 1);
        assert_eq!(FpOp::Mul.cost_arith(fx).dsp, 1);
        // width scaling of the multiplier's DSP mapping
        assert_eq!(FpOp::Mul.cost_arith(Arith::Fixed { bits: 24 }).dsp, 2);
        assert_eq!(FpOp::Mul.cost_arith(Arith::Fixed { bits: 32 }).dsp, 4);
    }

    #[test]
    fn arith_names_and_bits() {
        assert_eq!(Arith::F32.bits(), 32);
        assert_eq!(Arith::Fixed { bits: 16 }.name(), "fx16");
        assert_eq!(Arith::F32.name(), "f32");
    }

    #[test]
    fn bram_width_scaling() {
        // f32 path unchanged
        assert_eq!(bram_for_words_arith(512, Arith::F32), bram_for_words(512));
        // 16-bit words pack 2x denser (18-bit parity lanes)
        assert_eq!(bram_for_words_arith(1024, Arith::Fixed { bits: 16 }), 0.5);
        assert_eq!(bram_for_words_arith(1025, Arith::Fixed { bits: 16 }), 1.0);
        // 8-bit words 4x denser
        assert_eq!(bram_for_words_arith(2048, Arith::Fixed { bits: 8 }), 0.5);
        assert_eq!(bram_for_words_arith(0, Arith::Fixed { bits: 16 }), 0.0);
    }
}
