//! Whole-design assembly: the three synthesis configurations of
//! Tables 9/11, per-module resources (Table 10), and the Cortex-A9
//! software reference — producing the rows the benches print.
//!
//! Module datapaths are counted in operator instances (a pipelined II=1
//! loop needs one instance of each body operator; the RegSize-deep write
//! buffer of Algorithm 5 instantiates RegSize parallel MACs). Instance
//! counts reproduce Table 10's DSP numbers exactly (DFR core 15, bp 57,
//! ridge 20); LUT/FF control overheads are calibrated to the same table.
//!
//! The software reference models the paper's "SW only" row: the same
//! C++ pipeline executed by the dual-core Cortex-A9 at 667 MHz. Its
//! effective throughput (flops/cycle) is calibrated so the paper's
//! measured 13×/27× time/power gaps emerge from the model rather than
//! being asserted (their baseline was scalar, unvectorised HLS C++).

use super::power::{energy_j, fpga_power_w, CORTEX_A9_POWER_W};
use super::resource::{bram_for_words_arith, Arith, FpOp, ResourceBudget, ResourceUsage, XC7Z020};
use super::schedule::{
    infer_cycles, ridge_accumulate_cycles, ridge_solve_cycles, train_step_cycles,
    ScheduleConfig, ShapeParams,
};

/// One HLS module: operator instances + control/interface overhead.
#[derive(Clone, Debug)]
pub struct Module {
    pub name: &'static str,
    pub ops: Vec<(FpOp, u32)>,
    pub control_lut: u32,
    pub control_ff: u32,
    pub bram_words: usize,
}

impl Module {
    pub fn resources(&self) -> ResourceUsage {
        self.resources_arith(Arith::F32)
    }

    /// Module resources on the given datapath: operator cores swap for
    /// their width-scaled variants and the BRAM word storage packs
    /// denser; the control/interface overhead (state machines, AXI) is
    /// width-independent and carries over unchanged.
    pub fn resources_arith(&self, a: Arith) -> ResourceUsage {
        let mut u = ResourceUsage {
            lut: self.control_lut,
            ff: self.control_ff,
            bram36: bram_for_words_arith(self.bram_words, a),
            ..Default::default()
        };
        for (op, n) in &self.ops {
            u.add(&op.cost_arith(a).scaled(*n));
        }
        u
    }
}

/// Synthesis configuration (the Table 11 axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DesignConfig {
    /// pipelined, RegSize=4 write buffer, shared state-update module —
    /// the paper's main design (Table 9 "HW only")
    Standard,
    /// minimal area: no pipelining, no write buffer
    NonPipelined,
    /// pipelined + state update expanded inline (fastest, most area)
    Inlined,
}

impl DesignConfig {
    pub fn schedule(self) -> ScheduleConfig {
        match self {
            DesignConfig::Standard => ScheduleConfig {
                pipelined: true,
                reg_size: 4,
                inline_state_update: false,
            },
            DesignConfig::NonPipelined => ScheduleConfig {
                pipelined: false,
                reg_size: 1,
                inline_state_update: false,
            },
            DesignConfig::Inlined => ScheduleConfig {
                pipelined: true,
                reg_size: 4,
                inline_state_update: true,
            },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DesignConfig::Standard => "standard",
            DesignConfig::NonPipelined => "non-pipelined",
            DesignConfig::Inlined => "inlined",
        }
    }
}

/// The whole system model for one dataset shape.
pub struct SystemModel {
    pub shape: ShapeParams,
    pub config: DesignConfig,
    pub clock_hz: f64,
    /// datapath word ([`Arith::F32`] keeps the seed model's numbers; a
    /// `quant::sweep`-chosen fixed-point width makes Tables 9/11
    /// width-aware)
    pub arith: Arith,
}

impl SystemModel {
    pub fn new(shape: ShapeParams, config: DesignConfig) -> Self {
        Self::with_arith(shape, config, Arith::F32)
    }

    /// Model the same design on a different datapath word — resources
    /// and power scale with width; the cycle schedule stays the paper's
    /// (conservative for fixed point, whose 1-cycle adds would also lift
    /// the RMW-limited IIs — see `schedule::accumulation_ii_arith`).
    pub fn with_arith(shape: ShapeParams, config: DesignConfig, arith: Arith) -> Self {
        SystemModel {
            shape,
            config,
            clock_hz: 100e6, // the paper's achieved clock
            arith,
        }
    }

    /// Per-module breakdown (Table 10). Instance counts chosen per the
    /// module's pipelined loops; the inlined config duplicates the
    /// state-update datapath inside the DFR core.
    pub fn modules(&self) -> Vec<Module> {
        let inline_dup = if self.config == DesignConfig::Inlined {
            1
        } else {
            0
        };
        let reg = self.config.schedule().reg_size;
        let s = self.shape.s as usize;
        vec![
            Module {
                // masking + node cascade + state buffers
                name: "dfr_core",
                ops: vec![(FpOp::Mul, 3 + 2 * inline_dup), (FpOp::Add, 3 + 2 * inline_dup)],
                control_lut: 7_294 + 8_000 * inline_dup,
                control_ff: 9_616 + 8_600 * inline_dup,
                bram_words: 3 * self.shape.nx as usize
                    + self.shape.nx as usize * self.shape.v as usize,
            },
            Module {
                // output-layer grads, bpv, reverse cascade, dp/dq reduce
                name: "backpropagation",
                ops: vec![(FpOp::Mul, 9), (FpOp::Add, 15)],
                control_lut: 5_675,
                control_ff: 2_775,
                bram_words: self.shape.nx as usize * (self.shape.nx as usize + 1)
                    + 2 * self.shape.nx as usize,
            },
            Module {
                // Algorithms 2+5: RegSize parallel MACs + div + sqrt
                name: "ridge_regression",
                ops: vec![(FpOp::Mul, reg), (FpOp::Add, reg), (FpOp::Div, 1), (FpOp::Sqrt, 1)],
                control_lut: 4_667,
                control_ff: 3_758,
                // the packed triangle does not fit BRAM (s(s+1)/2 words);
                // on-chip only the working row/column set + Q
                bram_words: 4 * s + self.shape.ny as usize * s,
            },
            Module {
                // DPRR accumulate + AXI/DMA + top-level control
                name: "dprr_and_io",
                ops: vec![(FpOp::Mul, 6), (FpOp::Add, 6), (FpOp::Cmp, 8)],
                control_lut: 8_000,
                control_ff: 12_000,
                bram_words: 2 * self.shape.nx as usize * (self.shape.nx as usize + 1),
            },
        ]
    }

    pub fn total_resources(&self) -> ResourceUsage {
        let mut u = ResourceUsage {
            bufg: 1,
            lutram: match self.config {
                DesignConfig::Standard => 1_073,
                DesignConfig::NonPipelined => 755,
                DesignConfig::Inlined => 884,
            },
            ..Default::default()
        };
        for m in self.modules() {
            u.add(&m.resources_arith(self.arith));
        }
        u
    }

    /// Seconds to train online: `epochs` truncated-BP passes over
    /// `n_train` samples, then ridge accumulate + β-swept solves.
    pub fn training_seconds(&self, n_train: u64, epochs: u64, n_betas: u64) -> f64 {
        let cfg = self.config.schedule();
        let bp = epochs * n_train * train_step_cycles(&self.shape, &cfg);
        let acc = n_train * ridge_accumulate_cycles(&self.shape, &cfg);
        let solve = n_betas * ridge_solve_cycles(&self.shape, &cfg);
        (bp + acc + solve) as f64 / self.clock_hz
    }

    /// Seconds to run inference over `n_test` samples.
    pub fn inference_seconds(&self, n_test: u64) -> f64 {
        let cfg = self.config.schedule();
        (n_test * infer_cycles(&self.shape, &cfg)) as f64 / self.clock_hz
    }

    pub fn power_w(&self) -> f32 {
        fpga_power_w(&self.total_resources(), self.clock_hz)
    }

    /// Full Table 9/11-style report for a workload.
    pub fn report(&self, n_train: u64, epochs: u64, n_betas: u64, n_test: u64) -> DesignReport {
        let train_s = self.training_seconds(n_train, epochs, n_betas);
        let infer_s = self.inference_seconds(n_test);
        let power = self.power_w();
        DesignReport {
            name: self.config.name(),
            resources: self.total_resources(),
            budget: XC7Z020,
            clock_hz: self.clock_hz,
            train_s,
            infer_s,
            power_w: power,
            energy_j: energy_j(power, train_s + infer_s),
        }
    }
}

/// One row of Table 9/11.
#[derive(Clone, Debug)]
pub struct DesignReport {
    pub name: &'static str,
    pub resources: ResourceUsage,
    pub budget: ResourceBudget,
    pub clock_hz: f64,
    pub train_s: f64,
    pub infer_s: f64,
    pub power_w: f32,
    pub energy_j: f64,
}

impl DesignReport {
    pub fn calc_s(&self) -> f64 {
        self.train_s + self.infer_s
    }
}

// ---------------------------------------------------------------------------
// Cortex-A9 software reference
// ---------------------------------------------------------------------------

/// A9 clock on the Zynq PS.
pub const A9_CLOCK_HZ: f64 = 667e6;

/// Effective f32 operations per cycle of the paper's scalar C++ baseline
/// on the A9 (unvectorised VFP with load/store and call overhead;
/// calibrated so Table 9's measured 13× HW/SW gap emerges).
pub const A9_FLOPS_PER_CYCLE: f64 = 0.08;

/// Software time for the same workload from flop counts.
pub fn sw_training_seconds(shape: &ShapeParams, n_train: u64, epochs: u64, n_betas: u64) -> f64 {
    let flops = epochs * n_train * train_step_flops(shape)
        + n_train * (shape.s * (shape.s + 1) + 2 * shape.s)
        + n_betas * ridge_solve_flops(shape);
    flops as f64 / (A9_CLOCK_HZ * A9_FLOPS_PER_CYCLE)
}

pub fn sw_inference_seconds(shape: &ShapeParams, n_test: u64) -> f64 {
    let flops = n_test * (forward_flops(shape) + 2 * shape.ny * shape.s);
    flops as f64 / (A9_CLOCK_HZ * A9_FLOPS_PER_CYCLE)
}

fn forward_flops(s: &ShapeParams) -> u64 {
    // mask matvec + cascade + DPRR rank-1, per time step
    s.t * (2 * s.nx * s.v + 4 * s.nx + 2 * s.nx * (s.nx + 1))
}

fn train_step_flops(s: &ShapeParams) -> u64 {
    let nr = s.nx * (s.nx + 1);
    forward_flops(s) + 6 * s.ny * nr + 2 * nr + 4 * s.nx
}

fn ridge_solve_flops(s: &ShapeParams) -> u64 {
    let ops = crate::linalg::counters::ops_proposed(s.s, s.ny);
    ops.add + ops.mul + 8 * (ops.div + ops.sqrt)
}

/// The complete SW-only row of Table 9.
pub fn sw_report(shape: &ShapeParams, n_train: u64, epochs: u64, n_betas: u64, n_test: u64) -> SwReport {
    let train_s = sw_training_seconds(shape, n_train, epochs, n_betas);
    let infer_s = sw_inference_seconds(shape, n_test);
    SwReport {
        clock_hz: A9_CLOCK_HZ,
        train_s,
        infer_s,
        power_w: CORTEX_A9_POWER_W,
        energy_j: energy_j(CORTEX_A9_POWER_W, train_s + infer_s),
    }
}

/// SW-only row.
#[derive(Clone, Debug)]
pub struct SwReport {
    pub clock_hz: f64,
    pub train_s: f64,
    pub infer_s: f64,
    pub power_w: f32,
    pub energy_j: f64,
}

impl SwReport {
    pub fn calc_s(&self) -> f64 {
        self.train_s + self.infer_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jpvow() -> ShapeParams {
        ShapeParams::new(30, 12, 9, 29)
    }

    #[test]
    fn table10_dsp_counts_exact() {
        let m = SystemModel::new(jpvow(), DesignConfig::Standard);
        let mods = m.modules();
        let dsp = |name: &str| {
            mods.iter()
                .find(|m| m.name == name)
                .unwrap()
                .resources()
                .dsp
        };
        assert_eq!(dsp("dfr_core"), 15); // Table 10
        assert_eq!(dsp("backpropagation"), 57); // Table 10
        assert_eq!(dsp("ridge_regression"), 20); // Table 10
    }

    #[test]
    fn table10_lut_ff_within_band() {
        let m = SystemModel::new(jpvow(), DesignConfig::Standard);
        for (name, lut, ff) in [
            ("dfr_core", 8_764u32, 11_266u32),
            ("backpropagation", 12_245, 10_125),
            ("ridge_regression", 7_827, 8_228),
        ] {
            let r = m
                .modules()
                .into_iter()
                .find(|mm| mm.name == name)
                .unwrap()
                .resources();
            let rel = |a: u32, b: u32| (a as f32 - b as f32).abs() / b as f32;
            assert!(rel(r.lut, lut) < 0.15, "{name} lut {} vs {lut}", r.lut);
            assert!(rel(r.ff, ff) < 0.25, "{name} ff {} vs {ff}", r.ff);
        }
    }

    #[test]
    fn whole_design_fits_and_tracks_table9() {
        let m = SystemModel::new(jpvow(), DesignConfig::Standard);
        let r = m.total_resources();
        assert!(r.fits(&XC7Z020), "{r:?}");
        // Table 9: 33,674 LUT (63.2%), 143 DSP (65%)
        let rel = |a: f32, b: f32| (a - b).abs() / b;
        assert!(rel(r.lut as f32, 33_674.0) < 0.2, "lut {}", r.lut);
        assert!(rel(r.dsp as f32, 143.0) < 0.2, "dsp {}", r.dsp);
    }

    #[test]
    fn config_ordering_matches_table11() {
        // area: non-pipelined < standard < inlined
        // speed: inlined < standard < non-pipelined (calc time)
        let shape = jpvow();
        let rep = |c: DesignConfig| SystemModel::new(shape, c).report(270, 25, 4, 370);
        let std_ = rep(DesignConfig::Standard);
        let nop = rep(DesignConfig::NonPipelined);
        let inl = rep(DesignConfig::Inlined);
        assert!(nop.resources.lut < std_.resources.lut);
        assert!(std_.resources.lut < inl.resources.lut);
        assert!(inl.calc_s() < std_.calc_s());
        assert!(std_.calc_s() < nop.calc_s());
        // power: non-pipelined < standard < inlined (Table 11)
        assert!(nop.power_w < std_.power_w);
        assert!(std_.power_w < inl.power_w);
    }

    #[test]
    fn hw_vs_sw_ratios_match_paper_shape() {
        // Table 9: computation ≈ 13× faster, power ≈ 2× lower,
        // energy ≈ 27× lower on HW
        let shape = jpvow();
        let hw = SystemModel::new(shape, DesignConfig::Standard).report(270, 25, 4, 370);
        let sw = sw_report(&shape, 270, 25, 4, 370);
        let t_ratio = sw.calc_s() / hw.calc_s();
        let e_ratio = sw.energy_j / hw.energy_j;
        assert!(
            (6.0..=30.0).contains(&t_ratio),
            "time ratio {t_ratio} (paper ~13)"
        );
        assert!(
            (12.0..=60.0).contains(&e_ratio),
            "energy ratio {e_ratio} (paper ~27)"
        );
    }

    #[test]
    fn power_in_paper_band() {
        let p = SystemModel::new(jpvow(), DesignConfig::Standard).power_w();
        assert!((0.5..=1.1).contains(&p), "{p}");
    }

    #[test]
    fn fixed_point_datapath_shrinks_resources_and_power() {
        let shape = jpvow();
        let f32_m = SystemModel::new(shape, DesignConfig::Standard);
        let fx16 = SystemModel::with_arith(
            shape,
            DesignConfig::Standard,
            Arith::Fixed { bits: 16 },
        );
        let rf = f32_m.total_resources();
        let rq = fx16.total_resources();
        assert!(rq.lut < rf.lut, "lut {} vs {}", rq.lut, rf.lut);
        assert!(rq.dsp < rf.dsp, "dsp {} vs {}", rq.dsp, rf.dsp);
        assert!(rq.bram36 <= rf.bram36, "bram {} vs {}", rq.bram36, rf.bram36);
        assert!(fx16.power_w() < f32_m.power_w());
        assert!(rq.fits(&XC7Z020));
        // timing model unchanged (schedule is width-agnostic here)
        assert_eq!(
            f32_m.training_seconds(270, 25, 4),
            fx16.training_seconds(270, 25, 4)
        );
        // widening back to 32-bit fixed point costs more than 16-bit
        let fx32 = SystemModel::with_arith(
            shape,
            DesignConfig::Standard,
            Arith::Fixed { bits: 32 },
        );
        assert!(fx32.total_resources().dsp > rq.dsp);
    }
}
