//! Leveled logger writing to stderr, controlled by `DFR_LOG`
//! (error|warn|info|debug|trace; default info — an unrecognized value
//! falls back to info with a one-time WARN naming it).
//!
//! Tests can install a capture sink ([`set_test_sink`]) that receives
//! every formatted line in addition to stderr, so structured operational
//! lines (e.g. the tracer's slow-request breakdowns) are assertable.

use std::io::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

/// Capture sink for tests: receives every formatted log line that passes
/// the level filter. Cold in production (a single relaxed-ordering load
/// guards the lock).
pub type Sink = Box<dyn Fn(Level, &str) + Send + 'static>;
static SINK: Mutex<Option<Sink>> = Mutex::new(None);
static SINK_SET: AtomicU8 = AtomicU8::new(0);

fn level_from_env() -> u8 {
    match std::env::var("DFR_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("info") => 2,
        Ok("debug") => 3,
        Ok("trace") => 4,
        Err(_) => 2,
        Ok(other) => {
            // default BEFORE warning so the warning itself passes the
            // level filter without re-entering initialization
            LEVEL.store(2, Ordering::Relaxed);
            log(
                Level::Warn,
                module_path!(),
                format_args!("unrecognized DFR_LOG value {other:?}; defaulting to info"),
            );
            2
        }
    }
}

/// Current log level (lazily initialized from the environment).
pub fn level() -> Level {
    let mut l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        l = level_from_env();
        LEVEL.store(l, Ordering::Relaxed);
    }
    match l {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Install (or clear, with `None`) a capture sink that receives every
/// formatted line passing the level filter. Intended for tests asserting
/// on operational output; lines still go to stderr as usual.
pub fn set_test_sink(sink: Option<Sink>) {
    SINK_SET.store(sink.is_some() as u8, Ordering::Release);
    if let Ok(mut s) = SINK.lock() {
        *s = sink;
    }
}

/// Core log call — prefer the macros.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if l <= level() {
        if SINK_SET.load(Ordering::Acquire) != 0 {
            if let Ok(s) = SINK.lock() {
                if let Some(sink) = s.as_ref() {
                    sink(l, &format!("[{} {}] {}", l.tag(), module, msg));
                }
            }
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{} {}] {}", l.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex as StdMutex};

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
    }

    #[test]
    fn test_sink_captures_formatted_lines() {
        set_level(Level::Info);
        let captured: Arc<StdMutex<Vec<String>>> = Arc::default();
        let c = captured.clone();
        set_test_sink(Some(Box::new(move |_, line| {
            c.lock().unwrap().push(line.to_string());
        })));
        log(Level::Info, "mod", format_args!("hello {}", 42));
        // below the filter: must not reach the sink
        log(Level::Debug, "mod", format_args!("invisible"));
        set_test_sink(None);
        // after clearing, nothing more is captured
        log(Level::Info, "mod", format_args!("late"));
        let lines = captured.lock().unwrap();
        assert_eq!(lines.len(), 1, "{lines:?}");
        assert!(lines[0].contains("[INFO  mod] hello 42"), "{lines:?}");
    }
}
