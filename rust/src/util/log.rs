//! Leveled logger writing to stderr, controlled by `DFR_LOG`
//! (error|warn|info|debug|trace; default info).

use std::io::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level_from_env() -> u8 {
    match std::env::var("DFR_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    }
}

/// Current log level (lazily initialized from the environment).
pub fn level() -> Level {
    let mut l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        l = level_from_env();
        LEVEL.store(l, Ordering::Relaxed);
    }
    match l {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Core log call — prefer the macros.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if l <= level() {
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{} {}] {}", l.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
    }
}
