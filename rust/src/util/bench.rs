//! Micro/macro benchmark harness (no criterion in the image).
//!
//! Used by every `rust/benches/*.rs` target (`harness = false`). Provides
//! warm-up, adaptive iteration counts, robust statistics (median + MAD),
//! and CSV/markdown emission into `results/`.
//!
//! Concurrency benches (e.g. `coordinator_throughput`) that measure
//! many-threaded request latency rather than a repeatable closure record
//! client-side into [`crate::util::metrics::Histogram`]s, merge the
//! snapshots, and emit through [`markdown_table`] /
//! [`write_results_file`] here.

use std::fmt::Write as _;
use std::fs;
use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

pub use std::hint::black_box as bb;

/// Statistics of one benchmark in seconds.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub mad: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median
    }
}

/// Benchmark runner with adaptive iteration count.
pub struct Bencher {
    /// target wall time per benchmark (seconds)
    pub target_time: f64,
    /// max samples collected
    pub max_samples: usize,
    /// suppress the per-bench println (table-style benches)
    pub quiet: bool,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            target_time: 0.6,
            max_samples: 61,
            quiet: false,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_target_time(secs: f64) -> Self {
        Bencher {
            target_time: secs,
            ..Default::default()
        }
    }

    /// Benchmark `f`, printing and recording the stats.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // estimate cost with a single call
        let t0 = Instant::now();
        black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);

        // choose per-sample iterations so one sample is ~target/samples
        let samples = self.max_samples.min(((self.target_time / once) as usize).max(1));
        let iters_per_sample =
            ((self.target_time / samples as f64 / once).ceil() as u64).clamp(1, 1_000_000);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            times.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        let stats = Stats {
            name: name.to_string(),
            iters: iters_per_sample * samples as u64,
            mean,
            median,
            min: times[0],
            max: *times.last().unwrap(),
            mad,
        };
        if !self.quiet {
            println!(
                "bench {:<42} median {:>12} (±{:>10}, {} iters)",
                stats.name,
                super::timer::fmt_secs(stats.median),
                super::timer::fmt_secs(stats.mad),
                stats.iters
            );
        }
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Time a one-shot (non-repeatable) measurement, recording it alongside
    /// the adaptive benches (used for long end-to-end runs).
    pub fn once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, &Stats) {
        let t = Instant::now();
        let v = black_box(f());
        let secs = t.elapsed().as_secs_f64();
        let stats = Stats {
            name: name.to_string(),
            iters: 1,
            mean: secs,
            median: secs,
            min: secs,
            max: secs,
            mad: 0.0,
        };
        println!(
            "bench {:<42} once   {:>12}",
            stats.name,
            super::timer::fmt_secs(secs)
        );
        self.results.push(stats);
        (v, self.results.last().unwrap())
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Write collected stats to `results/<file>.csv`.
    pub fn write_csv(&self, file: &str) -> std::io::Result<()> {
        let mut s = String::from("name,iters,median_s,mean_s,min_s,max_s,mad_s\n");
        for r in &self.results {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{}",
                r.name, r.iters, r.median, r.mean, r.min, r.max, r.mad
            );
        }
        write_results_file(file, &s)
    }
}

/// Write any text artifact into `results/` (creating the dir).
pub fn write_results_file(file: &str, contents: &str) -> std::io::Result<()> {
    let dir = Path::new("results");
    fs::create_dir_all(dir)?;
    fs::write(dir.join(file), contents)
}

/// Render rows as a GitHub-flavoured markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "| {} |", headers.join(" | "));
    let _ = writeln!(
        s,
        "|{}|",
        headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for row in rows {
        let _ = writeln!(s, "| {} |", row.join(" | "));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_stats() {
        let mut b = Bencher::with_target_time(0.02);
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert!(s.median > 0.0);
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::new();
        let (v, s) = b.once("x", || 7);
        assert_eq!(v, 7);
        assert_eq!(s.iters, 1);
    }

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        assert_eq!(t.lines().count(), 4);
        assert!(t.contains("| 1 | 2 |"));
    }
}
