//! Per-request tracing and the operational event journal.
//!
//! # Trace model
//!
//! Every request entering the coordinator gets a **trace id** minted at
//! the public call edge (`Server::call*`, and therefore also the TCP
//! edge, which goes through `call_timeout`). The id rides the envelope
//! through the shard queue and batch planner; the shard loop opens a
//! thread-local span accumulator per request ([`begin`]/[`take_stages`])
//! and the stage taxonomy below partitions the request's wall time into
//! **disjoint** spans, so the per-stage sum is bounded by the measured
//! request latency:
//!
//! | stage          | measures                                                |
//! |----------------|---------------------------------------------------------|
//! | `queue_wait`   | enqueue → drained into a batch                          |
//! | `plan`         | batch planning minus the forward sweep                  |
//! | `batch_forward`| the node-major multi-session forward sweep              |
//! | `score_fold`   | per-call feature extraction + scoring                   |
//! | `online_ridge` | rank-1 fold / reseed / adaptation / (re)train           |
//! | `checkpoint`   | durable checkpoint writes + hibernation park/rehydrate  |
//! | `reply`        | shipping the reply                                      |
//!
//! Shared cycle work (`plan`, `batch_forward`) is attributed in full to
//! every request in the cycle: each of those requests did wait for it,
//! so the bound still holds per trace.
//!
//! Completed traces are recorded into **per-shard single-writer seqlock
//! rings** ([`TraceRing`]): the shard thread writes fixed-size records
//! word-by-word through relaxed atomics (no lock, no allocation — the
//! steady-state serve path stays alloc-free), readers validate each
//! slot's sequence number and simply skip slots that were overwritten
//! mid-read. Torn reads are detected, never returned.
//!
//! Traces slower than the configured threshold additionally emit a
//! structured one-line breakdown through `util::log` (allocation happens
//! only on that gated slow path).
//!
//! # Event journal
//!
//! [`EventLog`] is a bounded mutex-guarded ring of structured
//! operational events (shard death/respawn, generation rolls, quant
//! fallback flips, quarantines, hibernation churn, checkpoint writes).
//! Events are rare and always coincide with already-allocating slow
//! paths, so a lock + `String` detail is fine there.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::log_warn;

/// Number of trace stages (see module docs for the taxonomy).
pub const N_STAGES: usize = 7;

/// Disjoint request stages; `as usize` is the span-array index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Stage {
    QueueWait = 0,
    Plan = 1,
    BatchForward = 2,
    ScoreFold = 3,
    OnlineRidge = 4,
    Checkpoint = 5,
    Reply = 6,
}

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::QueueWait,
        Stage::Plan,
        Stage::BatchForward,
        Stage::ScoreFold,
        Stage::OnlineRidge,
        Stage::Checkpoint,
        Stage::Reply,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::Plan => "plan",
            Stage::BatchForward => "batch_forward",
            Stage::ScoreFold => "score_fold",
            Stage::OnlineRidge => "online_ridge",
            Stage::Checkpoint => "checkpoint",
            Stage::Reply => "reply",
        }
    }
}

/// Microseconds since the process trace epoch (first call wins).
pub fn epoch_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// `session` value meaning "no session attached to this request".
pub const NO_SESSION: u64 = u64::MAX;

/// One completed request trace. Plain `Copy` data — fixed size, no heap —
/// so recording stays allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    pub trace_id: u64,
    /// Session id, or [`NO_SESSION`].
    pub session: u64,
    pub shard: u32,
    /// Request kind — mirrors the `protocol::REQ_*` wire codes
    /// (0 = internal/other).
    pub kind: u8,
    /// Response kind — mirrors the `protocol::RESP_*` wire codes
    /// (0 = reply dropped).
    pub outcome: u8,
    /// Drain depth of the batch cycle that served this request.
    pub batch: u16,
    /// Microseconds since [`epoch_us`] at which processing completed.
    pub end_us: u64,
    /// Total envelope residency: enqueue → reply shipped (µs).
    pub total_us: u64,
    /// Per-stage durations (µs), indexed by [`Stage`].
    pub stages_us: [u64; N_STAGES],
}

/// Words per serialized record (the seqlock ring stores records as plain
/// `u64` words so readers and the writer never form references to
/// concurrently-mutated memory).
const WORDS: usize = 5 + N_STAGES;

impl TraceRecord {
    pub fn stages_sum_us(&self) -> u64 {
        self.stages_us.iter().sum()
    }

    fn to_words(self) -> [u64; WORDS] {
        let mut w = [0u64; WORDS];
        w[0] = self.trace_id;
        w[1] = self.session;
        w[2] = ((self.shard as u64) << 32)
            | ((self.kind as u64) << 24)
            | ((self.outcome as u64) << 16)
            | (self.batch as u64);
        w[3] = self.end_us;
        w[4] = self.total_us;
        w[5..].copy_from_slice(&self.stages_us);
        w
    }

    fn from_words(w: &[u64; WORDS]) -> Self {
        let mut stages_us = [0u64; N_STAGES];
        stages_us.copy_from_slice(&w[5..]);
        TraceRecord {
            trace_id: w[0],
            session: w[1],
            shard: (w[2] >> 32) as u32,
            kind: (w[2] >> 24) as u8,
            outcome: (w[2] >> 16) as u8,
            batch: w[2] as u16,
            end_us: w[3],
            total_us: w[4],
            stages_us,
        }
    }

    /// One JSON object per line (`Request::Traces` payload format).
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(256);
        s.push_str(&format!(
            "{{\"trace_id\":{},\"shard\":{},\"session\":",
            self.trace_id, self.shard
        ));
        if self.session == NO_SESSION {
            s.push_str("null");
        } else {
            s.push_str(&format!("{}", self.session));
        }
        s.push_str(&format!(
            ",\"kind\":\"{}\",\"outcome\":\"{}\",\"batch\":{},\"end_us\":{},\"total_us\":{},\"stages_us\":{{",
            kind_name(self.kind),
            outcome_name(self.outcome),
            self.batch,
            self.end_us,
            self.total_us,
        ));
        for (i, st) in Stage::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{}\":{}", st.name(), self.stages_us[i]));
        }
        s.push_str("}}");
        s
    }
}

/// Human name for a request-kind code (mirrors `protocol::REQ_*`; 0 is
/// reserved for internal probes).
pub fn kind_name(k: u8) -> &'static str {
    match k {
        0 => "internal",
        1 => "labelled",
        2 => "infer",
        3 => "finalize",
        4 => "stats",
        5 => "traces",
        6 => "events",
        _ => "unknown",
    }
}

/// Human name for a response-kind code (mirrors `protocol::RESP_*`; 0 is
/// "reply dropped before send").
pub fn outcome_name(o: u8) -> &'static str {
    match o {
        0 => "dropped",
        1 => "accepted",
        2 => "prediction",
        3 => "trained",
        4 => "observed",
        5 => "adapted",
        6 => "stats",
        7 => "rejected",
        8 => "error",
        9 => "bye",
        10 => "traces",
        11 => "events",
        _ => "unknown",
    }
}

// ---------------------------------------------------------------------------
// per-shard seqlock ring
// ---------------------------------------------------------------------------

struct Slot {
    /// Seqlock word: `2*(generation+1)` once generation `g`'s record is
    /// fully written, odd while a write is in flight, 0 when never
    /// written.
    seq: AtomicU64,
    words: [AtomicU64; WORDS],
}

impl Slot {
    fn new() -> Self {
        Slot {
            seq: AtomicU64::new(0),
            words: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Lock-free single-writer ring of [`TraceRecord`]s.
///
/// The shard thread is the only writer; any thread may snapshot. The
/// canonical seqlock protocol is used (odd sequence while writing,
/// `Release` publication, reader re-validation with an `Acquire` fence),
/// over `AtomicU64` words so there is no UB-prone shared plain memory.
pub struct TraceRing {
    slots: Box<[Slot]>,
    /// Number of records ever pushed (monotone).
    head: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        TraceRing {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Records ever pushed (not the currently-retained count).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Writer side — single-threaded by contract, allocation-free.
    pub fn push(&self, rec: &TraceRecord) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h % self.slots.len() as u64) as usize];
        slot.seq.store(2 * h + 1, Ordering::Relaxed);
        // odd marker must be visible before any word of the new record
        fence(Ordering::Release);
        let words = rec.to_words();
        for (w, v) in slot.words.iter().zip(words.iter()) {
            w.store(*v, Ordering::Relaxed);
        }
        // publish: every word store above stays before this
        slot.seq.store(2 * (h + 1), Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Append up to the newest `n` retained records into `out`, oldest
    /// first. Slots overwritten or mid-write during the read are skipped
    /// (detected via the sequence word), never returned torn.
    pub fn snapshot_last(&self, n: usize, out: &mut Vec<TraceRecord>) {
        let h = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let avail = h.min(cap).min(n as u64);
        for g in (h - avail)..h {
            let slot = &self.slots[(g % cap) as usize];
            let expect = 2 * (g + 1);
            if slot.seq.load(Ordering::Acquire) != expect {
                continue;
            }
            let mut w = [0u64; WORDS];
            for (dst, src) in w.iter_mut().zip(slot.words.iter()) {
                *dst = src.load(Ordering::Relaxed);
            }
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != expect {
                continue;
            }
            out.push(TraceRecord::from_words(&w));
        }
    }
}

// ---------------------------------------------------------------------------
// thread-local span accumulator
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct Active {
    on: bool,
    stages_us: [u64; N_STAGES],
}

thread_local! {
    static CURRENT: Cell<Active> = const {
        Cell::new(Active { on: false, stages_us: [0; N_STAGES] })
    };
}

/// Open the thread-local span accumulator for one request. Subsequent
/// [`span`] guards and [`add_stage_us`] calls accumulate until
/// [`take_stages`]. No-op-cheap and allocation-free.
pub fn begin() {
    CURRENT.with(|c| {
        c.set(Active {
            on: true,
            stages_us: [0; N_STAGES],
        })
    });
}

/// Close the accumulator and return the per-stage totals.
pub fn take_stages() -> [u64; N_STAGES] {
    CURRENT.with(|c| {
        let cur = c.get();
        c.set(Active {
            on: false,
            stages_us: [0; N_STAGES],
        });
        cur.stages_us
    })
}

/// Add an externally-measured duration to a stage of the active trace
/// (used for `queue_wait` and the shared cycle spans). No-op when no
/// trace is active.
pub fn add_stage_us(stage: Stage, us: u64) {
    CURRENT.with(|c| {
        let mut cur = c.get();
        if cur.on {
            cur.stages_us[stage as usize] += us;
            c.set(cur);
        }
    });
}

/// RAII span: measures from construction to drop and adds the elapsed
/// microseconds to `stage` of the active trace. Inert (a single
/// thread-local read) when no trace is active, so instrumented library
/// code costs nothing outside the serve loop.
pub struct SpanGuard {
    stage: Stage,
    start: Instant,
    armed: bool,
}

/// Open a [`SpanGuard`] for `stage`.
pub fn span(stage: Stage) -> SpanGuard {
    let armed = CURRENT.with(|c| c.get().on);
    SpanGuard {
        stage,
        start: Instant::now(),
        armed,
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            add_stage_us(self.stage, self.start.elapsed().as_micros() as u64);
        }
    }
}

// ---------------------------------------------------------------------------
// hub: id minting, per-shard rings, slow-request breakdown
// ---------------------------------------------------------------------------

/// Shared tracing state for one server: the id mint, one ring per shard
/// and the slow-request threshold.
pub struct TraceHub {
    rings: Vec<TraceRing>,
    next_id: AtomicU64,
    slow_us: u64,
}

impl TraceHub {
    /// `slow_ms = None` disables the slow-request breakdown log.
    pub fn new(shards: usize, ring_capacity: usize, slow_ms: Option<u64>) -> Self {
        TraceHub {
            rings: (0..shards.max(1))
                .map(|_| TraceRing::new(ring_capacity))
                .collect(),
            next_id: AtomicU64::new(1),
            slow_us: slow_ms.map(|ms| ms.saturating_mul(1000)).unwrap_or(0),
        }
    }

    /// Mint a fresh trace id (never 0).
    pub fn mint(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn ring(&self, shard: usize) -> &TraceRing {
        &self.rings[shard % self.rings.len()]
    }

    pub fn shards(&self) -> usize {
        self.rings.len()
    }

    /// Slow threshold in µs (0 = disabled).
    pub fn slow_us(&self) -> u64 {
        self.slow_us
    }

    /// Record a completed trace: push into the shard's ring and, when it
    /// crosses the slow threshold, emit a structured breakdown line.
    /// The ring push is lock- and allocation-free; only the gated slow
    /// path formats.
    pub fn record(&self, rec: &TraceRecord) {
        self.ring(rec.shard as usize).push(rec);
        if self.slow_us > 0 && rec.total_us >= self.slow_us {
            log_warn!(
                "slow-request trace_id={} shard={} session={} kind={} outcome={} batch={} total_us={} \
                 queue_wait_us={} plan_us={} batch_forward_us={} score_fold_us={} online_ridge_us={} \
                 checkpoint_us={} reply_us={}",
                rec.trace_id,
                rec.shard,
                rec.session as i64, // NO_SESSION renders as -1
                kind_name(rec.kind),
                outcome_name(rec.outcome),
                rec.batch,
                rec.total_us,
                rec.stages_us[Stage::QueueWait as usize],
                rec.stages_us[Stage::Plan as usize],
                rec.stages_us[Stage::BatchForward as usize],
                rec.stages_us[Stage::ScoreFold as usize],
                rec.stages_us[Stage::OnlineRidge as usize],
                rec.stages_us[Stage::Checkpoint as usize],
                rec.stages_us[Stage::Reply as usize],
            );
        }
    }

    /// Collect the newest `n` traces across all shards (oldest first) as
    /// JSON lines.
    pub fn last_json(&self, n: usize) -> String {
        let mut all = Vec::new();
        for ring in &self.rings {
            ring.snapshot_last(n, &mut all);
        }
        all.sort_by_key(|r| (r.end_us, r.trace_id));
        let skip = all.len().saturating_sub(n);
        let mut out = String::new();
        for rec in &all[skip..] {
            out.push_str(&rec.to_json_line());
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------------------------------
// event journal
// ---------------------------------------------------------------------------

/// Operational event classes recorded in the [`EventLog`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    ShardDeath,
    ShardRespawn,
    GenerationRoll,
    QuantFallback,
    QuantRecover,
    Quarantine,
    HibernatePark,
    HibernateRehydrate,
    CheckpointWrite,
    CheckpointError,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::ShardDeath => "shard_death",
            EventKind::ShardRespawn => "shard_respawn",
            EventKind::GenerationRoll => "generation_roll",
            EventKind::QuantFallback => "quant_fallback",
            EventKind::QuantRecover => "quant_recover",
            EventKind::Quarantine => "quarantine",
            EventKind::HibernatePark => "hibernate_park",
            EventKind::HibernateRehydrate => "hibernate_rehydrate",
            EventKind::CheckpointWrite => "checkpoint_write",
            EventKind::CheckpointError => "checkpoint_error",
        }
    }
}

/// One structured operational event.
#[derive(Clone, Debug)]
pub struct Event {
    /// µs since [`epoch_us`].
    pub at_us: u64,
    pub kind: EventKind,
    pub shard: u32,
    /// Session id, or [`NO_SESSION`].
    pub session: u64,
    pub detail: String,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Event {
    pub fn to_json_line(&self) -> String {
        let session = if self.session == NO_SESSION {
            "null".to_string()
        } else {
            format!("{}", self.session)
        };
        format!(
            "{{\"at_us\":{},\"kind\":\"{}\",\"shard\":{},\"session\":{},\"detail\":\"{}\"}}",
            self.at_us,
            self.kind.name(),
            self.shard,
            session,
            json_escape(&self.detail),
        )
    }
}

/// Bounded ring of operational events. Push evicts the oldest entry once
/// the capacity is reached (evictions are counted, not silent).
pub struct EventLog {
    ring: Mutex<VecDeque<Event>>,
    cap: usize,
    evicted: AtomicU64,
}

impl EventLog {
    pub fn new(capacity: usize) -> Self {
        EventLog {
            ring: Mutex::new(VecDeque::new()),
            cap: capacity.max(1),
            evicted: AtomicU64::new(0),
        }
    }

    /// Record an event (timestamped now). Events sit on rare,
    /// already-allocating paths, so the lock + `String` are fine here —
    /// never call this per request.
    pub fn push(&self, kind: EventKind, shard: u32, session: u64, detail: String) {
        let ev = Event {
            at_us: epoch_us(),
            kind,
            shard,
            session,
            detail,
        };
        if let Ok(mut ring) = self.ring.lock() {
            if ring.len() == self.cap {
                ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
            ring.push_back(ev);
        }
    }

    pub fn len(&self) -> usize {
        self.ring.lock().map(|r| r.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by the capacity bound since startup.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// The newest `n` events, oldest first, as JSON lines.
    pub fn last_json(&self, n: usize) -> String {
        let mut out = String::new();
        if let Ok(ring) = self.ring.lock() {
            let skip = ring.len().saturating_sub(n);
            for ev in ring.iter().skip(skip) {
                out.push_str(&ev.to_json_line());
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, shard: u32, end_us: u64) -> TraceRecord {
        TraceRecord {
            trace_id: id,
            session: 7,
            shard,
            kind: 2,
            outcome: 2,
            batch: 3,
            end_us,
            total_us: 120,
            stages_us: [10, 20, 30, 40, 5, 0, 15],
        }
    }

    #[test]
    fn record_words_round_trip() {
        let r = rec(u64::MAX - 1, u32::MAX, 99);
        assert_eq!(TraceRecord::from_words(&r.to_words()), r);
        let r2 = TraceRecord {
            session: NO_SESSION,
            kind: 255,
            outcome: 255,
            batch: u16::MAX,
            ..r
        };
        assert_eq!(TraceRecord::from_words(&r2.to_words()), r2);
    }

    #[test]
    fn ring_keeps_newest_and_orders() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.push(&rec(i, 0, i));
        }
        let mut out = Vec::new();
        ring.snapshot_last(8, &mut out);
        // capacity 4: only the last 4 survive, oldest first
        let ids: Vec<u64> = out.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        out.clear();
        ring.snapshot_last(2, &mut out);
        let ids: Vec<u64> = out.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![8, 9]);
        assert_eq!(ring.pushed(), 10);
    }

    #[test]
    fn ring_survives_concurrent_readers() {
        use std::sync::Arc;
        let ring = Arc::new(TraceRing::new(8));
        let stop = Arc::new(AtomicU64::new(0));
        let mut readers = Vec::new();
        for _ in 0..2 {
            let ring = ring.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                while stop.load(Ordering::Relaxed) == 0 {
                    out.clear();
                    ring.snapshot_last(8, &mut out);
                    for r in &out {
                        // a torn record would violate the writer's
                        // invariant end_us == trace_id
                        assert_eq!(r.end_us, r.trace_id, "torn record escaped the seqlock");
                    }
                }
            }));
        }
        for i in 0..20_000u64 {
            ring.push(&rec(i, 0, i));
        }
        stop.store(1, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
    }

    #[test]
    fn span_guards_accumulate_only_when_active() {
        // inactive: guard is inert
        drop(span(Stage::ScoreFold));
        begin();
        {
            let _g = span(Stage::ScoreFold);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        add_stage_us(Stage::QueueWait, 17);
        let stages = take_stages();
        assert!(stages[Stage::ScoreFold as usize] >= 1_000, "{stages:?}");
        assert_eq!(stages[Stage::QueueWait as usize], 17);
        // accumulator is closed now
        add_stage_us(Stage::Plan, 5);
        begin();
        let fresh = take_stages();
        assert_eq!(fresh, [0; N_STAGES], "stale spans leaked across begin()");
    }

    #[test]
    fn hub_minting_and_slow_threshold() {
        let hub = TraceHub::new(2, 16, Some(1));
        assert_eq!(hub.mint(), 1);
        assert_eq!(hub.mint(), 2);
        assert_eq!(hub.slow_us(), 1000);
        hub.record(&rec(1, 0, 1));
        hub.record(&rec(2, 1, 2));
        let json = hub.last_json(10);
        assert_eq!(json.lines().count(), 2, "{json}");
        assert!(json.contains("\"trace_id\":1"), "{json}");
        assert!(json.contains("\"kind\":\"infer\""), "{json}");
        // n caps the output across shards, newest retained
        let json = hub.last_json(1);
        assert_eq!(json.lines().count(), 1, "{json}");
        assert!(json.contains("\"trace_id\":2"), "{json}");
    }

    #[test]
    fn trace_json_lines_parse() {
        let line = rec(3, 1, 44).to_json_line();
        let parsed = crate::util::json::Json::parse(&line).expect("trace line must be valid JSON");
        assert_eq!(parsed.get("trace_id").and_then(|v| v.as_usize()), Some(3));
        let stages = parsed.get("stages_us").expect("stages_us object");
        assert_eq!(stages.get("queue_wait").and_then(|v| v.as_usize()), Some(10));
    }

    #[test]
    fn event_log_bounds_and_renders() {
        let log = EventLog::new(2);
        log.push(EventKind::ShardDeath, 0, NO_SESSION, "panic: boom".into());
        log.push(EventKind::ShardRespawn, 0, NO_SESSION, String::new());
        log.push(EventKind::CheckpointWrite, 1, 42, "3 sessions".into());
        assert_eq!(log.len(), 2);
        assert_eq!(log.evicted(), 1);
        let json = log.last_json(10);
        assert!(!json.contains("shard_death"), "{json}");
        assert!(json.contains("\"kind\":\"shard_respawn\""), "{json}");
        assert!(json.contains("\"session\":42"), "{json}");
        for line in json.lines() {
            crate::util::json::Json::parse(line).expect("event line must be valid JSON");
        }
    }

    #[test]
    fn event_details_are_escaped() {
        let ev = Event {
            at_us: 1,
            kind: EventKind::Quarantine,
            shard: 0,
            session: NO_SESSION,
            detail: "bad \"score\"\nline\\two".into(),
        };
        let line = ev.to_json_line();
        crate::util::json::Json::parse(&line).expect("escaped detail must parse");
    }
}
