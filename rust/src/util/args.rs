//! Declarative command-line parser (no clap in the image).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and positional arguments; generates `--help` text.

use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A declarative command: name, help, options.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Self {
        Command {
            name,
            help,
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    /// Parse `argv` (without the subcommand itself).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} is a flag, takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }

        // defaults + required checks
        for o in &self.opts {
            if o.is_flag || values.contains_key(o.name) {
                continue;
            }
            match o.default {
                Some(d) => {
                    values.insert(o.name.to_string(), d.to_string());
                }
                None => return Err(format!("missing required option --{}", o.name)),
            }
        }
        if pos.len() < self.positional.len() {
            return Err(format!(
                "missing positional argument <{}>\n{}",
                self.positional[pos.len()].0,
                self.usage()
            ));
        }
        Ok(Parsed { values, flags, pos })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: dfr-edge {} [options]", self.name);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(&format!("\n\n{}\n", self.help));
        if !self.positional.is_empty() {
            s.push_str("\npositional:\n");
            for (p, h) in &self.positional {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\noptions:\n");
            for o in &self.opts {
                let d = match (o.is_flag, o.default) {
                    (true, _) => String::new(),
                    (false, Some(d)) => format!(" (default: {d})"),
                    (false, None) => " (required)".to_string(),
                };
                s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
            }
        }
        s
    }
}

/// Result of parsing.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub pos: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(String::as_str)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{}'", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got '{}'", self.get(name)))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected float, got '{}'", self.get(name)))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("dataset", "jpvow", "dataset profile")
            .opt("epochs", "25", "SGD epochs")
            .req("out", "output path")
            .flag("verbose", "log more")
            .pos("input", "input file")
    }

    #[test]
    fn parses_defaults_and_values() {
        let p = cmd()
            .parse(&argv(&["--out", "w.bin", "data.npz", "--epochs=10"]))
            .unwrap();
        assert_eq!(p.get("dataset"), "jpvow");
        assert_eq!(p.get_usize("epochs").unwrap(), 10);
        assert_eq!(p.get("out"), "w.bin");
        assert_eq!(p.pos, vec!["data.npz"]);
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn flag_and_equals() {
        let p = cmd()
            .parse(&argv(&["--verbose", "--out=o", "x"]))
            .unwrap();
        assert!(p.has_flag("verbose"));
        assert_eq!(p.get("out"), "o");
    }

    #[test]
    fn missing_required() {
        let e = cmd().parse(&argv(&["x"])).unwrap_err();
        assert!(e.contains("--out"), "{e}");
    }

    #[test]
    fn unknown_option() {
        let e = cmd().parse(&argv(&["--nope", "1", "x"])).unwrap_err();
        assert!(e.contains("unknown option"), "{e}");
    }

    #[test]
    fn missing_positional() {
        let e = cmd().parse(&argv(&["--out", "o"])).unwrap_err();
        assert!(e.contains("positional"), "{e}");
    }

    #[test]
    fn bad_number() {
        let p = cmd()
            .parse(&argv(&["--out", "o", "--epochs", "abc", "x"]))
            .unwrap();
        assert!(p.get_usize("epochs").is_err());
    }

    #[test]
    fn help_text_lists_options() {
        let u = cmd().usage();
        for needle in ["--dataset", "--epochs", "--out", "--verbose", "<input>"] {
            assert!(u.contains(needle), "{needle} missing in\n{u}");
        }
    }
}
