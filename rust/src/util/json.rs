//! Minimal JSON reader/writer (no serde in the image).
//!
//! Covers exactly what the system needs: the artifact `manifest.json`
//! contract with `python/compile/aot.py`, and result emission for the
//! benchmark harness. Numbers parse as f64; integers round-trip exactly
//! up to 2^53.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// NOTE: hand-rolled Display/Error — the image vendors no `thiserror`.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"nx_default": 30, "profiles": {"jpvow": {"n_v": 12,
            "entries": {"step": {"file": "step_jpvow.hlo.txt",
            "args": [{"name": "x_prev", "dims": [30], "dtype": "float32"}]}}}}}"#;
        let v = Json::parse(src).unwrap();
        let nv = v
            .get("profiles")
            .and_then(|p| p.get("jpvow"))
            .and_then(|p| p.get("n_v"))
            .and_then(Json::as_usize);
        assert_eq!(nv, Some(12));
        // serialize then reparse
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers() {
        for (s, n) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap(), Json::Num(n), "{s}");
        }
    }

    #[test]
    fn strings_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integer_roundtrip_exact() {
        let v = Json::Num(1_752_142.0);
        assert_eq!(v.to_string(), "1752142");
    }
}
