//! Scoped worker pool: borrow-friendly data parallelism over `&[T]`.
//!
//! [`runtimex::parallel_map`](super::runtimex::parallel_map) requires
//! `'static` items, which forces callers (grid search, ridge training)
//! to `Arc`-clone whole datasets before fanning out. [`scoped_map`] uses
//! `std::thread::scope` instead, so workers borrow the input slice and
//! every captured reference directly — no cloning, no `Arc`, no heap
//! beyond the result vector. Work is distributed by an atomic cursor
//! (cheap work stealing: a slow item never stalls the other workers) and
//! results are returned in input order, so `scoped_map` is a drop-in
//! deterministic replacement for a serial `iter().map().collect()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

/// Map `f` over `items` on up to `threads` scoped workers, preserving
/// input order of the results.
///
/// `threads <= 1` (or a single item) runs inline on the caller with no
/// thread spawned, so the serial and parallel paths produce identical
/// results element-for-element. A panic inside `f` propagates to the
/// caller when the scope joins.
pub fn scoped_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                if tx.send((i, f(&items[i]))).is_err() {
                    return;
                }
            });
        }
        drop(tx);
        // receive while the workers run — the scope joins them after
        for (i, r) in rx {
            out[i] = Some(r);
        }
    });
    out.into_iter()
        .map(|r| r.expect("scoped_map worker died before finishing"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..97).collect();
        let out = scoped_map(&items, 8, |&x| x * 3);
        assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn borrows_without_static() {
        // the whole point: captured references, no Arc / 'static
        let data = vec![String::from("a"), String::from("bb"), String::from("ccc")];
        let prefix = String::from("len=");
        let out = scoped_map(&data, 2, |s| format!("{prefix}{}", s.len()));
        assert_eq!(out, vec!["len=1", "len=2", "len=3"]);
    }

    #[test]
    fn empty_and_serial_paths() {
        let out: Vec<i32> = scoped_map(&[], 4, |x: &i32| *x);
        assert!(out.is_empty());
        let out = scoped_map(&[5, 6], 1, |&x| x + 1);
        assert_eq!(out, vec![6, 7]);
    }

    #[test]
    fn thread_count_larger_than_items() {
        let out = scoped_map(&[1, 2, 3], 64, |&x| x);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let items: Vec<u32> = (0..8).collect();
        scoped_map(&items, 4, |&x| {
            if x == 5 {
                panic!("boom");
            }
            x
        });
    }
}
