//! Wall-clock timing helpers shared by the bench harness and the
//! coordinator's latency metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let r = f();
    (r, sw.elapsed_secs())
}

/// Format seconds human-readably (for table output).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-5).ends_with("µs"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with(" s"));
        assert!(fmt_secs(500.0).ends_with("min"));
    }
}
