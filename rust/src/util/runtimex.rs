//! Minimal thread-pool runtime (no tokio in the image).
//!
//! A fixed-size worker pool consuming a bounded MPMC queue (backpressure
//! by blocking send), plus [`parallel_map`], a tiny `par_iter`
//! substitute. Grid search and the bench sweeps run on these. The
//! coordinator's shard pool (`coordinator::server`) uses dedicated
//! per-shard queues instead — sessions must be pinned to one thread,
//! which a work-stealing MPMC pool cannot guarantee — but shares the
//! same backpressure idiom (`submit` blocks, `try_submit` refuses).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    q: VecDeque<Job>,
    closed: bool,
}

/// Fixed-size thread pool with a bounded queue.
///
/// `submit` blocks when the queue is full — that is the system's
/// backpressure mechanism (the paper's edge device must bound memory).
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, queue_capacity: usize) -> Self {
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState {
                q: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: queue_capacity.max(1),
        });
        let workers = (0..threads.max(1))
            .map(|_| {
                let q = Arc::clone(&queue);
                thread::spawn(move || loop {
                    let job = {
                        let mut st = q.jobs.lock().unwrap();
                        loop {
                            if let Some(j) = st.q.pop_front() {
                                q.not_full.notify_one();
                                break j;
                            }
                            if st.closed {
                                return;
                            }
                            st = q.not_empty.wait(st).unwrap();
                        }
                    };
                    job();
                })
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Submit a job; blocks while the queue is at capacity (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.queue.jobs.lock().unwrap();
        while st.q.len() >= self.queue.capacity {
            st = self.queue.not_full.wait(st).unwrap();
        }
        assert!(!st.closed, "submit on closed pool");
        st.q.push_back(Box::new(job));
        drop(st);
        self.queue.not_empty.notify_one();
    }

    /// Try to submit without blocking; returns false when saturated.
    pub fn try_submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        let mut st = self.queue.jobs.lock().unwrap();
        if st.q.len() >= self.queue.capacity || st.closed {
            return false;
        }
        st.q.push_back(Box::new(job));
        drop(st);
        self.queue.not_empty.notify_one();
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.jobs.lock().unwrap().q.len()
    }

    /// Close the queue and join all workers (drains pending jobs first).
    pub fn shutdown(self) {
        {
            let mut st = self.queue.jobs.lock().unwrap();
            st.closed = true;
        }
        self.queue.not_empty.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Run `f` over items on `threads` workers, preserving input order of
/// results. A tiny rayon-par_iter substitute for benches and grid search.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let work: Arc<Mutex<VecDeque<(usize, T)>>> =
        Arc::new(Mutex::new(items.into_iter().enumerate().collect()));
    let mut handles = Vec::new();
    for _ in 0..threads {
        let work = Arc::clone(&work);
        let tx = tx.clone();
        let f = Arc::clone(&f);
        handles.push(thread::spawn(move || loop {
            let next = work.lock().unwrap().pop_front();
            match next {
                Some((i, item)) => {
                    let r = f(item);
                    if tx.send((i, r)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    for h in handles {
        let _ = h.join();
    }
    out.into_iter().map(Option::unwrap).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let n = Arc::clone(&n);
            pool.submit(move || {
                n.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(n.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_backpressure() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        // block the single worker
        pool.submit(move || {
            let (m, c) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = c.wait(open).unwrap();
            }
        });
        // fill the queue; eventually try_submit must refuse
        let mut refused = false;
        for _ in 0..10 {
            if !pool.try_submit(|| {}) {
                refused = true;
                break;
            }
        }
        assert!(refused, "queue never saturated");
        {
            let (m, c) = &*gate;
            *m.lock().unwrap() = true;
            c.notify_all();
        }
        pool.shutdown();
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..64).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
