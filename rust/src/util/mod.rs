//! Substrate utilities built from scratch for the edge binary.
//!
//! The deployment image vendors no general-purpose crates (no `rand`,
//! `clap`, `serde`, `tokio`, `criterion`, `proptest`), so the pieces the
//! system needs are implemented here: a PCG PRNG, a declarative argument
//! parser, a minimal JSON reader/writer, a thread-pool event loop, a
//! scoped (borrow-friendly) worker pool, a timing/benchmark harness and
//! a tiny property-testing driver.

pub mod args;
pub mod bench;
pub mod json;
pub mod log;
pub mod metrics;
pub mod prng;
pub mod proptest;
pub mod runtimex;
pub mod scoped_pool;
pub mod timer;
pub mod trace;
