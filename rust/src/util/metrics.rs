//! Lightweight metrics: counters and latency histograms for the
//! coordinator (request counts, per-stage latencies, queue rejections).
//!
//! Metrics may carry labels (e.g. `shard="2"`): every shard of the
//! coordinator registers its own labelled instruments in one shared
//! [`Registry`], and [`Registry::render`] emits both the per-label lines
//! and an aggregated line per metric name (counter values summed,
//! histogram buckets merged), so a single `Request::Stats` snapshot shows
//! the whole server *and* each shard.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log-scale buckets (microsecond powers of two up to ~67 s).
const BUCKETS: usize = 27;

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement — for the few counters that track a level
    /// rather than a rate (e.g. the coordinator's `shards_active`, which
    /// drops when a shard dies and recovers when the supervisor respawns
    /// it). Never wraps below zero.
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(observed) => cur = observed,
            }
        }
    }

    /// Overwrite the value — for level gauges with a single writer
    /// (e.g. each shard's `resident_sessions{shard=…}`, re-published
    /// after every batch cycle). The labelled aggregate stays correct
    /// because each shard owns its own labelled instance; do not `set`
    /// a counter that several threads also `inc`/`add`.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-scale latency histogram (microsecond buckets, powers of two up to
/// ~67 s). Lock-free recording.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_secs(&self, secs: f64) {
        let us = (secs * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        self.snapshot().mean_secs()
    }

    /// Approximate quantile from the log buckets (upper bound of bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.snapshot().quantile_secs(q)
    }

    /// Consistent-enough point-in-time copy (individual loads are relaxed;
    /// recording concurrently with a snapshot may skew one sample).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of a [`Histogram`]; snapshots of different histograms
/// (e.g. one per shard) can be merged into an aggregate view.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    buckets: [u64; BUCKETS],
    sum_us: u64,
    count: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: [0; BUCKETS],
            sum_us: 0,
            count: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Add another snapshot's samples into this one.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e6
    }

    /// Approximate quantile from the log buckets (upper bound of bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return (1u64 << i) as f64 / 1e6;
            }
        }
        (1u64 << (BUCKETS - 1)) as f64 / 1e6
    }

    fn render_line(&self, key: &str) -> String {
        format!(
            "hist {key} count {} mean_s {:.6} p50_s {:.6} p99_s {:.6}\n",
            self.count,
            self.mean_secs(),
            self.quantile_secs(0.5),
            self.quantile_secs(0.99),
        )
    }
}

/// Metric identity: a name plus optional `key="value"` labels. Ordering is
/// name-major, so a [`BTreeMap`] keyed by `MetricKey` groups all labelled
/// variants of one name together.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct MetricKey {
    name: String,
    labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        MetricKey {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        }
    }

    /// Rendering used for per-variant lines inside a name group. The
    /// unlabelled variant renders as `name{}` so it can never be confused
    /// with the group's aggregate `name` line.
    fn render_in_group(&self) -> String {
        let l: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{v}\""))
            .collect();
        format!("{}{{{}}}", self.name, l.join(","))
    }
}

/// Group a name-sorted metric map into per-name runs (`BTreeMap` keyed by
/// [`MetricKey`] is name-major, so one linear pass suffices).
fn groups<V>(map: &BTreeMap<MetricKey, V>) -> Vec<(&str, Vec<(&MetricKey, &V)>)> {
    let mut out: Vec<(&str, Vec<(&MetricKey, &V)>)> = Vec::new();
    for (k, v) in map {
        match out.last_mut() {
            Some((name, group)) if *name == k.name => group.push((k, v)),
            _ => out.push((k.name.as_str(), vec![(k, v)])),
        }
    }
    out
}

/// A named registry of counters and histograms, shared across threads.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<MetricKey, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<MetricKey, Arc<Histogram>>>,
}

impl Registry {
    /// Unlabelled counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_labelled(name, &[])
    }

    /// Counter with labels, e.g. `counter_labelled("requests_total", &[("shard", "0")])`.
    pub fn counter_labelled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(MetricKey::new(name, labels))
            .or_default()
            .clone()
    }

    /// Unlabelled histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_labelled(name, &[])
    }

    /// Histogram with labels.
    pub fn histogram_labelled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(MetricKey::new(name, labels))
            .or_default()
            .clone()
    }

    /// Sum of all counters registered under `name`, across labels.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, c)| c.get())
            .sum()
    }

    /// Merged snapshot of all histograms registered under `name`.
    pub fn histogram_total(&self, name: &str) -> HistogramSnapshot {
        let mut total = HistogramSnapshot::default();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            if k.name == name {
                total.merge(&h.snapshot());
            }
        }
        total
    }

    /// Render all metrics as text lines.
    ///
    /// Each metric name gets one aggregated line (`counter name value` /
    /// `hist name count … p99_s …`); when labelled variants exist they
    /// follow the aggregate, e.g. `counter requests_total{shard="1"} 42`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        {
            let counters = self.counters.lock().unwrap();
            for (name, group) in groups(&counters) {
                let total: u64 = group.iter().map(|(_, c)| c.get()).sum();
                out.push_str(&format!("counter {name} {total}\n"));
                if group.len() > 1 || !group[0].0.labels.is_empty() {
                    for (k, c) in group {
                        out.push_str(&format!(
                            "counter {} {}\n",
                            k.render_in_group(),
                            c.get()
                        ));
                    }
                }
            }
        }
        {
            let histograms = self.histograms.lock().unwrap();
            for (name, group) in groups(&histograms) {
                let mut total = HistogramSnapshot::default();
                for (_, h) in &group {
                    total.merge(&h.snapshot());
                }
                out.push_str(&total.render_line(name));
                if group.len() > 1 || !group[0].0.labels.is_empty() {
                    for (k, h) in group {
                        out.push_str(&h.snapshot().render_line(&k.render_in_group()));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn counter_set_overwrites_for_level_gauges() {
        let c = Counter::default();
        c.add(10);
        c.set(3);
        assert_eq!(c.get(), 3);
        c.set(0);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn counter_sub_saturates_at_zero() {
        let c = Counter::default();
        c.add(3);
        c.sub(1);
        assert_eq!(c.get(), 2);
        c.sub(10);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_secs(0.5);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p99);
        assert!(h.mean_secs() > 0.0);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        assert!(r.render().contains("counter a 2"));
    }

    #[test]
    fn labelled_counters_aggregate_in_render() {
        let r = Registry::default();
        r.counter_labelled("req", &[("shard", "0")]).add(3);
        r.counter_labelled("req", &[("shard", "1")]).add(4);
        r.counter("other").inc();
        assert_eq!(r.counter_total("req"), 7);
        let text = r.render();
        assert!(text.contains("counter req 7\n"), "{text}");
        assert!(text.contains("counter req{shard=\"0\"} 3\n"), "{text}");
        assert!(text.contains("counter req{shard=\"1\"} 4\n"), "{text}");
        // unlabelled metrics keep the legacy single-line format
        assert!(text.contains("counter other 1\n"), "{text}");
        assert!(!text.contains("other{"), "{text}");
    }

    #[test]
    fn mixed_labelled_and_unlabelled_render_unambiguously() {
        let r = Registry::default();
        r.counter("req").add(5);
        r.counter_labelled("req", &[("shard", "0")]).add(3);
        let text = r.render();
        // one aggregate line; the unlabelled variant renders as `req{}`
        // so no two `counter req ...` lines can carry different values
        assert!(text.contains("counter req 8\n"), "{text}");
        assert!(text.contains("counter req{} 5\n"), "{text}");
        assert!(text.contains("counter req{shard=\"0\"} 3\n"), "{text}");
        assert!(!text.contains("counter req 5"), "{text}");
    }

    #[test]
    fn labelled_histograms_merge() {
        let r = Registry::default();
        r.histogram_labelled("lat", &[("shard", "0")]).record_secs(1e-4);
        r.histogram_labelled("lat", &[("shard", "1")]).record_secs(1e-2);
        let total = r.histogram_total("lat");
        assert_eq!(total.count(), 2);
        assert!(total.mean_secs() > 1e-4 && total.mean_secs() < 1e-2);
        let text = r.render();
        assert!(text.contains("hist lat count 2"), "{text}");
        assert!(text.contains("hist lat{shard=\"0\"} count 1"), "{text}");
    }

    #[test]
    fn snapshot_merge_is_additive() {
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 1..=50 {
            a.record_secs(i as f64 * 1e-5);
            b.record_secs(i as f64 * 1e-3);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count(), 100);
        // merged p99 reflects the slow histogram's tail
        assert!(m.quantile_secs(0.99) >= b.snapshot().quantile_secs(0.5));
    }
}
