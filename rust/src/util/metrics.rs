//! Lightweight metrics: counters, level gauges and latency histograms for
//! the coordinator (request counts, shard liveness, per-stage latencies,
//! queue rejections).
//!
//! Metrics may carry labels (e.g. `shard="2"`): every shard of the
//! coordinator registers its own labelled instruments in one shared
//! [`Registry`], and [`Registry::render`] emits both the per-label lines
//! and an aggregated line per metric name (counter values summed,
//! histogram buckets merged), so a single `Request::Stats` snapshot shows
//! the whole server *and* each shard.
//!
//! Two text renderings exist side by side:
//!
//! * [`Registry::render`] — the compact `counter name value` /
//!   `gauge name value` / `hist name count … p99_s …` dump served by
//!   `Request::Stats` (human- and test-oriented, aggregate lines
//!   included).
//! * [`Registry::render_prometheus`] — Prometheus text exposition format
//!   0.0.4 (`# HELP`/`# TYPE`, cumulative `_bucket`/`_sum`/`_count`
//!   series derived from the log buckets), served over HTTP by the
//!   coordinator's `/metrics` endpoint. Only per-series lines are
//!   emitted (no aggregates — `sum()` is the scraper's job), and every
//!   family is prefixed `dfr_`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log-scale buckets (microsecond powers of two up to ~67 s).
///
/// Bucket `i` counts samples whose duration `d` satisfies
/// `2^(i-1) µs < d ≤ 2^i µs` (bucket 0: `d ≤ 1 µs`). The last bucket is
/// the overflow bucket: anything slower than `2^(BUCKETS-2)` µs lands
/// there, so the Prometheus rendering maps it onto `le="+Inf"`.
pub const BUCKETS: usize = 27;

/// Monotonic counter. Counters only ever go up — a level that can fall
/// (shard liveness, resident sessions, open connections) is a [`Gauge`].
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Level gauge: a value that rises *and* falls (shards currently alive,
/// sessions currently resident, connections currently open).
///
/// Unlike the old `Counter::set`/`sub` idiom this replaces, a gauge is
/// safe with several writers: `add`/`sub` are atomic read-modify-write
/// ops, so concurrent increments can never be lost to a racing `set`.
/// `set` remains available for single-writer republication (each shard
/// re-publishing its own labelled `resident_sessions{shard=…}` level).
#[derive(Default, Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    /// Overwrite the level. Only appropriate when this gauge instance has
    /// a single writer (labelled per-shard instances republished by their
    /// owning shard); multi-writer gauges must use `inc`/`dec`/`add`/`sub`.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Exact log₂ bucket index for a microsecond duration: bucket 0 holds
/// `us ≤ 1`, bucket `i ≥ 1` holds `2^(i-1) < us ≤ 2^i`, the last bucket
/// overflows.
fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        0
    } else {
        ((64 - (us - 1).leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Upper bound of bucket `i` in seconds.
fn bucket_upper_secs(i: usize) -> f64 {
    (1u64 << i) as f64 / 1e6
}

/// Log-scale latency histogram (microsecond buckets, powers of two up to
/// ~67 s). Lock-free recording.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_secs(&self, secs: f64) {
        self.record_us((secs * 1e6).max(0.0) as u64);
    }

    /// Record a duration already measured in whole microseconds (the
    /// tracer's native unit — skips the f64 round trip).
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        self.snapshot().mean_secs()
    }

    /// Approximate quantile from the log buckets (upper bound of bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.snapshot().quantile_secs(q)
    }

    /// Consistent-enough point-in-time copy (individual loads are relaxed;
    /// recording concurrently with a snapshot may skew one sample).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of a [`Histogram`]; snapshots of different histograms
/// (e.g. one per shard) can be merged into an aggregate view.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    buckets: [u64; BUCKETS],
    sum_us: u64,
    count: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: [0; BUCKETS],
            sum_us: 0,
            count: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Add another snapshot's samples into this one.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e6
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// bucket holding the target sample). `q = 0` is the first non-empty
    /// bucket's upper bound, `q = 1` the last non-empty bucket's; an
    /// empty histogram reports 0 for every quantile (no phantom 1 µs).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // target rank is at least 1: q=0 must select the first *sample*,
        // not trip `acc >= 0` on an empty leading bucket
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return bucket_upper_secs(i);
            }
        }
        bucket_upper_secs(BUCKETS - 1)
    }

    fn render_line(&self, key: &str) -> String {
        format!(
            "hist {key} count {} mean_s {:.6} p50_s {:.6} p99_s {:.6}\n",
            self.count,
            self.mean_secs(),
            self.quantile_secs(0.5),
            self.quantile_secs(0.99),
        )
    }
}

/// Metric identity: a name plus optional `key="value"` labels. Ordering is
/// name-major, so a [`BTreeMap`] keyed by `MetricKey` groups all labelled
/// variants of one name together.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct MetricKey {
    name: String,
    labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        MetricKey {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        }
    }

    /// Rendering used for per-variant lines inside a name group. The
    /// unlabelled variant renders as `name{}` so it can never be confused
    /// with the group's aggregate `name` line.
    fn render_in_group(&self) -> String {
        let l: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{v}\""))
            .collect();
        format!("{}{{{}}}", self.name, l.join(","))
    }

    /// Prometheus label block (`{k="v",…}`), empty string when unlabelled,
    /// label values escaped per the exposition format.
    fn prom_labels(&self, extra: Option<(&str, &str)>) -> String {
        let mut parts: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{}=\"{}\"", prom_name_sanitize(k), prom_escape(v)))
            .collect();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{}\"", prom_escape(v)));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }
}

/// Sanitize a metric/label name into the Prometheus charset
/// `[a-zA-Z_][a-zA-Z0-9_]*`.
fn prom_name_sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic() || c == '_' || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Escape a label value per the text exposition format: backslash, double
/// quote and newline.
fn prom_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Family name for the exposition: `dfr_` prefix plus the sanitized
/// registry name.
fn prom_family(name: &str) -> String {
    format!("dfr_{}", prom_name_sanitize(name))
}

/// Group a name-sorted metric map into per-name runs (`BTreeMap` keyed by
/// [`MetricKey`] is name-major, so one linear pass suffices).
fn groups<V>(map: &BTreeMap<MetricKey, V>) -> Vec<(&str, Vec<(&MetricKey, &V)>)> {
    let mut out: Vec<(&str, Vec<(&MetricKey, &V)>)> = Vec::new();
    for (k, v) in map {
        match out.last_mut() {
            Some((name, group)) if *name == k.name => group.push((k, v)),
            _ => out.push((k.name.as_str(), vec![(k, v)])),
        }
    }
    out
}

/// A named registry of counters, gauges and histograms, shared across
/// threads.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<MetricKey, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<MetricKey, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<MetricKey, Arc<Histogram>>>,
}

impl Registry {
    /// Unlabelled counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_labelled(name, &[])
    }

    /// Counter with labels, e.g. `counter_labelled("requests_total", &[("shard", "0")])`.
    pub fn counter_labelled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(MetricKey::new(name, labels))
            .or_default()
            .clone()
    }

    /// Unlabelled level gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauge_labelled(name, &[])
    }

    /// Gauge with labels, e.g. `gauge_labelled("resident_sessions", &[("shard", "0")])`.
    pub fn gauge_labelled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(MetricKey::new(name, labels))
            .or_default()
            .clone()
    }

    /// Unlabelled histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_labelled(name, &[])
    }

    /// Histogram with labels.
    pub fn histogram_labelled(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(MetricKey::new(name, labels))
            .or_default()
            .clone()
    }

    /// Sum of all counters registered under `name`, across labels.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, c)| c.get())
            .sum()
    }

    /// Sum of all gauges registered under `name`, across labels.
    pub fn gauge_total(&self, name: &str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, g)| g.get())
            .sum()
    }

    /// Merged snapshot of all histograms registered under `name`.
    pub fn histogram_total(&self, name: &str) -> HistogramSnapshot {
        let mut total = HistogramSnapshot::default();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            if k.name == name {
                total.merge(&h.snapshot());
            }
        }
        total
    }

    /// Render all metrics as compact text lines.
    ///
    /// Each metric name gets one aggregated line (`counter name value` /
    /// `gauge name value` / `hist name count … p99_s …`); when labelled
    /// variants exist they follow the aggregate, e.g.
    /// `counter requests_total{shard="1"} 42`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        {
            let counters = self.counters.lock().unwrap();
            for (name, group) in groups(&counters) {
                let total: u64 = group.iter().map(|(_, c)| c.get()).sum();
                out.push_str(&format!("counter {name} {total}\n"));
                if group.len() > 1 || !group[0].0.labels.is_empty() {
                    for (k, c) in group {
                        out.push_str(&format!(
                            "counter {} {}\n",
                            k.render_in_group(),
                            c.get()
                        ));
                    }
                }
            }
        }
        {
            let gauges = self.gauges.lock().unwrap();
            for (name, group) in groups(&gauges) {
                let total: i64 = group.iter().map(|(_, g)| g.get()).sum();
                out.push_str(&format!("gauge {name} {total}\n"));
                if group.len() > 1 || !group[0].0.labels.is_empty() {
                    for (k, g) in group {
                        out.push_str(&format!("gauge {} {}\n", k.render_in_group(), g.get()));
                    }
                }
            }
        }
        {
            let histograms = self.histograms.lock().unwrap();
            for (name, group) in groups(&histograms) {
                let mut total = HistogramSnapshot::default();
                for (_, h) in &group {
                    total.merge(&h.snapshot());
                }
                out.push_str(&total.render_line(name));
                if group.len() > 1 || !group[0].0.labels.is_empty() {
                    for (k, h) in group {
                        out.push_str(&h.snapshot().render_line(&k.render_in_group()));
                    }
                }
            }
        }
        out
    }

    /// Render all metrics in the Prometheus text exposition format 0.0.4.
    ///
    /// * every family is prefixed `dfr_` and announced by `# HELP` /
    ///   `# TYPE` lines;
    /// * only per-series lines are emitted (no aggregate duplicates —
    ///   `sum by (…)` is the scraper's job);
    /// * histograms become `<family>_seconds` with cumulative
    ///   `_bucket{le="…"}` lines derived from the log₂-µs buckets
    ///   (upper bound of bucket `i` is `2^i` µs), the overflow bucket
    ///   mapped onto `le="+Inf"`, plus `_sum` (seconds) and `_count`.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        {
            let counters = self.counters.lock().unwrap();
            for (name, group) in groups(&counters) {
                let fam = prom_family(name);
                out.push_str(&format!(
                    "# HELP {fam} Counter `{name}` from the dfr-edge registry.\n# TYPE {fam} counter\n"
                ));
                for (k, c) in group {
                    out.push_str(&format!("{fam}{} {}\n", k.prom_labels(None), c.get()));
                }
            }
        }
        {
            let gauges = self.gauges.lock().unwrap();
            for (name, group) in groups(&gauges) {
                let fam = prom_family(name);
                out.push_str(&format!(
                    "# HELP {fam} Level gauge `{name}` from the dfr-edge registry.\n# TYPE {fam} gauge\n"
                ));
                for (k, g) in group {
                    out.push_str(&format!("{fam}{} {}\n", k.prom_labels(None), g.get()));
                }
            }
        }
        {
            let histograms = self.histograms.lock().unwrap();
            for (name, group) in groups(&histograms) {
                let fam = if name.ends_with("_seconds") {
                    prom_family(name)
                } else {
                    format!("{}_seconds", prom_family(name))
                };
                out.push_str(&format!(
                    "# HELP {fam} Log2-microsecond-bucket histogram `{name}` from the dfr-edge registry.\n# TYPE {fam} histogram\n"
                ));
                for (k, h) in group {
                    let snap = h.snapshot();
                    let mut acc = 0u64;
                    // buckets 0..BUCKETS-2 carry honest upper bounds; the
                    // overflow bucket only reports under +Inf
                    for (i, b) in snap.buckets.iter().enumerate().take(BUCKETS - 1) {
                        acc += b;
                        out.push_str(&format!(
                            "{fam}_bucket{} {acc}\n",
                            k.prom_labels(Some(("le", &format!("{}", bucket_upper_secs(i))))),
                        ));
                    }
                    out.push_str(&format!(
                        "{fam}_bucket{} {}\n",
                        k.prom_labels(Some(("le", "+Inf"))),
                        snap.count,
                    ));
                    out.push_str(&format!(
                        "{fam}_sum{} {}\n",
                        k.prom_labels(None),
                        snap.sum_us as f64 / 1e6,
                    ));
                    out.push_str(&format!(
                        "{fam}_count{} {}\n",
                        k.prom_labels(None),
                        snap.count,
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_rises_and_falls() {
        let g = Gauge::default();
        g.add(3);
        g.dec();
        assert_eq!(g.get(), 2);
        g.sub(10);
        assert_eq!(g.get(), -8, "gauges may legitimately go negative");
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn bucket_indexing_is_exact_at_the_edges() {
        // sub-µs and exactly-1-µs samples land in bucket 0 …
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        // … and each power of two is the *upper* bound of its bucket
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 25), 25);
        assert_eq!(bucket_index((1 << 25) + 1), 26);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn sub_microsecond_samples_reach_bucket_zero() {
        let h = Histogram::default();
        h.record_secs(0.0);
        h.record_secs(5e-7);
        h.record_secs(1e-6);
        // all three sit in bucket 0, so every quantile is its 1 µs bound
        assert_eq!(h.quantile_secs(0.0), 1e-6);
        assert_eq!(h.quantile_secs(1.0), 1e-6);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn quantile_q0_is_not_phantom() {
        let h = Histogram::default();
        // a single slow sample: bucket 0 is empty, so q=0 must NOT
        // report the old phantom 1 µs from tripping `acc >= 0`
        h.record_secs(1.0);
        let q0 = h.quantile_secs(0.0);
        assert!(q0 >= 1.0, "q=0 fell into an empty leading bucket: {q0}");
        assert_eq!(h.quantile_secs(0.0), h.quantile_secs(1.0));
        // and an empty histogram reports 0 for every quantile
        let e = Histogram::default();
        assert_eq!(e.quantile_secs(0.0), 0.0);
        assert_eq!(e.quantile_secs(1.0), 0.0);
    }

    #[test]
    fn quantile_q1_hits_last_nonempty_bucket() {
        let h = Histogram::default();
        h.record_secs(1e-6); // bucket 0
        h.record_secs(3e-3); // ~3 ms
        assert_eq!(h.quantile_secs(0.0), 1e-6);
        let q1 = h.quantile_secs(1.0);
        assert!(q1 >= 3e-3 && q1 < 1e-2, "{q1}");
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_secs(0.5);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p99);
        assert!(h.mean_secs() > 0.0);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        assert!(r.render().contains("counter a 2"));
    }

    #[test]
    fn labelled_counters_aggregate_in_render() {
        let r = Registry::default();
        r.counter_labelled("req", &[("shard", "0")]).add(3);
        r.counter_labelled("req", &[("shard", "1")]).add(4);
        r.counter("other").inc();
        assert_eq!(r.counter_total("req"), 7);
        let text = r.render();
        assert!(text.contains("counter req 7\n"), "{text}");
        assert!(text.contains("counter req{shard=\"0\"} 3\n"), "{text}");
        assert!(text.contains("counter req{shard=\"1\"} 4\n"), "{text}");
        // unlabelled metrics keep the legacy single-line format
        assert!(text.contains("counter other 1\n"), "{text}");
        assert!(!text.contains("other{"), "{text}");
    }

    #[test]
    fn gauges_render_with_aggregate() {
        let r = Registry::default();
        r.gauge_labelled("live", &[("shard", "0")]).set(2);
        r.gauge_labelled("live", &[("shard", "1")]).set(1);
        assert_eq!(r.gauge_total("live"), 3);
        let text = r.render();
        assert!(text.contains("gauge live 3\n"), "{text}");
        assert!(text.contains("gauge live{shard=\"0\"} 2\n"), "{text}");
        assert!(text.contains("gauge live{shard=\"1\"} 1\n"), "{text}");
    }

    #[test]
    fn mixed_labelled_and_unlabelled_render_unambiguously() {
        let r = Registry::default();
        r.counter("req").add(5);
        r.counter_labelled("req", &[("shard", "0")]).add(3);
        let text = r.render();
        // one aggregate line; the unlabelled variant renders as `req{}`
        // so no two `counter req ...` lines can carry different values
        assert!(text.contains("counter req 8\n"), "{text}");
        assert!(text.contains("counter req{} 5\n"), "{text}");
        assert!(text.contains("counter req{shard=\"0\"} 3\n"), "{text}");
        assert!(!text.contains("counter req 5"), "{text}");
    }

    #[test]
    fn labelled_histograms_merge() {
        let r = Registry::default();
        r.histogram_labelled("lat", &[("shard", "0")]).record_secs(1e-4);
        r.histogram_labelled("lat", &[("shard", "1")]).record_secs(1e-2);
        let total = r.histogram_total("lat");
        assert_eq!(total.count(), 2);
        assert!(total.mean_secs() > 1e-4 && total.mean_secs() < 1e-2);
        let text = r.render();
        assert!(text.contains("hist lat count 2"), "{text}");
        assert!(text.contains("hist lat{shard=\"0\"} count 1"), "{text}");
    }

    #[test]
    fn snapshot_merge_is_additive() {
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 1..=50 {
            a.record_secs(i as f64 * 1e-5);
            b.record_secs(i as f64 * 1e-3);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count(), 100);
        // merged p99 reflects the slow histogram's tail
        assert!(m.quantile_secs(0.99) >= b.snapshot().quantile_secs(0.5));
    }

    #[test]
    fn prometheus_families_are_typed_and_prefixed() {
        let r = Registry::default();
        r.counter_labelled("req_total", &[("shard", "0")]).add(3);
        r.gauge("live").set(2);
        r.histogram("lat").record_secs(1e-3);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE dfr_req_total counter\n"), "{text}");
        assert!(text.contains("dfr_req_total{shard=\"0\"} 3\n"), "{text}");
        assert!(text.contains("# TYPE dfr_live gauge\n"), "{text}");
        assert!(text.contains("dfr_live 2\n"), "{text}");
        assert!(text.contains("# TYPE dfr_lat_seconds histogram\n"), "{text}");
        assert!(text.contains("dfr_lat_seconds_count 1\n"), "{text}");
        // no aggregate duplicates: exactly one series line per family
        assert_eq!(text.matches("\ndfr_req_total").count(), 1, "{text}");
    }

    #[test]
    fn prometheus_buckets_are_cumulative_and_capped_by_inf() {
        let r = Registry::default();
        let h = r.histogram("lat");
        h.record_secs(5e-7); // bucket 0
        h.record_secs(3e-6); // bucket 2
        h.record_secs(1e2); // overflow bucket -> only under +Inf
        let text = r.render_prometheus();
        assert!(
            text.contains("dfr_lat_seconds_bucket{le=\"0.000001\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("dfr_lat_seconds_bucket{le=\"0.000004\"} 2\n"),
            "{text}"
        );
        // the honest-bound buckets never claim the overflow sample …
        assert!(
            text.contains("dfr_lat_seconds_bucket{le=\"33.554432\"} 2\n"),
            "{text}"
        );
        // … which appears only under +Inf
        assert!(text.contains("dfr_lat_seconds_bucket{le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("dfr_lat_seconds_count 3\n"), "{text}");
    }

    #[test]
    fn prometheus_escapes_label_values() {
        let r = Registry::default();
        r.counter_labelled("c", &[("path", "a\"b\\c\nd")]).inc();
        let text = r.render_prometheus();
        assert!(text.contains("dfr_c{path=\"a\\\"b\\\\c\\nd\"} 1\n"), "{text}");
    }
}
