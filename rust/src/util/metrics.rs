//! Lightweight metrics: counters and latency histograms for the
//! coordinator (queue depths, batch sizes, per-stage latencies).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-scale latency histogram (microsecond buckets, powers of two up to
/// ~67 s). Lock-free recording.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 27],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_secs(&self, secs: f64) {
        let us = (secs * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile from the log buckets (upper bound of bucket).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << i) as f64 / 1e6;
            }
        }
        (1u64 << (self.buckets.len() - 1)) as f64 / 1e6
    }
}

/// A named registry of counters and histograms.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Render all metrics as text lines (`name value`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", c.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {k} count {} mean_s {:.6} p50_s {:.6} p99_s {:.6}\n",
                h.count(),
                h.mean_secs(),
                h.quantile_secs(0.5),
                h.quantile_secs(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_secs(0.5);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p99);
        assert!(h.mean_secs() > 0.0);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        assert!(r.render().contains("counter a 2"));
    }
}
