//! Tiny property-testing driver (no proptest crate in the image).
//!
//! Runs a property over N generated cases with deterministic seeds and, on
//! failure, performs a simple halving shrink on the seed's size parameter
//! to report the smallest failing size. Used for the linalg, dfr and
//! coordinator invariant suites.

use super::prng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
    /// maximum "size" hint passed to the generator (e.g. matrix dim)
    pub max_size: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xDF12_ED6E_u64,
            max_size: 24,
        }
    }
}

/// Run `prop(rng, size)`; the property returns `Err(msg)` on violation.
///
/// Panics with a reproduction line on failure.
pub fn run_prop<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Pcg32, u32) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let size = 1 + (case % cfg.max_size);
        let mut rng = Pcg32::new(cfg.seed.wrapping_add(u64::from(case)), u64::from(case));
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: try smaller sizes with the same seed
            let mut min_fail = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng2 =
                    Pcg32::new(cfg.seed.wrapping_add(u64::from(case)), u64::from(case));
                match prop(&mut rng2, s) {
                    Err(m) => {
                        min_fail = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, size {}, seed {}): {}",
                min_fail.0,
                cfg.seed.wrapping_add(u64::from(case)),
                min_fail.1
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run_prop("trivial", Config::default(), |_, _| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, Config::default().cases);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_repro() {
        run_prop("fails", Config::default(), |_, size| {
            if size >= 3 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
    }
}
