//! PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! Deterministic, seedable and splittable into independent streams — every
//! stochastic component in the system (mask generation, dataset synthesis,
//! SGD shuffling, property tests) draws from here so whole experiments are
//! reproducible from a single seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a state and a stream id; distinct stream ids give
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-argument constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// The raw `(state, inc)` pair — everything the generator is. Paired
    /// with [`from_state_parts`](Self::from_state_parts) so a checkpoint
    /// can restore a generator that continues the *exact* draw sequence
    /// (the coordinator's session snapshots depend on this for bitwise
    /// restart equivalence).
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`state_parts`](Self::state_parts)
    /// output. No seeding/warm-up runs: the next `next_u32` continues
    /// where the exported generator left off.
    pub fn from_state_parts(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    /// Derive an independent child generator (used to give each dataset /
    /// sample / epoch its own stream without coupling draw counts).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let s = (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32());
        Pcg32::new(s ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u32) -> u32 {
        ((u64::from(self.next_u32()) * u64::from(n)) >> 32) as u32
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f32 {
        // no cache to keep the struct Copy-light; two draws per call is fine
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * core::f32::consts::PI * u2).cos();
            }
        }
    }

    /// ±1 with equal probability — the paper's binary mask elements (Fig. 2).
    pub fn sign(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle (used for SGD epoch ordering).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg32::seed(1);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += f64::from(u);
        }
        let mean = sum / f64::from(n);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seed(2);
        let n = 40_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = f64::from(rng.normal());
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / f64::from(n);
        let var = s2 / f64::from(n) - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg32::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Pcg32::seed(4);
        let n = 10_000;
        let pos = (0..n).filter(|_| rng.sign() > 0.0).count();
        let frac = pos as f64 / f64::from(n);
        assert!((frac - 0.5).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seed(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_parts_roundtrip_continues_sequence() {
        let mut a = Pcg32::new(0xFEED, 3);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg32::seed(6);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
