//! Datasets: Table 4 profiles, synthetic generators substituting the
//! UEA/UCR npz benchmark sets, and npy/npz IO.
//!
//! The paper evaluates on 12 multivariate time-series classification
//! datasets distributed as npz files by Bianchi et al. [6]. Those files
//! are not redistributable here, so [`synth`] generates class-conditional
//! surrogates with **exactly** the shape statistics of Table 4 (#V, #C,
//! Train, Test, T_min, T_max) — see DESIGN.md §3 for why that preserves
//! each experiment's behaviour. [`npz`] reads/writes real npy/npz so the
//! pipeline also accepts the original files when available.

pub mod dataset;
pub mod npz;
pub mod profiles;
pub mod synth;
pub mod zipstore;

pub use dataset::{Dataset, Sample};
pub use profiles::{Profile, PROFILES};
