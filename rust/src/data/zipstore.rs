//! Minimal zip container for npz files — stored (method 0) entries only.
//!
//! The crate's only npz producers/consumers are `numpy.savez` (which
//! writes STORED entries — `np.savez_compressed` is the deflated
//! variant) and our own golden/test fixtures, so a dependency-free
//! subset of the zip format suffices: the reader walks the end-of-
//! central-directory record and the central directory (the local
//! headers are consulted only for their variable-length name/extra
//! fields, because `zipfile` with `force_zip64` pads local headers with
//! a zip64 extra that the central directory does not carry), verifies
//! CRC-32, and rejects any compression method other than stored with a
//! pointed error. The writer emits local headers with exact sizes (no
//! data descriptors, no zip64 — fixtures are far below 4 GiB), a
//! central directory and the EOCD, which CPython's `zipfile`/numpy read
//! back verbatim.

use anyhow::{bail, Context, Result};

/// One stored entry.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub data: Vec<u8>,
}

const LOCAL_SIG: u32 = 0x0403_4b50;
const CENTRAL_SIG: u32 = 0x0201_4b50;
const EOCD_SIG: u32 = 0x0605_4b50;

#[inline]
fn u16le(b: &[u8], at: usize) -> usize {
    u16::from_le_bytes([b[at], b[at + 1]]) as usize
}

#[inline]
fn u32le(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse a zip archive held in memory into its stored entries.
pub fn read_archive(buf: &[u8]) -> Result<Vec<Entry>> {
    // EOCD: fixed 22 bytes + trailing comment; scan backwards for the
    // signature (the comment, if any, is at most 64 KiB).
    if buf.len() < 22 {
        bail!("zip too short ({} bytes)", buf.len());
    }
    let floor = buf.len().saturating_sub(22 + u16::MAX as usize);
    let mut eocd = None;
    let mut at = buf.len() - 22;
    loop {
        if u32le(buf, at) == EOCD_SIG {
            eocd = Some(at);
            break;
        }
        if at == floor {
            break;
        }
        at -= 1;
    }
    let eocd = eocd.context("zip: end-of-central-directory record not found")?;
    let entries = u16le(buf, eocd + 10);
    let cd_off = u32le(buf, eocd + 16) as usize;
    if cd_off > buf.len() {
        bail!("zip: central directory offset {cd_off} out of range");
    }

    let mut out = Vec::with_capacity(entries);
    let mut cd = cd_off;
    for _ in 0..entries {
        if cd + 46 > buf.len() || u32le(buf, cd) != CENTRAL_SIG {
            bail!("zip: bad central-directory entry at {cd}");
        }
        let method = u16le(buf, cd + 10);
        let crc = u32le(buf, cd + 16);
        let csize = u32le(buf, cd + 20) as usize;
        let usize_ = u32le(buf, cd + 24) as usize;
        let name_len = u16le(buf, cd + 28);
        let extra_len = u16le(buf, cd + 30);
        let comment_len = u16le(buf, cd + 32);
        let local_off = u32le(buf, cd + 42) as usize;
        if cd + 46 + name_len > buf.len() {
            bail!("zip: central-directory name truncated at {cd}");
        }
        let name = std::str::from_utf8(&buf[cd + 46..cd + 46 + name_len])
            .context("zip: entry name not utf-8")?
            .to_string();
        if method != 0 {
            bail!(
                "zip entry {name:?} uses compression method {method}; only stored \
                 (method 0) npz is supported — re-save with np.savez, not \
                 np.savez_compressed"
            );
        }
        if csize != usize_ {
            bail!("zip entry {name:?}: stored sizes disagree ({csize} vs {usize_})");
        }
        // local header: skip its own (possibly zip64-padded) name+extra
        if local_off + 30 > buf.len() || u32le(buf, local_off) != LOCAL_SIG {
            bail!("zip entry {name:?}: bad local header at {local_off}");
        }
        let l_name = u16le(buf, local_off + 26);
        let l_extra = u16le(buf, local_off + 28);
        let data_at = local_off + 30 + l_name + l_extra;
        if data_at + csize > buf.len() {
            bail!("zip entry {name:?}: payload truncated");
        }
        let data = buf[data_at..data_at + csize].to_vec();
        if crc32(&data) != crc {
            bail!("zip entry {name:?}: CRC-32 mismatch (corrupt archive)");
        }
        out.push(Entry { name, data });
        cd += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

/// Serialize entries as a stored zip archive (what `zipfile` reads back).
pub fn write_archive(entries: &[Entry]) -> Vec<u8> {
    let payload: usize = entries.iter().map(|e| 30 + e.name.len() + e.data.len()).sum();
    let central: usize = entries.iter().map(|e| 46 + e.name.len()).sum();
    let mut buf = Vec::with_capacity(payload + central + 22);
    let mut offsets = Vec::with_capacity(entries.len());
    for e in entries {
        offsets.push(buf.len() as u32);
        let crc = crc32(&e.data);
        buf.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes()); // version needed
        buf.extend_from_slice(&0u16.to_le_bytes()); // flags
        buf.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        buf.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes()); // csize
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes()); // usize
        buf.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes()); // extra len
        buf.extend_from_slice(e.name.as_bytes());
        buf.extend_from_slice(&e.data);
    }
    let cd_off = buf.len() as u32;
    for (e, off) in entries.iter().zip(&offsets) {
        let crc = crc32(&e.data);
        buf.extend_from_slice(&CENTRAL_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes()); // version made by
        buf.extend_from_slice(&20u16.to_le_bytes()); // version needed
        buf.extend_from_slice(&0u16.to_le_bytes()); // flags
        buf.extend_from_slice(&0u16.to_le_bytes()); // method
        buf.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes()); // extra
        buf.extend_from_slice(&0u16.to_le_bytes()); // comment
        buf.extend_from_slice(&0u16.to_le_bytes()); // disk
        buf.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        buf.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        buf.extend_from_slice(&off.to_le_bytes());
        buf.extend_from_slice(e.name.as_bytes());
    }
    let cd_size = buf.len() as u32 - cd_off;
    buf.extend_from_slice(&EOCD_SIG.to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // this disk
    buf.extend_from_slice(&0u16.to_le_bytes()); // cd disk
    buf.extend_from_slice(&(entries.len() as u16).to_le_bytes());
    buf.extend_from_slice(&(entries.len() as u16).to_le_bytes());
    buf.extend_from_slice(&cd_size.to_le_bytes());
    buf.extend_from_slice(&cd_off.to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // comment len
    buf
}

/// CRC-32 (IEEE 802.3, the zip polynomial), bytewise with a lazily-built
/// 256-entry table — fixture-sized archives don't justify slicing-by-8.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard test vectors for the IEEE polynomial
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn roundtrip_multiple_entries() {
        let entries = vec![
            Entry { name: "a.npy".into(), data: vec![1, 2, 3, 4, 5] },
            Entry { name: "b.npy".into(), data: vec![] },
            Entry { name: "dir/c.npy".into(), data: (0..=255).collect() },
        ];
        let buf = write_archive(&entries);
        let back = read_archive(&buf).unwrap();
        assert_eq!(back.len(), 3);
        for (e, b) in entries.iter().zip(&back) {
            assert_eq!(e.name, b.name);
            assert_eq!(e.data, b.data);
        }
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let entries = vec![Entry { name: "x".into(), data: vec![9; 64] }];
        let mut buf = write_archive(&entries);
        // flip a payload byte (local header is 30 bytes + 1-byte name)
        buf[31 + 7] ^= 0x40;
        let err = read_archive(&buf).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");
    }

    #[test]
    fn rejects_deflate_method() {
        let entries = vec![Entry { name: "x".into(), data: vec![1, 2, 3] }];
        let mut buf = write_archive(&entries);
        // patch method field in both local header (offset 8) and the
        // central directory entry (offset 10 within the CD record)
        buf[8] = 8;
        let cd = 30 + 1 + 3; // one local header + name + data
        buf[cd + 10] = 8;
        let err = read_archive(&buf).unwrap_err().to_string();
        assert!(err.contains("method 8"), "{err}");
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(read_archive(b"PK").is_err());
        assert!(read_archive(&[0u8; 64]).is_err());
    }

    #[test]
    fn empty_archive_roundtrips() {
        // a 22-byte EOCD-only archive is a VALID zip with zero entries
        // (numpy never writes one, but tooling may) — tolerate, not panic
        let buf = write_archive(&[]);
        assert_eq!(buf.len(), 22);
        let back = read_archive(&buf).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn truncated_eocd_is_an_error() {
        let buf = write_archive(&[]);
        for cut in [0usize, 1, 10, 21] {
            assert!(read_archive(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn zero_length_member_roundtrips_and_detects_crc_tamper() {
        let entries = vec![
            Entry { name: "empty.npy".into(), data: vec![] },
            Entry { name: "tail".into(), data: vec![7; 9] },
        ];
        let buf = write_archive(&entries);
        let back = read_archive(&buf).unwrap();
        assert_eq!(back[0].name, "empty.npy");
        assert!(back[0].data.is_empty());
        assert_eq!(back[1].data, vec![7; 9]);
        // corrupt the central-directory CRC of the zero-length member:
        // CRC-32 of b"" is 0, so flip a byte → mismatch error, no panic
        let cd_off = {
            let eocd = buf.len() - 22;
            u32::from_le_bytes([buf[eocd + 16], buf[eocd + 17], buf[eocd + 18], buf[eocd + 19]])
                as usize
        };
        let mut bad = buf.clone();
        bad[cd_off + 16] ^= 0x01; // first CRC byte of entry 0
        let err = read_archive(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");
    }

    #[test]
    fn lying_entry_count_is_an_error_not_a_panic() {
        let entries = vec![Entry { name: "x".into(), data: vec![1, 2, 3] }];
        let mut buf = write_archive(&entries);
        // EOCD total-entry count at offset 10: claim 5 entries where the
        // central directory holds 1 — the reader must bail on the walk
        let eocd = buf.len() - 22;
        buf[eocd + 10] = 5;
        assert!(read_archive(&buf).is_err());
    }

    #[test]
    fn out_of_range_central_directory_offset_is_an_error() {
        let entries = vec![Entry { name: "x".into(), data: vec![1] }];
        let mut buf = write_archive(&entries);
        let eocd = buf.len() - 22;
        // point the CD offset past the end of the buffer
        buf[eocd + 16..eocd + 20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_archive(&buf).is_err());
        // and at the EOCD itself (not a CD signature)
        let mut buf2 = write_archive(&entries);
        let off = (buf2.len() - 22) as u32;
        let eocd2 = buf2.len() - 22;
        buf2[eocd2 + 16..eocd2 + 20].copy_from_slice(&off.to_le_bytes());
        assert!(read_archive(&buf2).is_err());
    }
}
