//! Minimal zip container for npz files — stored (method 0) entries only.
//!
//! The crate's only npz producers/consumers are `numpy.savez` (which
//! writes STORED entries — `np.savez_compressed` is the deflated
//! variant) and our own golden/test fixtures, so a dependency-free
//! subset of the zip format suffices: the reader walks the end-of-
//! central-directory record and the central directory (the local
//! headers are consulted only for their variable-length name/extra
//! fields, because `zipfile` with `force_zip64` pads local headers with
//! a zip64 extra that the central directory does not carry), verifies
//! CRC-32, and rejects any compression method other than stored with a
//! pointed error. The writer emits local headers with exact sizes (no
//! data descriptors, no zip64), a central directory and the EOCD, which
//! CPython's `zipfile`/numpy read back verbatim.
//!
//! The classic (non-zip64) format caps the entry count at `u16::MAX` and
//! every size/offset at `u32::MAX`. [`write_archive`] **refuses** inputs
//! beyond those limits with a typed [`ZipWriteError`] instead of
//! truncating the casts — an archive that silently decodes short (an
//! EOCD claiming `70_000 % 65_536` entries) is corruption, not output.
//! The session-hibernation store (`coordinator::hibernate`) stays under
//! the caps by bucketing sessions across many archives.

use std::fmt;

use anyhow::{bail, Context, Result};

/// One stored entry.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub data: Vec<u8>,
}

const LOCAL_SIG: u32 = 0x0403_4b50;
const CENTRAL_SIG: u32 = 0x0201_4b50;
const EOCD_SIG: u32 = 0x0605_4b50;

#[inline]
fn u16le(b: &[u8], at: usize) -> usize {
    u16::from_le_bytes([b[at], b[at + 1]]) as usize
}

#[inline]
fn u32le(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// Parse a zip archive held in memory into its stored entries.
pub fn read_archive(buf: &[u8]) -> Result<Vec<Entry>> {
    // EOCD: fixed 22 bytes + trailing comment; scan backwards for the
    // signature (the comment, if any, is at most 64 KiB).
    if buf.len() < 22 {
        bail!("zip too short ({} bytes)", buf.len());
    }
    let floor = buf.len().saturating_sub(22 + u16::MAX as usize);
    let mut eocd = None;
    let mut at = buf.len() - 22;
    loop {
        if u32le(buf, at) == EOCD_SIG {
            eocd = Some(at);
            break;
        }
        if at == floor {
            break;
        }
        at -= 1;
    }
    let eocd = eocd.context("zip: end-of-central-directory record not found")?;
    let entries = u16le(buf, eocd + 10);
    let cd_off = u32le(buf, eocd + 16) as usize;
    if cd_off > buf.len() {
        bail!("zip: central directory offset {cd_off} out of range");
    }

    let mut out = Vec::with_capacity(entries);
    let mut cd = cd_off;
    for _ in 0..entries {
        if cd + 46 > buf.len() || u32le(buf, cd) != CENTRAL_SIG {
            bail!("zip: bad central-directory entry at {cd}");
        }
        let method = u16le(buf, cd + 10);
        let crc = u32le(buf, cd + 16);
        let csize = u32le(buf, cd + 20) as usize;
        let usize_ = u32le(buf, cd + 24) as usize;
        let name_len = u16le(buf, cd + 28);
        let extra_len = u16le(buf, cd + 30);
        let comment_len = u16le(buf, cd + 32);
        let local_off = u32le(buf, cd + 42) as usize;
        if cd + 46 + name_len > buf.len() {
            bail!("zip: central-directory name truncated at {cd}");
        }
        let name = std::str::from_utf8(&buf[cd + 46..cd + 46 + name_len])
            .context("zip: entry name not utf-8")?
            .to_string();
        if method != 0 {
            bail!(
                "zip entry {name:?} uses compression method {method}; only stored \
                 (method 0) npz is supported — re-save with np.savez, not \
                 np.savez_compressed"
            );
        }
        if csize != usize_ {
            bail!("zip entry {name:?}: stored sizes disagree ({csize} vs {usize_})");
        }
        // local header: skip its own (possibly zip64-padded) name+extra
        if local_off + 30 > buf.len() || u32le(buf, local_off) != LOCAL_SIG {
            bail!("zip entry {name:?}: bad local header at {local_off}");
        }
        let l_name = u16le(buf, local_off + 26);
        let l_extra = u16le(buf, local_off + 28);
        let data_at = local_off + 30 + l_name + l_extra;
        if data_at + csize > buf.len() {
            bail!("zip entry {name:?}: payload truncated");
        }
        let data = buf[data_at..data_at + csize].to_vec();
        if crc32(&data) != crc {
            bail!("zip entry {name:?}: CRC-32 mismatch (corrupt archive)");
        }
        out.push(Entry { name, data });
        cd += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

/// Why [`write_archive`] refused to emit an archive. Each variant is a
/// hard limit of the classic zip format — proceeding would require
/// truncating a count/size/offset field and emitting a corrupt file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ZipWriteError {
    /// more entries than the EOCD's u16 entry-count field can carry
    TooManyEntries { count: usize },
    /// one entry's payload exceeds the u32 size fields
    EntryTooLarge { name: String, bytes: u64 },
    /// one entry's name exceeds the u16 name-length field
    NameTooLong { name_prefix: String, len: usize },
    /// local-header offsets / the central directory would pass u32
    ArchiveTooLarge { bytes: u64 },
}

impl fmt::Display for ZipWriteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ZipWriteError::TooManyEntries { count } => write!(
                f,
                "zip: {count} entries exceed the format's {} cap (no zip64)",
                u16::MAX
            ),
            ZipWriteError::EntryTooLarge { name, bytes } => write!(
                f,
                "zip: entry {name:?} is {bytes} bytes, beyond the u32 size field"
            ),
            ZipWriteError::NameTooLong { name_prefix, len } => write!(
                f,
                "zip: entry name {name_prefix:?}… is {len} bytes, beyond the u16 name field"
            ),
            ZipWriteError::ArchiveTooLarge { bytes } => write!(
                f,
                "zip: archive would be {bytes} bytes, beyond the u32 offset fields"
            ),
        }
    }
}

impl std::error::Error for ZipWriteError {}

/// Check that `count` entries with the given `(name, data_len)` shapes
/// fit the classic zip field widths. Pure arithmetic over metadata, so
/// the >4 GiB paths are unit-testable without allocating gigabytes.
fn check_limits<'a>(
    shapes: impl Iterator<Item = (&'a str, u64)>,
    count: usize,
) -> Result<(), ZipWriteError> {
    if count > u16::MAX as usize {
        return Err(ZipWriteError::TooManyEntries { count });
    }
    let mut payload: u64 = 0;
    let mut central: u64 = 22;
    for (name, data_len) in shapes {
        if name.len() > u16::MAX as usize {
            return Err(ZipWriteError::NameTooLong {
                name_prefix: name.chars().take(32).collect(),
                len: name.len(),
            });
        }
        if data_len > u64::from(u32::MAX) {
            return Err(ZipWriteError::EntryTooLarge {
                name: name.to_string(),
                bytes: data_len,
            });
        }
        payload += 30 + name.len() as u64 + data_len;
        central += 46 + name.len() as u64;
    }
    // every local-header offset is < payload, and the EOCD's cd_off /
    // cd_size fields cover [payload, payload + central) — bounding the
    // whole archive by u32::MAX keeps every emitted field lossless
    if payload + central > u64::from(u32::MAX) {
        return Err(ZipWriteError::ArchiveTooLarge {
            bytes: payload + central,
        });
    }
    Ok(())
}

/// Serialize entries as a stored zip archive (what `zipfile` reads back).
///
/// Returns a typed [`ZipWriteError`] when the input exceeds the classic
/// format's field widths (> 65 535 entries, an entry or the archive
/// past 4 GiB) — the caller gets a loud refusal, never an archive whose
/// EOCD silently decodes to `count % 65 536` entries.
pub fn write_archive(entries: &[Entry]) -> Result<Vec<u8>, ZipWriteError> {
    check_limits(
        entries.iter().map(|e| (e.name.as_str(), e.data.len() as u64)),
        entries.len(),
    )?;
    let payload: usize = entries.iter().map(|e| 30 + e.name.len() + e.data.len()).sum();
    let central: usize = entries.iter().map(|e| 46 + e.name.len()).sum();
    let mut buf = Vec::with_capacity(payload + central + 22);
    let mut offsets = Vec::with_capacity(entries.len());
    for e in entries {
        offsets.push(buf.len() as u32);
        let crc = crc32(&e.data);
        buf.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes()); // version needed
        buf.extend_from_slice(&0u16.to_le_bytes()); // flags
        buf.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        buf.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes()); // csize
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes()); // usize
        buf.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes()); // extra len
        buf.extend_from_slice(e.name.as_bytes());
        buf.extend_from_slice(&e.data);
    }
    let cd_off = buf.len() as u32;
    for (e, off) in entries.iter().zip(&offsets) {
        let crc = crc32(&e.data);
        buf.extend_from_slice(&CENTRAL_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes()); // version made by
        buf.extend_from_slice(&20u16.to_le_bytes()); // version needed
        buf.extend_from_slice(&0u16.to_le_bytes()); // flags
        buf.extend_from_slice(&0u16.to_le_bytes()); // method
        buf.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(e.data.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(e.name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes()); // extra
        buf.extend_from_slice(&0u16.to_le_bytes()); // comment
        buf.extend_from_slice(&0u16.to_le_bytes()); // disk
        buf.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        buf.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        buf.extend_from_slice(&off.to_le_bytes());
        buf.extend_from_slice(e.name.as_bytes());
    }
    let cd_size = buf.len() as u32 - cd_off;
    buf.extend_from_slice(&EOCD_SIG.to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // this disk
    buf.extend_from_slice(&0u16.to_le_bytes()); // cd disk
    buf.extend_from_slice(&(entries.len() as u16).to_le_bytes());
    buf.extend_from_slice(&(entries.len() as u16).to_le_bytes());
    buf.extend_from_slice(&cd_size.to_le_bytes());
    buf.extend_from_slice(&cd_off.to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // comment len
    Ok(buf)
}

/// CRC-32 (IEEE 802.3, the zip polynomial), bytewise with a lazily-built
/// 256-entry table — fixture-sized archives don't justify slicing-by-8.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard test vectors for the IEEE polynomial
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn roundtrip_multiple_entries() {
        let entries = vec![
            Entry { name: "a.npy".into(), data: vec![1, 2, 3, 4, 5] },
            Entry { name: "b.npy".into(), data: vec![] },
            Entry { name: "dir/c.npy".into(), data: (0..=255).collect() },
        ];
        let buf = write_archive(&entries).unwrap();
        let back = read_archive(&buf).unwrap();
        assert_eq!(back.len(), 3);
        for (e, b) in entries.iter().zip(&back) {
            assert_eq!(e.name, b.name);
            assert_eq!(e.data, b.data);
        }
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let entries = vec![Entry { name: "x".into(), data: vec![9; 64] }];
        let mut buf = write_archive(&entries).unwrap();
        // flip a payload byte (local header is 30 bytes + 1-byte name)
        buf[31 + 7] ^= 0x40;
        let err = read_archive(&buf).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");
    }

    #[test]
    fn rejects_deflate_method() {
        let entries = vec![Entry { name: "x".into(), data: vec![1, 2, 3] }];
        let mut buf = write_archive(&entries).unwrap();
        // patch method field in both local header (offset 8) and the
        // central directory entry (offset 10 within the CD record)
        buf[8] = 8;
        let cd = 30 + 1 + 3; // one local header + name + data
        buf[cd + 10] = 8;
        let err = read_archive(&buf).unwrap_err().to_string();
        assert!(err.contains("method 8"), "{err}");
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(read_archive(b"PK").is_err());
        assert!(read_archive(&[0u8; 64]).is_err());
    }

    #[test]
    fn empty_archive_roundtrips() {
        // a 22-byte EOCD-only archive is a VALID zip with zero entries
        // (numpy never writes one, but tooling may) — tolerate, not panic
        let buf = write_archive(&[]).unwrap();
        assert_eq!(buf.len(), 22);
        let back = read_archive(&buf).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn truncated_eocd_is_an_error() {
        let buf = write_archive(&[]).unwrap();
        for cut in [0usize, 1, 10, 21] {
            assert!(read_archive(&buf[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn zero_length_member_roundtrips_and_detects_crc_tamper() {
        let entries = vec![
            Entry { name: "empty.npy".into(), data: vec![] },
            Entry { name: "tail".into(), data: vec![7; 9] },
        ];
        let buf = write_archive(&entries).unwrap();
        let back = read_archive(&buf).unwrap();
        assert_eq!(back[0].name, "empty.npy");
        assert!(back[0].data.is_empty());
        assert_eq!(back[1].data, vec![7; 9]);
        // corrupt the central-directory CRC of the zero-length member:
        // CRC-32 of b"" is 0, so flip a byte → mismatch error, no panic
        let cd_off = {
            let eocd = buf.len() - 22;
            u32::from_le_bytes([buf[eocd + 16], buf[eocd + 17], buf[eocd + 18], buf[eocd + 19]])
                as usize
        };
        let mut bad = buf.clone();
        bad[cd_off + 16] ^= 0x01; // first CRC byte of entry 0
        let err = read_archive(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");
    }

    #[test]
    fn lying_entry_count_is_an_error_not_a_panic() {
        let entries = vec![Entry { name: "x".into(), data: vec![1, 2, 3] }];
        let mut buf = write_archive(&entries).unwrap();
        // EOCD total-entry count at offset 10: claim 5 entries where the
        // central directory holds 1 — the reader must bail on the walk
        let eocd = buf.len() - 22;
        buf[eocd + 10] = 5;
        assert!(read_archive(&buf).is_err());
    }

    #[test]
    fn seventy_thousand_entries_error_loudly_never_decode_short() {
        // the headline regression: 70 000 entries used to be written with
        // `entries.len() as u16`, so the EOCD claimed 70_000 % 65_536 =
        // 4_464 entries and the archive decoded SHORT. The writer must now
        // refuse with a typed error instead of emitting that corruption.
        let entries: Vec<Entry> = (0..70_000)
            .map(|i| Entry { name: format!("s{i}"), data: vec![] })
            .collect();
        match write_archive(&entries) {
            Err(ZipWriteError::TooManyEntries { count }) => assert_eq!(count, 70_000),
            other => panic!("expected TooManyEntries, got {other:?}"),
        }
    }

    #[test]
    fn entry_count_boundary_roundtrips() {
        // exactly u16::MAX entries is legal — the cap is exclusive above
        let entries: Vec<Entry> = (0..u16::MAX as usize)
            .map(|i| Entry { name: format!("e{i}"), data: vec![] })
            .collect();
        let buf = write_archive(&entries).unwrap();
        let back = read_archive(&buf).unwrap();
        assert_eq!(back.len(), u16::MAX as usize);
        assert_eq!(back[0].name, "e0");
        assert_eq!(back.last().unwrap().name, format!("e{}", u16::MAX as usize - 1));
        // one past the cap flips to the typed refusal
        let mut over = entries;
        over.push(Entry { name: "straw".into(), data: vec![] });
        assert!(matches!(
            write_archive(&over),
            Err(ZipWriteError::TooManyEntries { count }) if count == u16::MAX as usize + 1
        ));
    }

    #[test]
    fn oversized_entry_is_refused_without_allocating() {
        // check_limits works on (name, len) metadata, so the >4 GiB paths
        // are exercised without materializing gigabytes
        let five_gib = 5 * (1u64 << 30);
        let shapes = [("small", 16u64), ("big", five_gib)];
        match check_limits(shapes.iter().map(|&(n, l)| (n, l)), shapes.len()) {
            Err(ZipWriteError::EntryTooLarge { name, bytes }) => {
                assert_eq!(name, "big");
                assert_eq!(bytes, five_gib);
            }
            other => panic!("expected EntryTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn oversized_archive_total_is_refused() {
        // three 2 GiB entries: each fits the u32 size field, but the third
        // local header would sit past u32::MAX — offsets would wrap
        let two_gib = 2 * (1u64 << 30);
        let shapes = [("a", two_gib), ("b", two_gib), ("c", two_gib)];
        match check_limits(shapes.iter().map(|&(n, l)| (n, l)), shapes.len()) {
            Err(ZipWriteError::ArchiveTooLarge { bytes }) => {
                assert!(bytes > u64::from(u32::MAX), "{bytes}");
            }
            other => panic!("expected ArchiveTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn absurd_name_length_is_refused() {
        let long = "n".repeat(u16::MAX as usize + 1);
        match check_limits([(long.as_str(), 0u64)].into_iter(), 1) {
            Err(ZipWriteError::NameTooLong { len, .. }) => {
                assert_eq!(len, u16::MAX as usize + 1);
            }
            other => panic!("expected NameTooLong, got {other:?}"),
        }
    }

    #[test]
    fn zip_write_error_displays_are_pointed() {
        let e = ZipWriteError::TooManyEntries { count: 70_000 };
        let s = e.to_string();
        assert!(s.contains("70000") && s.contains("65535"), "{s}");
    }

    #[test]
    fn out_of_range_central_directory_offset_is_an_error() {
        let entries = vec![Entry { name: "x".into(), data: vec![1] }];
        let mut buf = write_archive(&entries).unwrap();
        let eocd = buf.len() - 22;
        // point the CD offset past the end of the buffer
        buf[eocd + 16..eocd + 20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_archive(&buf).is_err());
        // and at the EOCD itself (not a CD signature)
        let mut buf2 = write_archive(&entries).unwrap();
        let off = (buf2.len() - 22) as u32;
        let eocd2 = buf2.len() - 22;
        buf2[eocd2 + 16..eocd2 + 20].copy_from_slice(&off.to_le_bytes());
        assert!(read_archive(&buf2).is_err());
    }
}
