//! In-memory dataset representation: variable-length multivariate series
//! with integer class labels, plus normalization and padding utilities.

/// One labelled multivariate time series.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// row-major T×V
    pub u: Vec<f32>,
    /// series length T
    pub t: usize,
    /// label in [0, n_c)
    pub label: usize,
}

impl Sample {
    pub fn v(&self) -> usize {
        if self.t == 0 {
            0
        } else {
            self.u.len() / self.t
        }
    }

    /// Row at time step k.
    pub fn row(&self, k: usize, v: usize) -> &[f32] {
        &self.u[k * v..(k + 1) * v]
    }

    /// Copy into a zero-padded buffer of t_pad rows (artifact input).
    pub fn padded(&self, v: usize, t_pad: usize) -> Vec<f32> {
        assert!(self.t <= t_pad, "series longer than pad ({} > {t_pad})", self.t);
        let mut out = vec![0.0f32; t_pad * v];
        out[..self.t * v].copy_from_slice(&self.u);
        out
    }
}

/// A train/test split of samples with shared metadata.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub name: String,
    pub n_v: usize,
    pub n_c: usize,
    pub train: Vec<Sample>,
    pub test: Vec<Sample>,
}

impl Dataset {
    /// Longest series in either split.
    pub fn t_max(&self) -> usize {
        self.train
            .iter()
            .chain(&self.test)
            .map(|s| s.t)
            .max()
            .unwrap_or(0)
    }

    pub fn t_min(&self) -> usize {
        self.train
            .iter()
            .chain(&self.test)
            .map(|s| s.t)
            .min()
            .unwrap_or(0)
    }

    /// Standardize every channel to zero mean / unit variance using
    /// statistics of the training split only (no test leakage).
    pub fn standardize(&mut self) {
        let v = self.n_v;
        let mut mean = vec![0.0f64; v];
        let mut count = 0u64;
        for s in &self.train {
            for k in 0..s.t {
                for (m, x) in mean.iter_mut().zip(s.row(k, v)) {
                    *m += f64::from(*x);
                }
            }
            count += s.t as u64;
        }
        if count == 0 {
            return;
        }
        for m in mean.iter_mut() {
            *m /= count as f64;
        }
        let mut var = vec![0.0f64; v];
        for s in &self.train {
            for k in 0..s.t {
                for (vv, (x, m)) in var.iter_mut().zip(s.row(k, v).iter().zip(&mean)) {
                    let d = f64::from(*x) - m;
                    *vv += d * d;
                }
            }
        }
        let std: Vec<f64> = var
            .iter()
            .map(|&x| (x / count as f64).sqrt().max(1e-8))
            .collect();
        for s in self.train.iter_mut().chain(self.test.iter_mut()) {
            for k in 0..s.t {
                let row = &mut s.u[k * v..(k + 1) * v];
                for (x, (m, sd)) in row.iter_mut().zip(mean.iter().zip(&std)) {
                    *x = ((f64::from(*x) - m) / sd) as f32;
                }
            }
        }
    }

    /// Class histogram of the training split.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.n_c];
        for s in &self.train {
            c[s.label] += 1;
        }
        c
    }
}

/// Classification accuracy of predictions vs labels.
pub fn accuracy(pred: &[usize], samples: &[Sample]) -> f64 {
    assert_eq!(pred.len(), samples.len());
    if pred.is_empty() {
        return 0.0;
    }
    let ok = pred
        .iter()
        .zip(samples)
        .filter(|(p, s)| **p == s.label)
        .count();
    ok as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: usize, v: usize, fill: f32, label: usize) -> Sample {
        Sample {
            u: vec![fill; t * v],
            t,
            label,
        }
    }

    #[test]
    fn padded_zero_extends() {
        let s = Sample {
            u: vec![1.0, 2.0, 3.0, 4.0],
            t: 2,
            label: 0,
        };
        let p = s.padded(2, 4);
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn standardize_train_stats() {
        let mut d = Dataset {
            name: "t".into(),
            n_v: 1,
            n_c: 2,
            train: vec![sample(2, 1, 1.0, 0), sample(2, 1, 3.0, 1)],
            test: vec![sample(1, 1, 2.0, 0)],
        };
        d.standardize();
        // train mean 2, std 1 → values ±1; test value 2 → 0
        assert!((d.train[0].u[0] + 1.0).abs() < 1e-6);
        assert!((d.train[1].u[0] - 1.0).abs() < 1e-6);
        assert!(d.test[0].u[0].abs() < 1e-6);
    }

    #[test]
    fn tmax_tmin_counts() {
        let d = Dataset {
            name: "t".into(),
            n_v: 1,
            n_c: 2,
            train: vec![sample(5, 1, 0.0, 1), sample(2, 1, 0.0, 1)],
            test: vec![sample(9, 1, 0.0, 0)],
        };
        assert_eq!(d.t_max(), 9);
        assert_eq!(d.t_min(), 2);
        assert_eq!(d.class_counts(), vec![0, 2]);
    }

    #[test]
    fn accuracy_counts_matches() {
        let samples = vec![sample(1, 1, 0.0, 0), sample(1, 1, 0.0, 1)];
        assert_eq!(accuracy(&[0, 0], &samples), 0.5);
        assert_eq!(accuracy(&[0, 1], &samples), 1.0);
    }
}
