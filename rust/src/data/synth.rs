//! Synthetic class-conditional time-series generators — the stand-in for
//! the UEA/UCR npz files of Bianchi et al. [6] (DESIGN.md §3).
//!
//! Every profile of Table 4 gets a generator with identical shape
//! statistics (#V, #C, Train, Test, T_min, T_max). Class structure is a
//! mixture of class-keyed oscillations, class-dependent cross-channel
//! mixing and AR(1) noise; a per-profile `difficulty` scales the noise so
//! the relative accuracy ordering of the paper's datasets is roughly
//! preserved (e.g. WALK ≈ separable, NET/KICK hard).

use super::dataset::{Dataset, Sample};
use super::profiles::Profile;
use crate::util::prng::Pcg32;

/// Generation knobs per dataset (on top of the Table 4 shapes).
#[derive(Clone, Copy, Debug)]
pub struct SynthConfig {
    /// noise standard deviation relative to signal amplitude
    pub noise: f32,
    /// angular frequency separation between adjacent classes
    pub freq_sep: f32,
    /// AR(1) coefficient of the additive noise
    pub ar: f32,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            noise: 0.6,
            freq_sep: 0.055,
            ar: 0.5,
        }
    }
}

/// Per-profile difficulty tuning (rough match of the paper's accuracy
/// ordering on each dataset; see DESIGN.md §10 on what is and is not
/// claimed for the synthetic stand-ins).
pub fn config_for(name: &str) -> SynthConfig {
    let mut c = SynthConfig::default();
    match name {
        "walk" | "waf" | "jpvow" | "arab" => c.noise = 0.35, // high-acc sets
        "aus" | "cmu" => c.noise = 0.5,
        "char" | "uwav" | "ecg" => c.noise = 0.8,
        "lib" | "net" | "kick" => {
            c.noise = 0.4; // hard sets (paper accuracy ~0.78-0.81)
            c.freq_sep = 0.12;
        }
        _ => {}
    }
    c
}

/// Generate the full dataset for a Table 4 profile, deterministically
/// from `seed`.
pub fn generate(profile: &Profile, seed: u64) -> Dataset {
    generate_with(profile, config_for(profile.name), seed)
}

/// Generate with explicit knobs (used by the ablation benches).
pub fn generate_with(profile: &Profile, cfg: SynthConfig, seed: u64) -> Dataset {
    let mut root = Pcg32::new(seed, 0x5EED);
    // class signatures are shared between train and test
    let mut sig_rng = root.split(1);
    let sigs: Vec<ClassSignature> = (0..profile.n_c)
        .map(|c| ClassSignature::new(c, profile.n_v, cfg, &mut sig_rng))
        .collect();

    let mut train_rng = root.split(2);
    let mut test_rng = root.split(3);
    let train = draw_split(profile, &sigs, cfg, profile.train, &mut train_rng);
    let test = draw_split(profile, &sigs, cfg, profile.test, &mut test_rng);

    let mut d = Dataset {
        name: profile.name.to_string(),
        n_v: profile.n_v,
        n_c: profile.n_c,
        train,
        test,
    };
    d.standardize();
    d
}

/// Frequencies/phases/mixing defining one class's dynamics.
struct ClassSignature {
    /// two oscillation frequencies (rad per step)
    freqs: [f32; 2],
    /// per-channel phase offsets for each oscillator
    phases: Vec<[f32; 2]>,
    /// per-channel amplitude weights
    amps: Vec<[f32; 2]>,
}

impl ClassSignature {
    fn new(class: usize, n_v: usize, cfg: SynthConfig, rng: &mut Pcg32) -> Self {
        let base = 0.12;
        // classes spread over a 2-D frequency grid (5 columns) so
        // many-class datasets (AUS C=95, CHAR C=20, LIB C=15) stay
        // separable instead of crowding one frequency axis
        let f0 = base + cfg.freq_sep * (class % 5) as f32;
        let f1 = 2.3 * base + 1.7 * cfg.freq_sep * (class / 5) as f32;
        let phases = (0..n_v)
            .map(|_| {
                [
                    rng.uniform_in(0.0, core::f32::consts::TAU),
                    rng.uniform_in(0.0, core::f32::consts::TAU),
                ]
            })
            .collect();
        let amps = (0..n_v)
            .map(|_| [rng.uniform_in(0.5, 1.0), rng.uniform_in(0.2, 0.7)])
            .collect();
        ClassSignature {
            freqs: [f0, f1],
            phases,
            amps,
        }
    }

    fn sample(&self, t: usize, n_v: usize, cfg: SynthConfig, rng: &mut Pcg32) -> Vec<f32> {
        let mut u = vec![0.0f32; t * n_v];
        // per-sample jitter so instances of a class differ
        let fj = 1.0 + 0.02 * rng.normal();
        let pj: Vec<f32> = (0..n_v).map(|_| 0.3 * rng.normal()).collect();
        let mut ar_state = vec![0.0f32; n_v];
        for k in 0..t {
            for v in 0..n_v {
                let mut x = 0.0;
                for o in 0..2 {
                    x += self.amps[v][o]
                        * (self.freqs[o] * fj * k as f32 + self.phases[v][o] + pj[v]).sin();
                }
                ar_state[v] = cfg.ar * ar_state[v] + cfg.noise * rng.normal();
                u[k * n_v + v] = x + ar_state[v];
            }
        }
        u
    }
}

fn draw_split(
    profile: &Profile,
    sigs: &[ClassSignature],
    cfg: SynthConfig,
    n: usize,
    rng: &mut Pcg32,
) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            // round-robin labels keep every class populated even for the
            // tiny splits (KICK has 10 test samples over 2 classes)
            let label = i % profile.n_c;
            let t = if profile.t_min == profile.t_max {
                profile.t_min
            } else {
                profile.t_min + rng.below((profile.t_max - profile.t_min + 1) as u32) as usize
            };
            let u = sigs[label].sample(t, profile.n_v, cfg, rng);
            Sample { u, t, label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::profiles::Profile;

    fn prof(name: &str) -> &'static Profile {
        Profile::by_name(name).unwrap()
    }

    #[test]
    fn shapes_match_table4() {
        let d = generate(prof("jpvow"), 42);
        assert_eq!(d.train.len(), 270);
        assert_eq!(d.test.len(), 370);
        assert_eq!(d.n_v, 12);
        assert_eq!(d.n_c, 9);
        assert!(d.t_min() >= 7 && d.t_max() <= 29);
        for s in d.train.iter().chain(&d.test) {
            assert_eq!(s.u.len(), s.t * 12);
            assert!(s.label < 9);
        }
    }

    #[test]
    fn fixed_length_dataset_has_constant_t() {
        let d = generate(prof("lib"), 42);
        assert!(d.train.iter().all(|s| s.t == 45));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(prof("ecg"), 1);
        let b = generate(prof("ecg"), 1);
        assert_eq!(a.train[0].u, b.train[0].u);
        let c = generate(prof("ecg"), 2);
        assert_ne!(a.train[0].u, c.train[0].u);
    }

    #[test]
    fn all_classes_present() {
        let d = generate(prof("aus"), 7); // 95 classes
        let counts = d.class_counts();
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn standardized_channels() {
        let d = generate(prof("ecg"), 3);
        // pooled train mean ≈ 0, var ≈ 1 per channel
        let v = d.n_v;
        for ch in 0..v {
            let mut sum = 0.0f64;
            let mut sum2 = 0.0f64;
            let mut n = 0u64;
            for s in &d.train {
                for k in 0..s.t {
                    let x = f64::from(s.row(k, v)[ch]);
                    sum += x;
                    sum2 += x * x;
                    n += 1;
                }
            }
            let mean = sum / n as f64;
            let var = sum2 / n as f64 - mean * mean;
            assert!(mean.abs() < 0.05, "ch {ch} mean {mean}");
            assert!((var - 1.0).abs() < 0.1, "ch {ch} var {var}");
        }
    }

    #[test]
    fn classes_are_distinguishable_by_spectrum() {
        // amplitude spectra (phase-invariant) must be closer within a
        // class than across classes — the structure the reservoir layer
        // will pick up
        let d = generate(prof("walk"), 5);
        let v = d.n_v;
        // coarse amplitude spectrum of channel 0 at probe frequencies
        let spectrum = |s: &Sample| -> Vec<f64> {
            (1..=12)
                .map(|h| {
                    let w = 0.05 * h as f64;
                    let (mut cs, mut sn) = (0.0f64, 0.0f64);
                    for k in 0..s.t {
                        let x = f64::from(s.row(k, v)[0]);
                        cs += x * (w * k as f64).cos();
                        sn += x * (w * k as f64).sin();
                    }
                    ((cs * cs + sn * sn) / s.t as f64).sqrt()
                })
                .collect()
        };
        let specs: Vec<(usize, Vec<f64>)> = d.train[..20]
            .iter()
            .map(|s| (s.label, spectrum(s)))
            .collect();
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let (mut same, mut diff, mut ns, mut nd) = (0.0, 0.0, 0, 0);
        for i in 0..specs.len() {
            for j in (i + 1)..specs.len() {
                let dd = dist(&specs[i].1, &specs[j].1);
                if specs[i].0 == specs[j].0 {
                    same += dd;
                    ns += 1;
                } else {
                    diff += dd;
                    nd += 1;
                }
            }
        }
        assert!(
            same / (ns as f64) < diff / (nd as f64),
            "intra {same}/{ns} vs inter {diff}/{nd}"
        );
    }
}
