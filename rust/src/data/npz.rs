//! npy / npz reading and writing.
//!
//! The paper's datasets ship as npz files ([6]); this module implements
//! the npy v1 format and the npz (zip) container so the Rust side can
//! load the original files when present, exchange golden test vectors
//! with `python/tests/make_golden.py`, and export datasets for numpy.
//!
//! Supported dtypes: `<f4`, `<f8`, `<i4`, `<i8` (read), `<f4`/`<i4`
//! (write). C-order only.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// An n-dimensional array loaded from npy (f32 storage).
#[derive(Clone, Debug, PartialEq)]
pub struct Array {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Array {
    pub fn scalar(&self) -> Result<f32> {
        if self.data.len() == 1 {
            Ok(self.data[0])
        } else {
            bail!("expected scalar, shape {:?}", self.shape)
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

// ---------------------------------------------------------------------------
// npy
// ---------------------------------------------------------------------------

const MAGIC: &[u8] = b"\x93NUMPY";

/// Parse one npy buffer.
pub fn parse_npy(buf: &[u8]) -> Result<Array> {
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("not an npy file");
    }
    let major = buf[6];
    let header_len: usize = match major {
        1 => u16::from_le_bytes([buf[8], buf[9]]) as usize,
        2 | 3 => u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
        v => bail!("unsupported npy version {v}"),
    };
    let header_start = if major == 1 { 10 } else { 12 };
    let header = std::str::from_utf8(&buf[header_start..header_start + header_len])
        .context("npy header not utf-8")?;
    let descr = dict_value(header, "descr").ok_or_else(|| anyhow!("no descr"))?;
    let fortran = dict_value(header, "fortran_order").unwrap_or_else(|| "False".into());
    if fortran.trim() == "True" {
        bail!("fortran-order npy not supported");
    }
    let shape_str = dict_value(header, "shape").ok_or_else(|| anyhow!("no shape"))?;
    let shape: Vec<usize> = shape_str
        .trim_matches(|c| c == '(' || c == ')')
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<usize>().context("bad shape"))
        .collect::<Result<_>>()?;
    let count: usize = shape.iter().product::<usize>().max(1) * usize::from(!shape.is_empty())
        + usize::from(shape.is_empty()); // scalar npy: shape ()
    let payload = &buf[header_start + header_len..];

    let descr = descr.trim_matches(|c| c == '\'' || c == '"');
    let data = match descr {
        "<f4" | "|f4" | "=f4" => read_vec::<4>(payload, count)?
            .iter()
            .map(|b| f32::from_le_bytes(*b))
            .collect(),
        "<f8" => read_vec::<8>(payload, count)?
            .iter()
            .map(|b| f64::from_le_bytes(*b) as f32)
            .collect(),
        "<i4" => read_vec::<4>(payload, count)?
            .iter()
            .map(|b| i32::from_le_bytes(*b) as f32)
            .collect(),
        "<i8" => read_vec::<8>(payload, count)?
            .iter()
            .map(|b| i64::from_le_bytes(*b) as f32)
            .collect(),
        other => bail!("unsupported dtype {other}"),
    };
    Ok(Array { shape, data })
}

fn read_vec<const N: usize>(payload: &[u8], count: usize) -> Result<Vec<[u8; N]>> {
    if payload.len() < count * N {
        bail!("npy payload truncated: {} < {}", payload.len(), count * N);
    }
    Ok(payload[..count * N]
        .chunks_exact(N)
        .map(|c| {
            let mut a = [0u8; N];
            a.copy_from_slice(c);
            a
        })
        .collect())
}

/// Extract `'key': value` from the ad-hoc python-dict header.
fn dict_value(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = header[at..].trim_start();
    if rest.starts_with('(') {
        let end = rest.find(')')?;
        return Some(rest[..=end].to_string());
    }
    let end = rest.find(|c| c == ',' || c == '}')?;
    Some(rest[..end].trim().to_string())
}

/// Serialize an f32 array to npy v1 bytes.
pub fn write_npy_f32(shape: &[usize], data: &[f32]) -> Vec<u8> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad header so that data start is 64-byte aligned
    let base = MAGIC.len() + 4;
    let total = ((base + header.len() + 1 + 63) / 64) * 64;
    header.push_str(&" ".repeat(total - base - header.len() - 1));
    header.push('\n');

    let mut out = Vec::with_capacity(total + data.len() * 4);
    out.extend_from_slice(MAGIC);
    out.push(1);
    out.push(0);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

// ---------------------------------------------------------------------------
// npz (zip container — see `super::zipstore` for the stored-zip subset)
// ---------------------------------------------------------------------------

/// Read every array of an npz file.
pub fn read_npz(path: impl AsRef<Path>) -> Result<BTreeMap<String, Array>> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    read_npz_from(file)
}

/// Read npz from any reader (the whole archive is buffered; no `Seek`
/// needed, so pipes and network streams work too).
pub fn read_npz_from<R: Read>(mut reader: R) -> Result<BTreeMap<String, Array>> {
    let mut buf = Vec::new();
    reader.read_to_end(&mut buf).context("read npz bytes")?;
    let mut out = BTreeMap::new();
    for entry in super::zipstore::read_archive(&buf).context("not a zip/npz")? {
        let name = entry.name.trim_end_matches(".npy").to_string();
        out.insert(
            name,
            parse_npy(&entry.data).with_context(|| format!("entry {:?}", entry.name))?,
        );
    }
    Ok(out)
}

/// Write arrays as an npz file (stored, no compression — these are small
/// and numpy reads them either way).
pub fn write_npz(
    path: impl AsRef<Path>,
    arrays: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
) -> Result<()> {
    std::fs::write(path.as_ref(), write_npz_bytes(arrays)?)?;
    Ok(())
}

/// Round-trip helper used by tests: npz bytes in memory.
pub fn write_npz_bytes(arrays: &BTreeMap<String, (Vec<usize>, Vec<f32>)>) -> Result<Vec<u8>> {
    let entries: Vec<super::zipstore::Entry> = arrays
        .iter()
        .map(|(name, (shape, data))| super::zipstore::Entry {
            name: format!("{name}.npy"),
            data: write_npy_f32(shape, data),
        })
        .collect();
    Ok(super::zipstore::write_archive(&entries)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn npy_roundtrip_2d() {
        let data: Vec<f32> = (0..12).map(|x| x as f32 * 0.5).collect();
        let bytes = write_npy_f32(&[3, 4], &data);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
    }

    #[test]
    fn npy_roundtrip_scalar_and_1d() {
        let bytes = write_npy_f32(&[], &[7.5]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, Vec::<usize>::new());
        assert_eq!(arr.scalar().unwrap(), 7.5);

        let bytes = write_npy_f32(&[3], &[1.0, 2.0, 3.0]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, vec![3]);
    }

    #[test]
    fn npz_roundtrip_in_memory() {
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), (vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        m.insert("b".to_string(), (vec![1], vec![9.0]));
        let bytes = write_npz_bytes(&m).unwrap();
        let back = read_npz_from(Cursor::new(bytes)).unwrap();
        assert_eq!(back["a"].shape, vec![2, 2]);
        assert_eq!(back["a"].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["b"].data, vec![9.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not npy at all").is_err());
    }

    #[test]
    fn data_alignment_64() {
        let bytes = write_npy_f32(&[1], &[1.0]);
        // header block (magic..newline) must end on a 64-byte boundary
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }

    #[test]
    fn reads_python_golden_npz() {
        // the committed fixture (rust/artifacts/golden) — regenerate with
        // `python3 python/tests/make_golden.py rust/artifacts/golden`
        let path = std::path::Path::new("artifacts/golden/small.npz");
        assert!(
            path.exists(),
            "committed golden fixture missing: {path:?} (cwd {:?})",
            std::env::current_dir().ok()
        );
        let m = read_npz(path).unwrap();
        assert_eq!(m["nx"].scalar().unwrap(), 5.0);
        assert_eq!(m["u"].shape, vec![12, 2]);
        assert_eq!(m["r_mat"].shape, vec![5, 6]);
    }
}
