//! Dataset profiles — the paper's Table 4, mirrored from
//! `python/compile/profiles.py` (the manifest emitted by aot.py is the
//! runtime contract; this table drives synthesis and benches).

/// Shape statistics of one benchmark dataset (Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Profile {
    pub name: &'static str,
    /// input dimension #V
    pub n_v: usize,
    /// classes #C
    pub n_c: usize,
    pub train: usize,
    pub test: usize,
    pub t_min: usize,
    pub t_max: usize,
}

impl Profile {
    /// Padded length the AOT artifacts are specialised to.
    pub fn t_pad(&self) -> usize {
        self.t_max
    }

    /// Ridge system size s = Nx² + Nx + 1 for the default Nx.
    pub fn s(&self, nx: usize) -> usize {
        nx * nx + nx + 1
    }

    pub fn by_name(name: &str) -> Option<&'static Profile> {
        PROFILES.iter().find(|p| p.name == name)
    }
}

/// Table 4 of the paper (#V, #C, Train, Test, T_min, T_max).
pub const PROFILES: [Profile; 12] = [
    Profile { name: "arab", n_v: 13, n_c: 10, train: 6600, test: 2200, t_min: 4, t_max: 93 },
    Profile { name: "aus", n_v: 22, n_c: 95, train: 1140, test: 1425, t_min: 45, t_max: 136 },
    Profile { name: "char", n_v: 3, n_c: 20, train: 300, test: 2558, t_min: 109, t_max: 205 },
    Profile { name: "cmu", n_v: 62, n_c: 2, train: 29, test: 29, t_min: 127, t_max: 580 },
    Profile { name: "ecg", n_v: 2, n_c: 2, train: 100, test: 100, t_min: 39, t_max: 152 },
    Profile { name: "jpvow", n_v: 12, n_c: 9, train: 270, test: 370, t_min: 7, t_max: 29 },
    Profile { name: "kick", n_v: 62, n_c: 2, train: 16, test: 10, t_min: 274, t_max: 841 },
    Profile { name: "lib", n_v: 2, n_c: 15, train: 180, test: 180, t_min: 45, t_max: 45 },
    Profile { name: "net", n_v: 4, n_c: 13, train: 803, test: 534, t_min: 50, t_max: 994 },
    Profile { name: "uwav", n_v: 3, n_c: 8, train: 200, test: 427, t_min: 315, t_max: 315 },
    Profile { name: "waf", n_v: 6, n_c: 2, train: 298, test: 896, t_min: 104, t_max: 198 },
    Profile { name: "walk", n_v: 62, n_c: 2, train: 28, test: 16, t_min: 128, t_max: 1918 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_profiles_lookup() {
        assert_eq!(PROFILES.len(), 12);
        let j = Profile::by_name("jpvow").unwrap();
        assert_eq!((j.n_v, j.n_c, j.train, j.test), (12, 9, 270, 370));
        assert!(Profile::by_name("nope").is_none());
    }

    #[test]
    fn s_dim_paper() {
        assert_eq!(Profile::by_name("jpvow").unwrap().s(30), 931);
    }

    #[test]
    fn tmin_le_tmax() {
        for p in &PROFILES {
            assert!(p.t_min <= p.t_max, "{}", p.name);
        }
    }
}
