//! The sharded event loop: an N-shard worker pool, per-shard bounded
//! request queues, per-session routing, metrics — Rust owns the process
//! (no tokio; see `util::runtimex`).
//!
//! # Sharding
//!
//! [`Server::spawn`] starts `ServerConfig::shards` worker threads. Each
//! shard thread *exclusively owns* its `BTreeMap<u64, Session>` — there
//! is no cross-shard locking anywhere on the request path. A request for
//! session `id` is routed to shard `id % shards` at submit time, so all
//! requests for one session are serialized on one thread (the paper's
//! per-deployment protocol is inherently sequential) while distinct
//! sessions scale across cores.
//!
//! Each shard gets its own engine via [`Engine::fork`]; engines that
//! cannot be replicated (e.g. a single-owner PJRT client without
//! recompilable artifacts) degrade gracefully to fewer shards — the
//! effective count is exported as the `shards_active` metric.
//!
//! # Backpressure
//!
//! Two-level, as in the paper's bounded-memory edge design:
//! 1. every shard has a bounded request queue (`queue_cap` split evenly
//!    across shards); [`Server::try_call`] refuses (`None`) when the
//!    target shard's queue is saturated, and [`Server::call`] blocks;
//! 2. each session's collect buffer is capped
//!    (`SessionConfig::buffer_cap`) — overflowing samples are `Rejected`.
//!    Sessions on the streaming Serve path (`TrainConfig::forgetting` /
//!    `::window`) never reject labelled samples at this level: each one
//!    is folded in O(s²) and answered `Observed` (counted by the
//!    per-shard `online_updates_total` metric), and the recent-sample
//!    buffer recycles as a bounded FIFO. With reservoir adaptation on
//!    (`SessionConfig::adapt_reservoir`), each fold also drives a
//!    truncated-BPTT step (`reservoir_updates_total`) and generation
//!    rolls answer `Adapted` (`refeaturize_total`) — see DESIGN.md §13.
//!
//! # Batched drain (DESIGN.md §14)
//!
//! After blocking on one request, a shard opportunistically drains up to
//! [`ServerConfig::max_batch`] queued requests and pre-extracts the
//! features of the batchable ones — streaming-Serve `Feed`s and exact-
//! score `Infer`s on the current generation — through one
//! [`Engine::features_batch_into`] sweep (the node-major
//! `BatchScratch` kernel on the native engine). Responses are produced
//! in strict arrival order with results **bitwise equal** to per-call
//! processing (`tests/batch_equivalence.rs`); a mid-batch generation
//! roll splits the batch (stale lanes re-run per-call,
//! `batch_splits_total`). The `batch_size` histogram records one sample
//! per drain cycle (size encoded as µs).
//!
//! # Shutdown
//!
//! [`Server::shutdown`] drains every shard in order: it enqueues a
//! `Shutdown` marker behind the shard's pending requests and waits for
//! the `Bye` ack, which the shard only sends after answering everything
//! ahead of the marker. Shards then keep serving stragglers until the
//! server drops their queue senders, so no accepted request ever loses
//! its reply.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::Result;

use super::engine::Engine;
use super::protocol::{Request, Response};
use super::session::{FeedOutcome, InferError, Phase, Session, SessionConfig};
use crate::util::metrics::Registry;

/// A queued request with its reply channel.
type Envelope = (Request, mpsc::Sender<Response>);

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// template for newly-created sessions
    pub session: SessionConfig,
    /// total request-queue capacity, split evenly across shards
    /// (global backpressure)
    pub queue_cap: usize,
    pub seed: u64,
    /// worker shards; sessions are routed by `session_id % shards`.
    /// Clamped to ≥ 1, and reduced when the engine cannot [`Engine::fork`]
    /// enough replicas.
    pub shards: usize,
    /// Upper bound on the shard drain batch: after blocking on one
    /// request, a shard opportunistically drains up to `max_batch − 1`
    /// more already-queued requests and runs their feature extractions
    /// as one [`Engine::features_batch_into`] sweep. Responses keep
    /// strict FIFO order per shard (hence per session), and a value of 1
    /// disables batching entirely. Clamped to ≥ 1.
    pub max_batch: usize,
}

impl ServerConfig {
    /// Config with the defaults used by the CLI: queue of 256, one shard
    /// per available core, drain batches of up to 8.
    pub fn new(session: SessionConfig) -> Self {
        ServerConfig {
            session,
            queue_cap: 256,
            seed: 0,
            shards: default_shards(),
            max_batch: 8,
        }
    }
}

/// One shard per available core (the bench's sweet spot for the
/// compute-bound native engine).
pub fn default_shards() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Handle to a running server (owns the shard worker threads).
pub struct Server {
    txs: Vec<mpsc::SyncSender<Envelope>>,
    handles: Vec<thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
}

impl Server {
    /// Spawn the shard pool over an engine.
    ///
    /// The engine is forked once per extra shard; if the engine cannot be
    /// replicated the server runs with however many replicas it got
    /// (at least one — the engine passed in).
    ///
    /// Forks run serially on the spawning thread. For `NativeEngine`
    /// that is free; for `PjrtEngine` every fork recompiles the five HLO
    /// entry points (~1 s each), so with the one-shard-per-core default
    /// startup cost scales with core count — size `shards` deliberately
    /// for PJRT deployments.
    pub fn spawn(engine: Box<dyn Engine>, cfg: ServerConfig) -> Server {
        let want = cfg.shards.max(1);
        let mut engines: Vec<Box<dyn Engine>> = vec![engine];
        while engines.len() < want {
            match engines[0].fork() {
                Some(e) => engines.push(e),
                None => break,
            }
        }
        let shards = engines.len();
        let metrics = Arc::new(Registry::default());
        metrics.counter("shards_active").add(shards as u64);
        let per_shard_cap = (cfg.queue_cap.max(1) + shards - 1) / shards;
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for (i, eng) in engines.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<Envelope>(per_shard_cap);
            let m = Arc::clone(&metrics);
            let cfg = cfg.clone();
            let h = thread::Builder::new()
                .name(format!("dfr-shard-{i}"))
                .spawn(move || shard_loop(i, eng, cfg, rx, m))
                .expect("spawn shard thread");
            txs.push(tx);
            handles.push(h);
        }
        Server {
            txs,
            handles,
            metrics,
        }
    }

    /// Number of live shards (may be fewer than requested if the engine
    /// could not be forked).
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// The shard a request will be routed to.
    fn route(&self, req: &Request) -> usize {
        match req.session_id() {
            Some(id) => (id % self.txs.len() as u64) as usize,
            // remaining session-less requests (Shutdown via `call`) go to
            // shard 0; Stats never reaches here (answered inline).
            None => 0,
        }
    }

    /// Send a request and wait for the response (blocks under
    /// backpressure).
    ///
    /// `Stats` is answered directly from the shared registry without
    /// entering any shard queue — monitoring stays instant even when
    /// every shard is saturated with slow trainings.
    pub fn call(&self, req: Request) -> Result<Response> {
        if matches!(req, Request::Stats) {
            return Ok(Response::StatsText(self.metrics.render()));
        }
        let (rtx, rrx) = mpsc::channel();
        let shard = self.route(&req);
        self.txs[shard]
            .send((req, rtx))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx.recv()?)
    }

    /// Non-blocking send; `Ok(None)` means the target shard's queue is
    /// saturated (backpressure) — the caller should retry or shed load.
    /// `Stats` never sheds: the receiver already holds the snapshot.
    pub fn try_call(&self, req: Request) -> Result<Option<mpsc::Receiver<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        if matches!(req, Request::Stats) {
            let _ = rtx.send(Response::StatsText(self.metrics.render()));
            return Ok(Some(rrx));
        }
        let shard = self.route(&req);
        match self.txs[shard].try_send((req, rtx)) {
            Ok(()) => Ok(Some(rrx)),
            Err(mpsc::TrySendError::Full(_)) => Ok(None),
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(anyhow::anyhow!("server stopped"))
            }
        }
    }

    /// Graceful shutdown: drain every shard queue in order, then join the
    /// worker threads. All requests accepted before this call are
    /// answered first.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        for tx in &self.txs {
            let (rtx, rrx) = mpsc::channel();
            if tx.send((Request::Shutdown, rtx)).is_ok() {
                // Bye arrives only after everything queued ahead of the
                // marker has been answered.
                let _ = rrx.recv();
            }
        }
        // Dropping the senders disconnects the queues; shards drain any
        // requests that raced in behind the markers, then exit.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The generation coordinates a batched feature extraction was planned
/// at. Re-validated immediately before each item is processed: an
/// earlier item in the same drain batch may have rolled the session's
/// generation (`Adapted`/`Trained`) or the engine's shared datapath — a
/// mismatch splits the batch and the item re-runs per-call
/// (`batch_splits_total`), so features never mix generations.
#[derive(Clone, Copy)]
struct PlanTag {
    /// lane index into the drained feature buffers
    lane: usize,
    /// `Session::generation` at plan time
    session_gen: u64,
    /// `Session::engine_generation` (== `Engine::generation`) at plan time
    engine_gen: u64,
}

/// One shard: exclusively owns its session map and engine replica, and
/// registers `shard`-labelled instruments in the shared registry.
///
/// # Batched drain
///
/// The loop blocks on one request, then opportunistically drains up to
/// `max_batch − 1` more from its queue. Requests whose feature
/// extraction is batchable — streaming-Serve `Feed`s and `Infer`s whose
/// served generation matches the engine datapath (and, for `Infer`, an
/// engine whose scores are an exact function of r̃) — run through one
/// [`Engine::features_batch_into`] sweep, then every request is answered
/// **in arrival order** with its precomputed features (or per-call when
/// planning skipped it). Ordering, backpressure, and the
/// `Observed`/`Adapted` semantics of DESIGN.md §13 are unchanged:
/// a request that the per-call path would answer `Adapted` (generation
/// mismatch) is never planned, and a mid-batch generation roll
/// invalidates later planned items via their [`PlanTag`].
fn shard_loop(
    shard: usize,
    engine: Box<dyn Engine>,
    cfg: ServerConfig,
    rx: mpsc::Receiver<Envelope>,
    metrics: Arc<Registry>,
) {
    let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
    let shard_label = shard.to_string();
    let labels: [(&str, &str); 1] = [("shard", shard_label.as_str())];
    let req_counter = metrics.counter_labelled("requests_total", &labels);
    let infer_hist = metrics.histogram_labelled("infer_latency", &labels);
    let train_hist = metrics.histogram_labelled("train_latency", &labels);
    let trainings = metrics.counter_labelled("trainings_total", &labels);
    let inferences = metrics.counter_labelled("inferences_total", &labels);
    let rejected = metrics.counter_labelled("rejected_total", &labels);
    let online_updates = metrics.counter_labelled("online_updates_total", &labels);
    // Serve-phase reservoir adaptation (DESIGN.md §13): per-sample
    // truncated-BPTT steps, and generation rolls (re-featurize + reseed)
    let reservoir_updates = metrics.counter_labelled("reservoir_updates_total", &labels);
    let refeaturizes = metrics.counter_labelled("refeaturize_total", &labels);
    // drain-batch observability (DESIGN.md §14): `batch_size` records
    // one sample per drain cycle with the cycle's request count encoded
    // as microseconds (exact through `record_secs`: n·1e-6 s = n µs), so
    // `count` = drain cycles and `mean·count` = requests; `batch_splits`
    // counts planned items that re-ran per-call after a mid-batch
    // generation roll
    let batch_size = metrics.histogram_labelled("batch_size", &labels);
    let batch_splits = metrics.counter_labelled("batch_splits_total", &labels);

    let max_batch = cfg.max_batch.max(1);
    let mut batch: Vec<Envelope> = Vec::with_capacity(max_batch);
    // plan[i]: Some(tag) when batch[i]'s features were pre-extracted
    let mut plan: Vec<Option<PlanTag>> = Vec::with_capacity(max_batch);
    // grow-only per-lane feature buffers (r̃ per planned request)
    let mut feat_bufs: Vec<Vec<f32>> = Vec::new();

    while let Ok(first) = rx.recv() {
        batch.clear();
        batch.push(first);
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(env) => batch.push(env),
                Err(_) => break,
            }
        }
        batch_size.record_secs(batch.len() as f64 * 1e-6);

        // ---- plan: decide which requests can share one batched sweep
        plan.clear();
        {
            use crate::coordinator::engine::FeatureRequest;
            let mut reqs: Vec<FeatureRequest<'_>> = Vec::new();
            let engine_gen = engine.generation();
            let score_exact = engine.scores_from_features_exact();
            for (req, _) in &batch {
                let tag = match req {
                    Request::Labelled { session, sample } => sessions
                        .get(session)
                        .filter(|sess| {
                            // per-call would take the streaming fold at
                            // (gen_p, gen_q); anything else — Collect
                            // buffering, batch retrain triggers,
                            // validation rejects, pending datapath rolls
                            // (which must answer `Adapted`) — is not
                            // batchable
                            sess.streaming_serve()
                                && sess.sample_valid(sample)
                                && sess.engine_generation() == engine_gen
                        })
                        .map(|sess| (sess, sample)),
                    Request::Infer { session, sample } => sessions
                        .get(session)
                        .filter(|sess| {
                            // per-call scoring must be an exact function
                            // of r̃ (native; quant only while fallen
                            // back) and sync_generation must be a no-op
                            sess.phase == Phase::Serve
                                && score_exact
                                && sess.engine_generation() == engine_gen
                                && sample.v() == sess.cfg.n_v
                        })
                        .map(|sess| (sess, sample)),
                    _ => None,
                }
                .map(|(sess, sample)| {
                    let (p, q) = sess.serving_params();
                    reqs.push(FeatureRequest {
                        sample,
                        mask: &sess.mask,
                        p,
                        q,
                    });
                    PlanTag {
                        lane: reqs.len() - 1,
                        session_gen: sess.generation(),
                        engine_gen,
                    }
                });
                plan.push(tag);
            }
            // a single planned request gains nothing over per-call (the
            // kernel is bitwise-equal either way) — only sweep when the
            // batch actually amortizes
            if reqs.len() >= 2 {
                while feat_bufs.len() < reqs.len() {
                    feat_bufs.push(Vec::new());
                }
                if engine
                    .features_batch_into(&reqs, &mut feat_bufs[..reqs.len()])
                    .is_err()
                {
                    // per-call processing will surface the error per
                    // request with its usual Rejected mapping
                    plan.iter_mut().for_each(|t| *t = None);
                }
            } else {
                plan.iter_mut().for_each(|t| *t = None);
            }
        }

        // ---- process: strict arrival order, batched features where
        // still valid
        for (idx, (req, reply)) in batch.drain(..).enumerate() {
            req_counter.inc();
            let resp = match req {
                Request::Shutdown => {
                    // Ack the drain marker, then keep serving: anything
                    // still queued (or racing in) is answered until the
                    // server drops our sender and `recv` disconnects.
                    let _ = reply.send(Response::Bye);
                    continue;
                }
                // unreachable through `call`/`try_call` (answered inline
                // by the server handle); kept so a queued Stats still works
                Request::Stats => Response::StatsText(metrics.render()),
                Request::Labelled { session, sample } => {
                    let sess = sessions.entry(session).or_insert_with(|| {
                        Session::new(session, cfg.session.clone(), cfg.seed)
                    });
                    // footgun fix: an earlier item of this drain batch
                    // may have rolled the session generation (Adapted /
                    // fallback retrain) or the shared engine datapath —
                    // planned features are then stale and must NOT be
                    // folded (no cross-generation feature mixing)
                    let pre = plan[idx].filter(|t| {
                        let fresh = sess.generation() == t.session_gen
                            && sess.engine_generation() == t.engine_gen
                            && engine.generation() == t.engine_gen;
                        if !fresh {
                            batch_splits.inc();
                        }
                        fresh
                    });
                    let sw = crate::util::timer::Stopwatch::start();
                    let outcome = match pre {
                        Some(t) => sess.feed_labelled_with_features(
                            engine.as_ref(),
                            sample,
                            &feat_bufs[t.lane],
                        ),
                        None => sess.feed_labelled(engine.as_ref(), sample),
                    };
                    match outcome {
                        Ok(FeedOutcome::Buffered(n)) => Response::Accepted {
                            phase: sess.phase.name(),
                            buffered: n,
                        },
                        Ok(FeedOutcome::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }) => {
                            train_hist.record_secs(sw.elapsed_secs());
                            trainings.inc();
                            Response::Trained {
                                p,
                                q,
                                beta,
                                train_seconds,
                            }
                        }
                        Ok(FeedOutcome::Observed {
                            updates,
                            window,
                            reservoir_step,
                        }) => {
                            online_updates.inc();
                            if reservoir_step {
                                reservoir_updates.inc();
                            }
                            Response::Observed { updates, window }
                        }
                        Ok(FeedOutcome::Adapted {
                            generation,
                            p,
                            q,
                            updates,
                            reservoir_step,
                        }) => {
                            // the rolling sample was folded too
                            online_updates.inc();
                            if reservoir_step {
                                reservoir_updates.inc();
                            }
                            refeaturizes.inc();
                            Response::Adapted {
                                generation,
                                p,
                                q,
                                updates,
                            }
                        }
                        Ok(FeedOutcome::Rejected(msg)) => {
                            rejected.inc();
                            Response::Rejected(msg)
                        }
                        Err(e) => Response::Rejected(format!("engine error: {e:#}")),
                    }
                }
                Request::Infer { session, sample } => match sessions.get_mut(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => {
                        let pre = plan[idx].filter(|t| {
                            let fresh = sess.generation() == t.session_gen
                                && sess.engine_generation() == t.engine_gen
                                && engine.generation() == t.engine_gen;
                            if !fresh {
                                batch_splits.inc();
                            }
                            fresh
                        });
                        let sw = crate::util::timer::Stopwatch::start();
                        let result = match pre {
                            Some(t) => {
                                // freshness implies sync_generation is a
                                // no-op — the engine datapath equals what
                                // the factor was seeded under
                                sess.infer_with_features(engine.as_ref(), &feat_bufs[t.lane])
                            }
                            None => {
                                // track shared-datapath changes even on
                                // infer-only traffic (no-op unless the
                                // engine generation moved)
                                match sess.sync_generation(engine.as_ref()) {
                                    Ok(None) => {}
                                    Ok(Some(_)) => refeaturizes.inc(),
                                    Err(e) => {
                                        let _ = reply.send(Response::Rejected(format!(
                                            "engine error: {e:#}"
                                        )));
                                        continue;
                                    }
                                }
                                sess.infer(engine.as_ref(), &sample)
                            }
                        };
                        match result {
                            Ok((class, scores)) => {
                                infer_hist.record_secs(sw.elapsed_secs());
                                inferences.inc();
                                Response::Prediction { class, scores }
                            }
                            Err(e @ InferError::NotServing { .. }) => {
                                Response::Rejected(e.to_string())
                            }
                            Err(InferError::Engine(e)) => {
                                Response::Rejected(format!("engine error: {e:#}"))
                            }
                        }
                    }
                },
                Request::Finalize { session } => match sessions.get_mut(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => match sess.finalize(engine.as_ref()) {
                        Ok(FeedOutcome::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }) => Response::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        },
                        Ok(FeedOutcome::Rejected(msg)) => Response::Rejected(msg),
                        // finalize always runs the batch pipeline
                        Ok(
                            FeedOutcome::Buffered(_)
                            | FeedOutcome::Observed { .. }
                            | FeedOutcome::Adapted { .. },
                        ) => unreachable!(),
                        Err(e) => Response::Rejected(format!("engine error: {e:#}")),
                    },
                },
            };
            let _ = reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    fn server_with_shards(shards: usize) -> (Server, crate::data::dataset::Dataset) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 20,
            test: 10,
            t_min: 10,
            t_max: 12,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            13,
        );
        let mut scfg = SessionConfig::new(2, 2, 20);
        scfg.train.nx = 8;
        scfg.train.epochs = 3;
        scfg.train.res_decay_epochs = vec![2];
        scfg.train.out_decay_epochs = vec![2];
        let cfg = ServerConfig {
            session: scfg,
            queue_cap: 64,
            seed: 0xFEED,
            shards,
            max_batch: 8,
        };
        (Server::spawn(Box::new(NativeEngine::new(8, 2)), cfg), ds)
    }

    fn server() -> (Server, crate::data::dataset::Dataset) {
        server_with_shards(2)
    }

    #[test]
    fn end_to_end_train_then_serve() {
        let (srv, ds) = server();
        let mut last = None;
        for s in &ds.train {
            last = Some(
                srv.call(Request::Labelled {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap(),
            );
        }
        assert!(matches!(last, Some(Response::Trained { .. })), "{last:?}");
        let mut correct = 0;
        for s in &ds.test {
            match srv
                .call(Request::Infer {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap()
            {
                Response::Prediction { class, .. } => {
                    if class == s.label {
                        correct += 1;
                    }
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(correct >= 7, "{correct}/10");
        let stats = srv.call(Request::Stats).unwrap();
        match stats {
            Response::StatsText(t) => {
                assert!(t.contains("inferences_total 10"), "{t}");
                assert!(t.contains("trainings_total 1"), "{t}");
                // session 1 lives on shard 1 % 2
                assert!(t.contains("inferences_total{shard=\"1\"} 10"), "{t}");
            }
            other => panic!("{other:?}"),
        }
        srv.shutdown();
    }

    #[test]
    fn sessions_are_isolated() {
        let (srv, ds) = server();
        // session 2 never trained → inference rejected
        for s in ds.train.iter().take(3) {
            srv.call(Request::Labelled {
                session: 2,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv
            .call(Request::Infer {
                session: 2,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        // unknown session
        let r = srv
            .call(Request::Infer {
                session: 99,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)));
        srv.shutdown();
    }

    #[test]
    fn finalize_then_predict() {
        let (srv, ds) = server();
        for s in ds.train.iter().take(10) {
            srv.call(Request::Labelled {
                session: 5,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv.call(Request::Finalize { session: 5 }).unwrap();
        assert!(matches!(r, Response::Trained { .. }), "{r:?}");
        let r = srv
            .call(Request::Infer {
                session: 5,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }));
        srv.shutdown();
    }

    #[test]
    fn shard_count_clamps_to_at_least_one() {
        let (srv, ds) = server_with_shards(0);
        assert_eq!(srv.shards(), 1);
        let r = srv
            .call(Request::Labelled {
                session: 7,
                sample: ds.train[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Accepted { .. }), "{r:?}");
        srv.shutdown();
    }

    #[test]
    fn same_session_same_shard_across_requests() {
        // a session fed on a 4-shard server trains and serves exactly as
        // on a single shard — routing is stable
        let (srv, ds) = server_with_shards(4);
        assert_eq!(srv.shards(), 4);
        for s in &ds.train {
            srv.call(Request::Labelled {
                session: 6,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv
            .call(Request::Infer {
                session: 6,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
        srv.shutdown();
    }
}
