//! The sharded event loop: an N-shard worker pool, per-shard bounded
//! request queues, per-session routing, metrics — Rust owns the process
//! (no tokio; see `util::runtimex`).
//!
//! # Sharding
//!
//! [`Server::spawn`] starts `ServerConfig::shards` worker threads. Each
//! shard thread *exclusively owns* its `BTreeMap<u64, Session>` — there
//! is no cross-shard locking anywhere on the request path. A request for
//! session `id` is routed to shard `id % shards` at submit time, so all
//! requests for one session are serialized on one thread (the paper's
//! per-deployment protocol is inherently sequential) while distinct
//! sessions scale across cores.
//!
//! Each shard gets its own engine via [`Engine::fork`]; engines that
//! cannot be replicated (e.g. a single-owner PJRT client without
//! recompilable artifacts) degrade gracefully to fewer shards — the
//! effective count is exported as the `shards_active` metric.
//!
//! # Backpressure
//!
//! Two-level, as in the paper's bounded-memory edge design:
//! 1. every shard has a bounded request queue (`queue_cap` split evenly
//!    across shards); [`Server::try_call`] refuses (`None`) when the
//!    target shard's queue is saturated, [`Server::call`] blocks, and
//!    [`Server::call_timeout`] retries with backoff up to a deadline
//!    (`queue_retries_total`);
//! 2. each session's collect buffer is capped
//!    (`SessionConfig::buffer_cap`) — overflowing samples are `Rejected`.
//!    Sessions on the streaming Serve path (`TrainConfig::forgetting` /
//!    `::window`) never reject labelled samples at this level: each one
//!    is folded in O(s²) and answered `Observed` (counted by the
//!    per-shard `online_updates_total` metric), and the recent-sample
//!    buffer recycles as a bounded FIFO. With reservoir adaptation on
//!    (`SessionConfig::adapt_reservoir`), each fold also drives a
//!    truncated-BPTT step (`reservoir_updates_total`) and generation
//!    rolls answer `Adapted` (`refeaturize_total`) — see DESIGN.md §13.
//!
//! # Batched drain (DESIGN.md §14)
//!
//! After blocking on one request, a shard opportunistically drains up to
//! [`ServerConfig::max_batch`] queued requests and pre-extracts the
//! features of the batchable ones — streaming-Serve `Feed`s and exact-
//! score `Infer`s on the current generation, for sessions not flagged
//! degraded — through one [`Engine::features_batch_into`] sweep (the
//! node-major `BatchScratch` kernel on the native engine). Responses are
//! produced in strict arrival order with results **bitwise equal** to
//! per-call processing (`tests/batch_equivalence.rs`); a mid-batch
//! generation roll splits the batch (stale lanes re-run per-call,
//! `batch_splits_total`). The `batch_size` histogram records one sample
//! per drain cycle (size encoded as µs).
//!
//! # Fault tolerance (DESIGN.md §15)
//!
//! Every request is processed inside `catch_unwind`: a panic in the
//! engine or session logic is isolated to the one request that tripped
//! it, answered with a typed [`Response::Error`] (`request_panics_total`),
//! and the touched session is flagged degraded so its next labelled
//! sample runs the batch-retrain recovery path instead of trusting
//! possibly-torn streaming state. Panics during the batched feature
//! sweep drop the whole plan and fall back to per-call processing
//! (`plan_panics_total`). Non-finite inference scores are quarantined
//! the same way (`nonfinite_quarantined_total`).
//!
//! A shard can still die — deliberately (the fault harness's
//! [`ShardKill`] payload is re-raised, not swallowed) or through a
//! non-unwinding abort. A supervisor thread polls the worker handles;
//! when one exits outside shutdown it forks a fresh engine replica from
//! a reserve template, rehydrates the shard's sessions from the last
//! durable checkpoint, and swaps the new queue sender into the shard's
//! slot (`shard_deaths_total` / `shard_respawns_total`; `shards_active`
//! dips and recovers). Callers racing the respawn see a typed
//! [`CallError`]; [`Server::call_timeout`] retries through the gap.
//!
//! # Durable checkpoints
//!
//! With `ServerConfig::checkpoint` set, each shard snapshots its session
//! map to `<dir>/shard-<i>.ckpt` (atomic write-then-rename, CRC-guarded;
//! see `coordinator::checkpoint`) every `every` state-mutating requests
//! and once more when the shutdown drain marker is processed. At spawn,
//! existing archives are decoded, deduplicated (highest mutation count
//! wins) and partitioned back onto their owning shards, so a restarted
//! server resumes bitwise-identically from the last checkpoint boundary
//! (`tests/fault_injection.rs`).
//!
//! # Shutdown
//!
//! [`Server::shutdown`] drains every shard in order: it enqueues a
//! `Shutdown` marker behind the shard's pending requests and waits up to
//! `ServerConfig::drain_timeout` for the `Bye` ack, which the shard only
//! sends after answering everything ahead of the marker. A dead or
//! wedged shard cannot ack — it is skipped after the deadline
//! (`shutdown_drain_skipped_total`) instead of hanging the caller.
//! Shards then keep serving stragglers until the server disconnects
//! their queues, and the supervisor joins them with the same bound.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::checkpoint::{self, CheckpointConfig, ShardCheckpointer};
use super::engine::Engine;
use super::hibernate::{HibernateConfig, ShardHibernator};
use super::faulty::{InjectedPanic, ShardKill};
use super::protocol::{ErrorKind, Request, Response};
use super::session::{FeedOutcome, InferError, Phase, Session, SessionConfig, SessionSnapshot};
use crate::util::metrics::{Counter, Gauge, Histogram, Registry};
use crate::util::trace::{self, EventKind, EventLog, Stage, TraceHub, TraceRecord, NO_SESSION};
use crate::{log_error, log_warn};

/// Capacity of the server-wide operational event journal
/// (`Request::Events`); evictions past it are counted, not silent.
const EVENT_LOG_CAP: usize = 1024;

/// A queued request with its reply channel, trace id and enqueue stamp.
struct Envelope {
    req: Request,
    reply: mpsc::Sender<Response>,
    /// Trace id minted at the public call edge (0 = untraced internal).
    trace: u64,
    /// When the envelope was built — queue residency (`queue_wait`,
    /// including any backpressure backoff) is measured from here.
    enqueued: Instant,
}

impl Envelope {
    fn new(req: Request, reply: mpsc::Sender<Response>, trace: u64) -> Self {
        Envelope {
            req,
            reply,
            trace,
            enqueued: Instant::now(),
        }
    }
}

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// template for newly-created sessions
    pub session: SessionConfig,
    /// total request-queue capacity, split evenly across shards
    /// (global backpressure)
    pub queue_cap: usize,
    pub seed: u64,
    /// worker shards; sessions are routed by `session_id % shards`.
    /// Clamped to ≥ 1, and reduced when the engine cannot [`Engine::fork`]
    /// enough replicas.
    pub shards: usize,
    /// Upper bound on the shard drain batch: after blocking on one
    /// request, a shard opportunistically drains up to `max_batch − 1`
    /// more already-queued requests and runs their feature extractions
    /// as one [`Engine::features_batch_into`] sweep. Responses keep
    /// strict FIFO order per shard (hence per session), and a value of 1
    /// disables batching entirely. Clamped to ≥ 1.
    pub max_batch: usize,
    /// Durable session checkpointing (None disables it): shards snapshot
    /// to `<dir>/shard-<i>.ckpt` every `every` mutating requests plus at
    /// shutdown, and `spawn` rehydrates sessions from the directory.
    pub checkpoint: Option<CheckpointConfig>,
    /// How long `shutdown` waits for each shard's drain ack — and the
    /// supervisor for the worker threads — before skipping it. A dead
    /// shard never stalls shutdown longer than this.
    pub drain_timeout: Duration,
    /// Session hibernation (None disables it): each shard parks cold
    /// sessions into `<dir>/shard-<i>/` per the LRU/idle policy and
    /// rehydrates them on next touch — see `coordinator::hibernate`
    /// and DESIGN.md §16.
    pub hibernate: Option<HibernateConfig>,
    /// Emit a structured WARN line with the per-stage span breakdown for
    /// any request whose total latency (enqueue → reply) exceeds this
    /// many milliseconds. `None` disables the slow-request log.
    pub slow_request_ms: Option<u64>,
    /// Per-shard trace ring capacity: how many completed request traces
    /// each shard retains for `Request::Traces`. Clamped to ≥ 1.
    pub trace_ring: usize,
}

impl ServerConfig {
    /// Config with the defaults used by the CLI: queue of 256, one shard
    /// per available core, drain batches of up to 8, no checkpointing,
    /// 5 s shutdown drain bound.
    pub fn new(session: SessionConfig) -> Self {
        ServerConfig {
            session,
            queue_cap: 256,
            seed: 0,
            shards: default_shards(),
            max_batch: 8,
            checkpoint: None,
            drain_timeout: Duration::from_secs(5),
            hibernate: None,
            slow_request_ms: None,
            trace_ring: 256,
        }
    }
}

/// One shard per available core (the bench's sweet spot for the
/// compute-bound native engine).
pub fn default_shards() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Typed transport failure for [`Server::call`] / [`Server::try_call`] /
/// [`Server::call_timeout`]. Distinguishes "the shard is gone" (retry
/// may reach a respawned replica) from "the request was accepted but its
/// reply was lost" (the shard died mid-request; at-most-once, resubmit
/// if idempotent) from a plain deadline miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallError {
    /// The target shard's queue is disconnected (shard died and no
    /// respawn has replaced it yet, or the server is stopped).
    ShardDown { shard: usize },
    /// The request was enqueued but the shard died before replying.
    ReplyLost { shard: usize },
    /// Deadline expired while the queue stayed saturated or the reply
    /// never arrived.
    Timeout { shard: usize },
}

impl std::fmt::Display for CallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CallError::ShardDown { shard } => write!(f, "shard {shard} down"),
            CallError::ReplyLost { shard } => {
                write!(f, "reply lost: shard {shard} died mid-request")
            }
            CallError::Timeout { shard } => write!(f, "timed out waiting on shard {shard}"),
        }
    }
}

impl std::error::Error for CallError {}

/// Why the public call paths refuse `Request::Shutdown` (the documented
/// footgun: sent through `call` it would drain and ack exactly one
/// shard, leaving the rest serving — a half-stopped server).
const SHUTDOWN_VIA_CALL: &str =
    "Shutdown is a per-shard drain marker and would only drain one shard; \
     use Server::shutdown";

/// Why the public call paths refuse `Request::Ping` (it is the
/// readiness probe's queue check, not a wire request — remote peers
/// health-check through the exporter's `/readyz`).
const PING_VIA_CALL: &str =
    "Ping is the internal readiness probe; health-check via /readyz";

/// Per-shard queue senders behind mutexes, so the supervisor can swap in
/// a respawned shard's sender while callers keep cloning the current one
/// (lock → clone → unlock; no lock is held across a send).
struct Slots {
    txs: Vec<Mutex<mpsc::SyncSender<Envelope>>>,
}

impl Slots {
    fn sender(&self, shard: usize) -> mpsc::SyncSender<Envelope> {
        match self.txs[shard].lock() {
            Ok(g) => g.clone(),
            // a poisoned slot still holds a valid sender (clone can't panic)
            Err(p) => p.into_inner().clone(),
        }
    }

    fn set(&self, shard: usize, tx: mpsc::SyncSender<Envelope>) {
        match self.txs[shard].lock() {
            Ok(mut g) => *g = tx,
            Err(p) => *p.into_inner() = tx,
        }
    }
}

/// Handle to a running server (owns the supervisor, which owns the shard
/// worker threads).
pub struct Server {
    slots: Arc<Slots>,
    supervisor: Option<thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    drain_timeout: Duration,
    queue_retries: Arc<Counter>,
    hub: Arc<TraceHub>,
    events: Arc<EventLog>,
    shards_active: Arc<Gauge>,
    checkpoint_dir: Option<std::path::PathBuf>,
    pub metrics: Arc<Registry>,
}

impl Server {
    /// Spawn the shard pool over an engine.
    ///
    /// The engine is forked once per extra shard, plus once more as the
    /// supervisor's reserve template for respawning dead shards; if the
    /// engine cannot be replicated the server runs with however many
    /// replicas it got (at least one — the engine passed in) and dead
    /// shards stay down.
    ///
    /// Forks run serially on the spawning thread. For `NativeEngine`
    /// that is free; for `PjrtEngine` every fork recompiles the five HLO
    /// entry points (~1 s each), so with the one-shard-per-core default
    /// startup cost scales with core count — size `shards` deliberately
    /// for PJRT deployments.
    ///
    /// With `cfg.checkpoint` set, any `shard-*.ckpt` archives in the
    /// directory are decoded and their sessions rehydrated onto their
    /// owning shards before the first request is served; unreadable
    /// archives or snapshots count `checkpoint_restore_errors_total`
    /// and are skipped, never fatal.
    pub fn spawn(engine: Box<dyn Engine>, cfg: ServerConfig) -> Server {
        let want = cfg.shards.max(1);
        let mut engines: Vec<Box<dyn Engine>> = vec![engine];
        while engines.len() < want {
            match engines[0].fork() {
                Some(e) => engines.push(e),
                None => break,
            }
        }
        let shards = engines.len();
        // reserve replica for respawns — forked up-front so a PJRT-style
        // engine pays compilation now, not during recovery
        let template = engines[0].fork();
        let metrics = Arc::new(Registry::default());
        let shards_active = metrics.gauge("shards_active");
        shards_active.add(shards as i64);
        let hub = Arc::new(TraceHub::new(shards, cfg.trace_ring, cfg.slow_request_ms));
        let events = Arc::new(EventLog::new(EVENT_LOG_CAP));
        // pre-register the fleet counters so a Stats snapshot shows them
        // at zero before the first fault
        for name in [
            "shard_deaths_total",
            "shard_respawns_total",
            "queue_retries_total",
            "shutdown_drain_skipped_total",
            "sessions_restored_total",
            "checkpoint_restore_errors_total",
        ] {
            metrics.counter(name);
        }
        if cfg.hibernate.is_some() {
            for name in [
                "sessions_hibernated_total",
                "sessions_rehydrated_total",
                "hibernate_errors_total",
                "rehydrate_errors_total",
            ] {
                metrics.counter(name);
            }
        }
        let per_shard_cap = (cfg.queue_cap.max(1) + shards - 1) / shards;
        let mut snaps_by_shard: Vec<Vec<SessionSnapshot>> =
            (0..shards).map(|_| Vec::new()).collect();
        if let Some(ck) = &cfg.checkpoint {
            let (all, corrupt) = checkpoint::load_all(&ck.dir);
            if corrupt > 0 {
                metrics.counter("checkpoint_restore_errors_total").add(corrupt);
                log_warn!("{corrupt} corrupt checkpoint archive(s) under {:?}", ck.dir);
            }
            for snap in all {
                let i = (snap.id % shards as u64) as usize;
                snaps_by_shard[i].push(snap);
            }
        }
        let mut txs = Vec::with_capacity(shards);
        let mut handles: Vec<Option<thread::JoinHandle<()>>> = Vec::with_capacity(shards);
        for (i, (eng, snaps)) in engines.into_iter().zip(snaps_by_shard).enumerate() {
            // a failed thread spawn at startup is unrecoverable resource
            // exhaustion — nothing to degrade to
            #[allow(clippy::expect_used)]
            let (tx, h) = spawn_shard(
                i,
                eng,
                cfg.clone(),
                Arc::clone(&metrics),
                snaps,
                per_shard_cap,
                Arc::clone(&hub),
                Arc::clone(&events),
            )
            .expect("spawn shard thread");
            txs.push(Mutex::new(tx));
            handles.push(Some(h));
        }
        let slots = Arc::new(Slots { txs });
        let stopping = Arc::new(AtomicBool::new(false));
        let sup = Supervisor {
            slots: Arc::clone(&slots),
            handles,
            template,
            cfg: cfg.clone(),
            metrics: Arc::clone(&metrics),
            stopping: Arc::clone(&stopping),
            per_shard_cap,
            hub: Arc::clone(&hub),
            events: Arc::clone(&events),
        };
        #[allow(clippy::expect_used)]
        let supervisor = thread::Builder::new()
            .name("dfr-supervisor".into())
            .spawn(move || supervise(sup))
            .expect("spawn supervisor thread");
        let queue_retries = metrics.counter("queue_retries_total");
        let checkpoint_dir = cfg.checkpoint.as_ref().map(|c| c.dir.clone());
        Server {
            slots,
            supervisor: Some(supervisor),
            stopping,
            drain_timeout: cfg.drain_timeout,
            queue_retries,
            hub,
            events,
            shards_active,
            checkpoint_dir,
            metrics,
        }
    }

    /// Number of shard slots (may be fewer than requested if the engine
    /// could not be forked). Slots stay routable across a respawn; the
    /// live count at any instant is the `shards_active` metric.
    pub fn shards(&self) -> usize {
        self.slots.txs.len()
    }

    /// The server's trace hub: id mint, per-shard trace rings and the
    /// slow-request threshold.
    pub fn trace_hub(&self) -> &Arc<TraceHub> {
        &self.hub
    }

    /// The server's operational event journal (`Request::Events`).
    pub fn events(&self) -> &Arc<EventLog> {
        &self.events
    }

    /// Live shard count right now (the `shards_active` gauge — dips
    /// while the supervisor is burying and respawning a dead shard).
    pub fn shards_active(&self) -> i64 {
        self.shards_active.get()
    }

    /// Readiness probe backing the exporter's `/readyz`: every shard
    /// slot live (`shards_active == shards`), every shard queue
    /// accepting a [`Request::Ping`] probe (a wedged or saturated queue
    /// refuses it), and the checkpoint directory — when configured —
    /// still writable. Returns the first failing condition as a
    /// human-readable reason.
    pub fn readiness(&self) -> Result<(), String> {
        let live = self.shards_active.get();
        let want = self.shards() as i64;
        if live != want {
            return Err(format!("{live}/{want} shards active"));
        }
        for shard in 0..self.shards() {
            // the probe only checks that the queue accepts work; the
            // shard answers `Bye` into the dropped channel, harmlessly
            let (rtx, _rrx) = mpsc::channel();
            match self
                .slots
                .sender(shard)
                .try_send(Envelope::new(Request::Ping, rtx, 0))
            {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(_)) => {
                    return Err(format!("shard {shard}: queue saturated"));
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    return Err(format!("shard {shard}: queue disconnected"));
                }
            }
        }
        if let Some(dir) = &self.checkpoint_dir {
            if !checkpoint::dir_writable(dir) {
                return Err(format!("checkpoint dir {} not writable", dir.display()));
            }
        }
        Ok(())
    }

    /// The shard a request will be routed to.
    fn route(&self, req: &Request) -> usize {
        match req.session_id() {
            Some(id) => (id % self.slots.txs.len() as u64) as usize,
            // session-less requests never reach a queue through the
            // public paths (Stats is answered inline, Shutdown rejected);
            // shard 0 is a safe default for internal callers.
            None => 0,
        }
    }

    /// Send a request and wait for the response (blocks under
    /// backpressure).
    ///
    /// `Stats` is answered directly from the shared registry without
    /// entering any shard queue — monitoring stays instant even when
    /// every shard is saturated with slow trainings.
    ///
    /// Never hangs on a dead shard: a disconnected queue is
    /// [`CallError::ShardDown`], and a shard dying after accepting the
    /// request drops the reply sender, surfacing
    /// [`CallError::ReplyLost`] instead of blocking forever.
    pub fn call(&self, req: Request) -> Result<Response, CallError> {
        if let Some(resp) = self.inline_answer(&req) {
            return Ok(resp);
        }
        let shard = self.route(&req);
        let (rtx, rrx) = mpsc::channel();
        self.slots
            .sender(shard)
            .send(Envelope::new(req, rtx, self.hub.mint()))
            .map_err(|_| CallError::ShardDown { shard })?;
        rrx.recv().map_err(|_| CallError::ReplyLost { shard })
    }

    /// Requests the server handle answers without entering any shard
    /// queue: observability reads (`Stats`/`Traces`/`Events`) stay
    /// instant even when every shard is saturated with slow trainings,
    /// and the internal markers (`Shutdown`, `Ping`) are refused on the
    /// public paths. `None` means "route to a shard".
    fn inline_answer(&self, req: &Request) -> Option<Response> {
        match req {
            Request::Stats => Some(Response::StatsText(self.metrics.render())),
            Request::Traces { n } => Some(Response::Traces(self.hub.last_json(*n))),
            Request::Events { n } => Some(Response::Events(self.events.last_json(*n))),
            Request::Shutdown => Some(Response::Rejected(SHUTDOWN_VIA_CALL.into())),
            Request::Ping => Some(Response::Rejected(PING_VIA_CALL.into())),
            _ => None,
        }
    }

    /// Non-blocking send; `Ok(None)` means the target shard's queue is
    /// saturated (backpressure) — the caller should retry or shed load.
    /// `Stats` never sheds: the receiver already holds the snapshot.
    pub fn try_call(
        &self,
        req: Request,
    ) -> Result<Option<mpsc::Receiver<Response>>, CallError> {
        let (rtx, rrx) = mpsc::channel();
        if let Some(resp) = self.inline_answer(&req) {
            let _ = rtx.send(resp);
            return Ok(Some(rrx));
        }
        let shard = self.route(&req);
        match self
            .slots
            .sender(shard)
            .try_send(Envelope::new(req, rtx, self.hub.mint()))
        {
            Ok(()) => Ok(Some(rrx)),
            Err(mpsc::TrySendError::Full(_)) => Ok(None),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(CallError::ShardDown { shard }),
        }
    }

    /// [`Server::call`] with a deadline: retries a saturated queue with
    /// exponential backoff (100 µs doubling to 5 ms, counted by
    /// `queue_retries_total`), and keeps re-fetching the shard's current
    /// sender so a request submitted while the supervisor is respawning
    /// the shard lands on the fresh replica instead of failing fast.
    pub fn call_timeout(&self, req: Request, timeout: Duration) -> Result<Response, CallError> {
        if let Some(resp) = self.inline_answer(&req) {
            return Ok(resp);
        }
        let deadline = Instant::now() + timeout;
        let shard = self.route(&req);
        let (rtx, rrx) = mpsc::channel();
        let mut env = Envelope::new(req, rtx, self.hub.mint());
        let mut backoff = Duration::from_micros(100);
        loop {
            let (returned, was_down) = match self.slots.sender(shard).try_send(env) {
                Ok(()) => break,
                Err(mpsc::TrySendError::Full(e)) => (e, false),
                Err(mpsc::TrySendError::Disconnected(e)) => (e, true),
            };
            env = returned;
            self.queue_retries.inc();
            let now = Instant::now();
            if now >= deadline {
                return Err(if was_down {
                    CallError::ShardDown { shard }
                } else {
                    CallError::Timeout { shard }
                });
            }
            thread::sleep(backoff.min(deadline - now));
            backoff = (backoff * 2).min(Duration::from_millis(5));
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        rrx.recv_timeout(remaining).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => CallError::Timeout { shard },
            mpsc::RecvTimeoutError::Disconnected => CallError::ReplyLost { shard },
        })
    }

    /// Graceful shutdown: drain every shard queue in order (bounded by
    /// `drain_timeout` per shard — a dead shard is skipped, not waited
    /// on), then join the workers. All requests accepted before this
    /// call on a healthy shard are answered first; each checkpointing
    /// shard writes a final snapshot when it processes the drain marker,
    /// giving restart a well-defined recovery boundary.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        let drain_skipped = self.metrics.counter("shutdown_drain_skipped_total");
        let n = self.slots.txs.len();
        for shard in 0..n {
            let deadline = Instant::now() + self.drain_timeout;
            let (rtx, rrx) = mpsc::channel();
            // Enqueue the drain marker without ever blocking forever: a
            // wedged shard can leave its queue full, and a dead one
            // leaves it disconnected — both are skipped at the deadline
            // (the shutdown-vs-dead-shard race).
            let mut env = Envelope::new(Request::Shutdown, rtx, 0);
            let sent = loop {
                match self.slots.sender(shard).try_send(env) {
                    Ok(()) => break true,
                    Err(mpsc::TrySendError::Disconnected(_)) => break false,
                    Err(mpsc::TrySendError::Full(e)) => {
                        if Instant::now() >= deadline {
                            break false;
                        }
                        env = e;
                        thread::sleep(Duration::from_millis(1));
                    }
                }
            };
            // Bye arrives only after everything queued ahead of the
            // marker has been answered — but a shard that died after
            // accepting the marker can never ack, so the wait is bounded.
            let acked = sent
                && rrx
                    .recv_timeout(deadline.saturating_duration_since(Instant::now()))
                    .is_ok();
            if !acked {
                drain_skipped.inc();
                log_warn!(
                    "shard {shard}: no drain ack within {:?}; skipping",
                    self.drain_timeout
                );
            }
        }
        // Disconnect every queue by swapping in a sender whose receiver
        // is already gone; shards drain any stragglers that raced in
        // behind the markers, then exit.
        for shard in 0..n {
            let (dangling, _) = mpsc::sync_channel::<Envelope>(1);
            self.slots.set(shard, dangling);
        }
        // The supervisor joins the workers (bounded — it detaches a
        // wedged shard rather than hanging), then exits itself.
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Supervisor state: polls worker handles, buries dead shards, respawns
/// them from the reserve engine template with sessions rehydrated from
/// the durable checkpoint.
struct Supervisor {
    slots: Arc<Slots>,
    handles: Vec<Option<thread::JoinHandle<()>>>,
    template: Option<Box<dyn Engine>>,
    cfg: ServerConfig,
    metrics: Arc<Registry>,
    stopping: Arc<AtomicBool>,
    per_shard_cap: usize,
    hub: Arc<TraceHub>,
    events: Arc<EventLog>,
}

fn supervise(mut sup: Supervisor) {
    let poll = Duration::from_millis(10);
    let shards = sup.handles.len();
    while !sup.stopping.load(Ordering::SeqCst) {
        for shard in 0..shards {
            let dead = sup.handles[shard]
                .as_ref()
                .is_some_and(|h| h.is_finished());
            if !dead {
                continue;
            }
            if let Some(h) = sup.handles[shard].take() {
                // collect the panic payload (ShardKill or abort-grade);
                // the per-request guard already isolated everything else
                let _ = h.join();
            }
            if sup.stopping.load(Ordering::SeqCst) {
                break;
            }
            sup.metrics.gauge("shards_active").dec();
            sup.metrics.counter("shard_deaths_total").inc();
            sup.events.push(
                EventKind::ShardDeath,
                shard as u32,
                NO_SESSION,
                "worker thread exited outside shutdown".into(),
            );
            log_warn!("shard {shard} died; respawning from the reserve replica");
            let Some(engine) = sup.template.as_ref().and_then(|t| t.fork()) else {
                log_error!(
                    "shard {shard}: engine has no replica to respawn with; shard stays down"
                );
                continue;
            };
            let mut snaps = Vec::new();
            if let Some(ck) = &sup.cfg.checkpoint {
                let (all, corrupt) = checkpoint::load_all(&ck.dir);
                if corrupt > 0 {
                    sup.metrics
                        .counter("checkpoint_restore_errors_total")
                        .add(corrupt);
                }
                snaps = all
                    .into_iter()
                    .filter(|s| (s.id % shards as u64) as usize == shard)
                    .collect();
            }
            match spawn_shard(
                shard,
                engine,
                sup.cfg.clone(),
                Arc::clone(&sup.metrics),
                snaps,
                sup.per_shard_cap,
                Arc::clone(&sup.hub),
                Arc::clone(&sup.events),
            ) {
                Ok((tx, h)) => {
                    sup.slots.set(shard, tx);
                    sup.handles[shard] = Some(h);
                    sup.metrics.gauge("shards_active").inc();
                    sup.metrics.counter("shard_respawns_total").inc();
                    sup.events.push(
                        EventKind::ShardRespawn,
                        shard as u32,
                        NO_SESSION,
                        "respawned from the reserve replica".into(),
                    );
                }
                Err(e) => log_error!("shard {shard}: respawn thread failed: {e}"),
            }
        }
        thread::sleep(poll);
    }
    // shutdown: join the workers with a bound — a wedged shard is
    // detached (its thread dies with the process), never waited forever
    let deadline = Instant::now() + sup.cfg.drain_timeout;
    while Instant::now() < deadline
        && sup
            .handles
            .iter()
            .any(|h| h.as_ref().is_some_and(|h| !h.is_finished()))
    {
        thread::sleep(poll);
    }
    for (shard, slot) in sup.handles.iter_mut().enumerate() {
        if let Some(h) = slot.take() {
            if h.is_finished() {
                let _ = h.join();
            } else {
                log_warn!("shard {shard} unresponsive at shutdown; detaching");
            }
        }
    }
}

/// Create a shard's bounded queue and worker thread (used both at spawn
/// and by the supervisor when respawning a dead shard).
#[allow(clippy::too_many_arguments)]
fn spawn_shard(
    shard: usize,
    engine: Box<dyn Engine>,
    cfg: ServerConfig,
    metrics: Arc<Registry>,
    snapshots: Vec<SessionSnapshot>,
    per_shard_cap: usize,
    hub: Arc<TraceHub>,
    events: Arc<EventLog>,
) -> std::io::Result<(mpsc::SyncSender<Envelope>, thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::sync_channel::<Envelope>(per_shard_cap);
    let h = thread::Builder::new()
        .name(format!("dfr-shard-{shard}"))
        .spawn(move || shard_loop(shard, engine, cfg, rx, metrics, snapshots, hub, events))?;
    Ok((tx, h))
}

/// The generation coordinates a batched feature extraction was planned
/// at. Re-validated immediately before each item is processed: an
/// earlier item in the same drain batch may have rolled the session's
/// generation (`Adapted`/`Trained`) or the engine's shared datapath — a
/// mismatch splits the batch and the item re-runs per-call
/// (`batch_splits_total`), so features never mix generations.
#[derive(Clone, Copy)]
struct PlanTag {
    /// lane index into the drained feature buffers
    lane: usize,
    /// `Session::generation` at plan time
    session_gen: u64,
    /// `Session::engine_generation` (== `Engine::generation`) at plan time
    engine_gen: u64,
}

/// Decide which requests of a drain batch can share one batched feature
/// sweep, and run it. Runs under the shard's panic guard: a panic here
/// aborts only the plan (all lanes fall back to per-call processing).
///
/// Returns the microseconds spent inside the forward sweep itself, so
/// the caller can split the cycle's time into the `plan` and
/// `batch_forward` trace stages.
fn plan_batch(
    batch: &[Envelope],
    sessions: &BTreeMap<u64, Session>,
    engine: &dyn Engine,
    plan: &mut Vec<Option<PlanTag>>,
    feat_bufs: &mut Vec<Vec<f32>>,
) -> u64 {
    use crate::coordinator::engine::FeatureRequest;
    let mut reqs: Vec<FeatureRequest<'_>> = Vec::new();
    let engine_gen = engine.generation();
    let score_exact = engine.scores_from_features_exact();
    for env in batch {
        let tag = match &env.req {
            Request::Labelled { session, sample } => sessions
                .get(session)
                .filter(|sess| {
                    // per-call would take the streaming fold at
                    // (gen_p, gen_q); anything else — Collect
                    // buffering, batch retrain triggers, validation
                    // rejects, pending datapath rolls (which must
                    // answer `Adapted`), or a degraded session whose
                    // next feed runs the recovery retrain — is not
                    // batchable
                    sess.batchable()
                        && sess.streaming_serve()
                        && sess.sample_valid(sample)
                        && sess.engine_generation() == engine_gen
                })
                .map(|sess| (sess, sample)),
            Request::Infer { session, sample } => sessions
                .get(session)
                .filter(|sess| {
                    // per-call scoring must be an exact function
                    // of r̃ (native; quant only while fallen
                    // back) and sync_generation must be a no-op
                    sess.batchable()
                        && sess.phase == Phase::Serve
                        && score_exact
                        && sess.engine_generation() == engine_gen
                        && sample.v() == sess.cfg.n_v
                })
                .map(|sess| (sess, sample)),
            _ => None,
        }
        .map(|(sess, sample)| {
            let (p, q) = sess.serving_params();
            reqs.push(FeatureRequest {
                sample,
                mask: &sess.mask,
                p,
                q,
            });
            PlanTag {
                lane: reqs.len() - 1,
                session_gen: sess.generation(),
                engine_gen,
            }
        });
        plan.push(tag);
    }
    // a single planned request gains nothing over per-call (the
    // kernel is bitwise-equal either way) — only sweep when the
    // batch actually amortizes
    if reqs.len() >= 2 {
        while feat_bufs.len() < reqs.len() {
            feat_bufs.push(Vec::new());
        }
        let sweep = Instant::now();
        let swept = engine.features_batch_into(&reqs, &mut feat_bufs[..reqs.len()]);
        let sweep_us = sweep.elapsed().as_micros() as u64;
        if swept.is_err() {
            // per-call processing will surface the error per
            // request with its usual mapping
            plan.iter_mut().for_each(|t| *t = None);
        }
        sweep_us
    } else {
        plan.iter_mut().for_each(|t| *t = None);
        0
    }
}

/// Human-readable panic payload for the typed `Error` reply.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else if payload.is::<InjectedPanic>() {
        "injected panic"
    } else {
        "opaque panic payload"
    }
}

/// Ship a reply and complete its trace: the send runs under the `reply`
/// span, then the accumulator is closed and the record lands in the
/// shard's ring (plus the per-stage latency histograms). Allocation-free
/// on the steady-state path — only the hub's gated slow-request log
/// formats.
#[allow(clippy::too_many_arguments)]
fn finish_request(
    reply: mpsc::Sender<Response>,
    resp: Response,
    trace_id: u64,
    enqueued: Instant,
    kind: u8,
    session: u64,
    shard: u32,
    depth: u16,
    stage_hists: &[Arc<Histogram>; trace::N_STAGES],
    hub: &TraceHub,
) {
    let outcome = resp.kind_code();
    {
        let _span = trace::span(Stage::Reply);
        let _ = reply.send(resp);
    }
    let stages_us = trace::take_stages();
    let total_us = enqueued.elapsed().as_micros() as u64;
    // zero-length spans are skipped, not recorded: a stage that never
    // ran would otherwise flood bucket 0 of every histogram
    for (hist, &us) in stage_hists.iter().zip(stages_us.iter()) {
        if us > 0 {
            hist.record_us(us);
        }
    }
    hub.record(&TraceRecord {
        trace_id,
        session,
        shard,
        kind,
        outcome,
        batch: depth,
        end_us: trace::epoch_us(),
        total_us,
        stages_us,
    });
}

/// One shard: exclusively owns its session map and engine replica, and
/// registers `shard`-labelled instruments in the shared registry.
///
/// # Batched drain
///
/// The loop blocks on one request, then opportunistically drains up to
/// `max_batch − 1` more from its queue. Requests whose feature
/// extraction is batchable — streaming-Serve `Feed`s and `Infer`s whose
/// served generation matches the engine datapath (and, for `Infer`, an
/// engine whose scores are an exact function of r̃) — run through one
/// [`Engine::features_batch_into`] sweep, then every request is answered
/// **in arrival order** with its precomputed features (or per-call when
/// planning skipped it). Ordering, backpressure, and the
/// `Observed`/`Adapted` semantics of DESIGN.md §13 are unchanged:
/// a request that the per-call path would answer `Adapted` (generation
/// mismatch) is never planned, and a mid-batch generation roll
/// invalidates later planned items via their [`PlanTag`].
///
/// # Panic isolation
///
/// Shutdown and Stats are handled outside the guard (they touch no
/// session state); everything else runs inside `catch_unwind`. A caught
/// panic answers `Response::Error{kind: Panic}`, counts
/// `request_panics_total`, and flags the touched session degraded — its
/// next labelled sample runs the batch-retrain recovery path, so torn
/// streaming state is never folded forward. The fault harness's
/// [`ShardKill`] payload is deliberately re-raised so the supervisor's
/// respawn path stays testable.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard: usize,
    engine: Box<dyn Engine>,
    cfg: ServerConfig,
    rx: mpsc::Receiver<Envelope>,
    metrics: Arc<Registry>,
    snapshots: Vec<SessionSnapshot>,
    hub: Arc<TraceHub>,
    events: Arc<EventLog>,
) {
    // the hibernation policy head opens the shard's store first so
    // checkpoint-vs-store id collisions resolve before any session is
    // rehydrated; a store that cannot open disables hibernation for
    // this shard (loudly) rather than failing the spawn
    let mut hib = cfg.hibernate.as_ref().and_then(|h| {
        match ShardHibernator::new(h, shard, &metrics) {
            Ok(mut hb) => {
                hb.set_events(Arc::clone(&events));
                Some(hb)
            }
            Err(e) => {
                log_warn!("shard {shard}: hibernation disabled (store open failed): {e}");
                None
            }
        }
    });
    let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
    {
        let restored = metrics.counter("sessions_restored_total");
        let restore_errs = metrics.counter("checkpoint_restore_errors_total");
        for snap in snapshots {
            // an id present in both a checkpoint archive and the
            // hibernation store resolves by mutation freshness; the
            // hibernated copy always leaves the store
            let snap = match hib.as_mut() {
                Some(h) => h.resolve_restore_conflict(snap),
                None => snap,
            };
            let id = snap.id;
            match Session::restore(snap, cfg.session.clone()) {
                Ok(sess) => {
                    sessions.insert(id, sess);
                    restored.inc();
                }
                Err(e) => {
                    restore_errs.inc();
                    log_warn!("shard {shard}: dropping checkpointed session {id}: {e}");
                }
            }
        }
    }
    let mut ckpt = cfg
        .checkpoint
        .as_ref()
        .map(|c| ShardCheckpointer::new(c, shard));

    let shard_label = shard.to_string();
    let labels: [(&str, &str); 1] = [("shard", shard_label.as_str())];
    let req_counter = metrics.counter_labelled("requests_total", &labels);
    let infer_hist = metrics.histogram_labelled("infer_latency", &labels);
    let train_hist = metrics.histogram_labelled("train_latency", &labels);
    let trainings = metrics.counter_labelled("trainings_total", &labels);
    let inferences = metrics.counter_labelled("inferences_total", &labels);
    let rejected = metrics.counter_labelled("rejected_total", &labels);
    let online_updates = metrics.counter_labelled("online_updates_total", &labels);
    // Serve-phase reservoir adaptation (DESIGN.md §13): per-sample
    // truncated-BPTT steps, and generation rolls (re-featurize + reseed)
    let reservoir_updates = metrics.counter_labelled("reservoir_updates_total", &labels);
    let refeaturizes = metrics.counter_labelled("refeaturize_total", &labels);
    // drain-batch observability (DESIGN.md §14): `batch_size` records
    // one sample per drain cycle with the cycle's request count encoded
    // as microseconds (exact through `record_secs`: n·1e-6 s = n µs), so
    // `count` = drain cycles and `mean·count` = requests; `batch_splits`
    // counts planned items that re-ran per-call after a mid-batch
    // generation roll
    let batch_size = metrics.histogram_labelled("batch_size", &labels);
    let batch_splits = metrics.counter_labelled("batch_splits_total", &labels);
    // fault model (DESIGN.md §15)
    let request_panics = metrics.counter_labelled("request_panics_total", &labels);
    let plan_panics = metrics.counter_labelled("plan_panics_total", &labels);
    let nonfinite_q = metrics.counter_labelled("nonfinite_quarantined_total", &labels);
    let ckpt_writes = metrics.counter_labelled("checkpoint_writes_total", &labels);
    let ckpt_write_errs = metrics.counter_labelled("checkpoint_write_errors_total", &labels);
    // per-stage latency histograms fed by the trace spans (DESIGN.md
    // §17): indexed by `Stage`, so span totals land in the same log₂
    // buckets the Prometheus exposition renders
    let stage_hists: [Arc<Histogram>; trace::N_STAGES] = std::array::from_fn(|i| {
        let stage_labels: [(&str, &str); 2] = [
            ("shard", shard_label.as_str()),
            ("stage", Stage::ALL[i].name()),
        ];
        metrics.histogram_labelled("stage_latency", &stage_labels)
    });
    // shared-datapath generation watermark: a quantized engine bumps it
    // exactly when its f32 fallback flips either way (journaled below)
    let mut engine_gen = engine.generation();

    let max_batch = cfg.max_batch.max(1);
    let mut batch: Vec<Envelope> = Vec::with_capacity(max_batch);
    // plan[i]: Some(tag) when batch[i]'s features were pre-extracted
    let mut plan: Vec<Option<PlanTag>> = Vec::with_capacity(max_batch);
    // grow-only per-lane feature buffers (r̃ per planned request)
    let mut feat_bufs: Vec<Vec<f32>> = Vec::new();
    // session ids touched by the current drain cycle (LRU clock input)
    let mut touched: Vec<u64> = Vec::with_capacity(max_batch);

    // with the idle clock armed, the blocking recv gains a timeout so
    // a quiet shard still sweeps; otherwise the loop stays a plain
    // recv with zero overhead for non-hibernating servers
    let sweep = hib.as_ref().and_then(ShardHibernator::sweep_interval);
    if let Some(h) = hib.as_ref() {
        h.report_resident(sessions.len());
    }
    loop {
        let first = if let Some(interval) = sweep {
            match rx.recv_timeout(interval) {
                Ok(env) => env,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Some(h) = hib.as_mut() {
                        h.sweep_idle(&mut sessions);
                        h.report_resident(sessions.len());
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match rx.recv() {
                Ok(env) => env,
                Err(_) => break,
            }
        };
        batch.clear();
        batch.push(first);
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(env) => batch.push(env),
                Err(_) => break,
            }
        }
        batch_size.record_secs(batch.len() as f64 * 1e-6);
        // the drain boundary: queue_wait for every envelope of this
        // cycle ends here, and the shared cycle spans start
        let drained_at = Instant::now();
        let depth = batch.len().min(u16::MAX as usize) as u16;

        // ---- rehydrate: any requested session parked in the store
        // comes back *before* planning, so the batched feature sweep
        // and the per-call paths both see it resident — its next
        // responses are bitwise-equal to never having hibernated
        if let Some(h) = hib.as_mut() {
            touched.clear();
            for env in &batch {
                if let Some(id) = env.req.session_id() {
                    touched.push(id);
                    if !sessions.contains_key(&id) && h.knows(id) {
                        if let Some(sess) = h.rehydrate(id, &cfg.session) {
                            sessions.insert(id, sess);
                        }
                    }
                }
            }
        }

        // ---- plan: decide which requests can share one batched sweep.
        // A panic inside the sweep only costs the plan — every lane
        // falls back to the per-call path, which carries its own guard.
        plan.clear();
        let plan_sw = Instant::now();
        let mut forward_us = 0u64;
        let planned = catch_unwind(AssertUnwindSafe(|| {
            plan_batch(&batch, &sessions, engine.as_ref(), &mut plan, &mut feat_bufs)
        }));
        match planned {
            Ok(sweep_us) => forward_us = sweep_us,
            Err(payload) => {
                if payload.is::<ShardKill>() {
                    resume_unwind(payload);
                }
                plan_panics.inc();
                plan.clear();
                plan.resize(batch.len(), None);
            }
        }
        // planning minus the sweep = the `plan` stage; the sweep itself
        // is `batch_forward` — both attributed in full to every request
        // of the cycle (each one waited for them)
        let plan_us = (plan_sw.elapsed().as_micros() as u64).saturating_sub(forward_us);

        // ---- process: strict arrival order, batched features where
        // still valid
        for (idx, env) in batch.drain(..).enumerate() {
            let Envelope {
                req,
                reply,
                trace,
                enqueued,
            } = env;
            req_counter.inc();
            let kind = req.kind_code();
            // open the span accumulator: queue residency and the shared
            // cycle spans are attributed to every request of the cycle
            trace::begin();
            trace::add_stage_us(
                Stage::QueueWait,
                drained_at.saturating_duration_since(enqueued).as_micros() as u64,
            );
            trace::add_stage_us(Stage::Plan, plan_us);
            trace::add_stage_us(Stage::BatchForward, forward_us);
            let session_id = req.session_id();
            let mutating = matches!(req, Request::Labelled { .. } | Request::Finalize { .. });
            match &req {
                Request::Shutdown => {
                    // Final snapshot at a well-defined boundary (every
                    // request accepted before the marker is in it), then
                    // ack the drain and keep serving stragglers until
                    // the server drops our sender and `recv` disconnects.
                    if let Some(ck) = ckpt.as_mut() {
                        let _span = trace::span(Stage::Checkpoint);
                        match ck.write_now(sessions.values()) {
                            Ok(()) => {
                                ckpt_writes.inc();
                                events.push(
                                    EventKind::CheckpointWrite,
                                    shard as u32,
                                    NO_SESSION,
                                    format!("final checkpoint ({} sessions)", sessions.len()),
                                );
                            }
                            Err(e) => {
                                ckpt_write_errs.inc();
                                events.push(
                                    EventKind::CheckpointError,
                                    shard as u32,
                                    NO_SESSION,
                                    format!("final checkpoint failed: {e}"),
                                );
                                log_warn!("shard {shard}: final checkpoint failed: {e}");
                            }
                        }
                    }
                    // park everything AFTER the final checkpoint: on
                    // restart the colliding copies carry equal mutation
                    // stamps and the tie keeps the checkpoint record.
                    // Stragglers racing in behind the marker rehydrate
                    // on touch like any other cold session.
                    if let Some(h) = hib.as_mut() {
                        let _span = trace::span(Stage::Checkpoint);
                        h.hibernate_all(&mut sessions);
                        h.report_resident(sessions.len());
                    }
                    finish_request(
                        reply,
                        Response::Bye,
                        trace,
                        enqueued,
                        kind,
                        NO_SESSION,
                        shard as u32,
                        depth,
                        &stage_hists,
                        &hub,
                    );
                    continue;
                }
                // unreachable through `call`/`try_call` (answered inline
                // by the server handle); kept so a queued Stats still works
                Request::Stats => {
                    finish_request(
                        reply,
                        Response::StatsText(metrics.render()),
                        trace,
                        enqueued,
                        kind,
                        NO_SESSION,
                        shard as u32,
                        depth,
                        &stage_hists,
                        &hub,
                    );
                    continue;
                }
                // the readiness probe: answering proves this queue
                // still drains (the prober usually drops the receiver)
                Request::Ping => {
                    finish_request(
                        reply,
                        Response::Bye,
                        trace,
                        enqueued,
                        kind,
                        NO_SESSION,
                        shard as u32,
                        depth,
                        &stage_hists,
                        &hub,
                    );
                    continue;
                }
                // answered inline by the server handle on the public
                // paths; kept here so a directly-queued probe still works
                Request::Traces { n } => {
                    finish_request(
                        reply,
                        Response::Traces(hub.last_json(*n)),
                        trace,
                        enqueued,
                        kind,
                        NO_SESSION,
                        shard as u32,
                        depth,
                        &stage_hists,
                        &hub,
                    );
                    continue;
                }
                Request::Events { n } => {
                    finish_request(
                        reply,
                        Response::Events(events.last_json(*n)),
                        trace,
                        enqueued,
                        kind,
                        NO_SESSION,
                        shard as u32,
                        depth,
                        &stage_hists,
                        &hub,
                    );
                    continue;
                }
                _ => {}
            }
            let guarded = catch_unwind(AssertUnwindSafe(|| match req {
                // handled before the guard; kept total for the compiler
                Request::Shutdown
                | Request::Stats
                | Request::Ping
                | Request::Traces { .. }
                | Request::Events { .. } => Response::Bye,
                Request::Labelled { session, sample } => {
                    let sess = sessions.entry(session).or_insert_with(|| {
                        Session::new(session, cfg.session.clone(), cfg.seed)
                    });
                    // footgun fix: an earlier item of this drain batch
                    // may have rolled the session generation (Adapted /
                    // fallback retrain) or the shared engine datapath —
                    // planned features are then stale and must NOT be
                    // folded (no cross-generation feature mixing)
                    let pre = plan[idx].filter(|t| {
                        let fresh = sess.generation() == t.session_gen
                            && sess.engine_generation() == t.engine_gen
                            && engine.generation() == t.engine_gen;
                        if !fresh {
                            batch_splits.inc();
                        }
                        fresh
                    });
                    let q_before = sess.quarantine_events();
                    let sw = crate::util::timer::Stopwatch::start();
                    let outcome = match pre {
                        Some(t) => sess.feed_labelled_with_features(
                            engine.as_ref(),
                            sample,
                            &feat_bufs[t.lane],
                        ),
                        None => sess.feed_labelled(engine.as_ref(), sample),
                    };
                    // non-finite features quarantined inside the session
                    // (reseed + batch fallback) surface here as a counter
                    let quarantined = sess.quarantine_events().saturating_sub(q_before);
                    if quarantined > 0 {
                        nonfinite_q.add(quarantined);
                        events.push(
                            EventKind::Quarantine,
                            shard as u32,
                            session,
                            format!("{quarantined} non-finite feature quarantine(s)"),
                        );
                    }
                    match outcome {
                        Ok(FeedOutcome::Buffered(n)) => Response::Accepted {
                            phase: sess.phase.name(),
                            buffered: n,
                        },
                        Ok(FeedOutcome::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }) => {
                            train_hist.record_secs(sw.elapsed_secs());
                            trainings.inc();
                            Response::Trained {
                                p,
                                q,
                                beta,
                                train_seconds,
                            }
                        }
                        Ok(FeedOutcome::Observed {
                            updates,
                            window,
                            reservoir_step,
                        }) => {
                            online_updates.inc();
                            if reservoir_step {
                                reservoir_updates.inc();
                            }
                            Response::Observed { updates, window }
                        }
                        Ok(FeedOutcome::Adapted {
                            generation,
                            p,
                            q,
                            updates,
                            reservoir_step,
                        }) => {
                            // the rolling sample was folded too
                            online_updates.inc();
                            if reservoir_step {
                                reservoir_updates.inc();
                            }
                            refeaturizes.inc();
                            events.push(
                                EventKind::GenerationRoll,
                                shard as u32,
                                session,
                                format!("session generation {generation}"),
                            );
                            Response::Adapted {
                                generation,
                                p,
                                q,
                                updates,
                            }
                        }
                        Ok(FeedOutcome::Rejected(msg)) => {
                            rejected.inc();
                            Response::Rejected(msg)
                        }
                        Err(e) => {
                            // engine fault mid-feed: state may be torn —
                            // degrade so the next sample retrains from
                            // the buffered window instead of folding on
                            sess.flag_degraded();
                            Response::Error {
                                kind: ErrorKind::Engine,
                                detail: format!("{e:#}"),
                            }
                        }
                    }
                }
                Request::Infer { session, sample } => match sessions.get_mut(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => {
                        let pre = plan[idx].filter(|t| {
                            let fresh = sess.generation() == t.session_gen
                                && sess.engine_generation() == t.engine_gen
                                && engine.generation() == t.engine_gen;
                            if !fresh {
                                batch_splits.inc();
                            }
                            fresh
                        });
                        let sw = crate::util::timer::Stopwatch::start();
                        let result = match pre {
                            Some(t) => {
                                // freshness implies sync_generation is a
                                // no-op — the engine datapath equals what
                                // the factor was seeded under
                                sess.infer_with_features(engine.as_ref(), &feat_bufs[t.lane])
                            }
                            None => {
                                // track shared-datapath changes even on
                                // infer-only traffic (no-op unless the
                                // engine generation moved)
                                match sess.sync_generation(engine.as_ref()) {
                                    Ok(refeat) => {
                                        if refeat.is_some() {
                                            refeaturizes.inc();
                                        }
                                        sess.infer(engine.as_ref(), &sample)
                                    }
                                    Err(e) => Err(InferError::Engine(e)),
                                }
                            }
                        };
                        match result {
                            Ok((class, scores)) => {
                                if scores.iter().all(|s| s.is_finite()) {
                                    infer_hist.record_secs(sw.elapsed_secs());
                                    inferences.inc();
                                    Response::Prediction { class, scores }
                                } else {
                                    // non-finite scores never reach the
                                    // caller as a Prediction: quarantine
                                    // and degrade so the next labelled
                                    // sample reseeds via batch retrain
                                    sess.flag_degraded();
                                    nonfinite_q.inc();
                                    events.push(
                                        EventKind::Quarantine,
                                        shard as u32,
                                        session,
                                        "non-finite inference scores quarantined".into(),
                                    );
                                    Response::Error {
                                        kind: ErrorKind::NonFinite,
                                        detail: "non-finite scores quarantined; \
                                                 session flagged for retrain"
                                            .into(),
                                    }
                                }
                            }
                            Err(e @ InferError::NotServing { .. }) => {
                                Response::Rejected(e.to_string())
                            }
                            Err(InferError::Engine(e)) => {
                                sess.flag_degraded();
                                Response::Error {
                                    kind: ErrorKind::Engine,
                                    detail: format!("{e:#}"),
                                }
                            }
                        }
                    }
                },
                Request::Finalize { session } => match sessions.get_mut(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => match sess.finalize(engine.as_ref()) {
                        Ok(FeedOutcome::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }) => Response::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        },
                        Ok(FeedOutcome::Rejected(msg)) => Response::Rejected(msg),
                        // finalize always runs the batch pipeline
                        Ok(
                            FeedOutcome::Buffered(_)
                            | FeedOutcome::Observed { .. }
                            | FeedOutcome::Adapted { .. },
                        ) => Response::Rejected("internal: unexpected finalize outcome".into()),
                        Err(e) => {
                            sess.flag_degraded();
                            Response::Error {
                                kind: ErrorKind::Engine,
                                detail: format!("{e:#}"),
                            }
                        }
                    },
                },
            }));
            // map the guard: Ok replies in order, Err isolates the
            // panic to this one request
            let resp = match guarded {
                Ok(resp) => resp,
                Err(payload) => {
                    if payload.is::<ShardKill>() {
                        // deliberate kill (fault harness / unrecoverable):
                        // die loudly and let the supervisor bury us
                        resume_unwind(payload);
                    }
                    request_panics.inc();
                    if let Some(id) = session_id {
                        if let Some(sess) = sessions.get_mut(&id) {
                            sess.flag_degraded();
                        }
                    }
                    let detail = panic_message(payload.as_ref());
                    Response::Error {
                        kind: ErrorKind::Panic,
                        detail: format!("panic isolated on shard {shard}: {detail}"),
                    }
                }
            };
            // the cadence checkpoint runs before the reply ships so its
            // cost lands in this request's `checkpoint` span (the next
            // request could not start any earlier either way)
            if mutating {
                if let Some(ck) = ckpt.as_mut() {
                    // cadence counts mutating *requests* (even rejected
                    // ones) — a cheap, deterministic trigger
                    if ck.note_mutation() {
                        let _span = trace::span(Stage::Checkpoint);
                        match ck.write_now(sessions.values()) {
                            Ok(()) => {
                                ckpt_writes.inc();
                                events.push(
                                    EventKind::CheckpointWrite,
                                    shard as u32,
                                    session_id.unwrap_or(NO_SESSION),
                                    format!("cadence checkpoint ({} sessions)", sessions.len()),
                                );
                            }
                            Err(e) => {
                                ckpt_write_errs.inc();
                                events.push(
                                    EventKind::CheckpointError,
                                    shard as u32,
                                    session_id.unwrap_or(NO_SESSION),
                                    format!("checkpoint write failed: {e}"),
                                );
                                log_warn!("shard {shard}: checkpoint write failed: {e}");
                            }
                        }
                    }
                }
            }
            // journal shared-datapath generation moves: a quantized
            // engine bumps its generation exactly when the f32 fallback
            // flips (either way), so the flip direction is `fell_back`
            let gen_now = engine.generation();
            if gen_now != engine_gen {
                engine_gen = gen_now;
                let flip = if engine.fell_back() {
                    EventKind::QuantFallback
                } else {
                    EventKind::QuantRecover
                };
                events.push(
                    flip,
                    shard as u32,
                    session_id.unwrap_or(NO_SESSION),
                    format!("engine datapath generation {gen_now}"),
                );
            }
            finish_request(
                reply,
                resp,
                trace,
                enqueued,
                kind,
                session_id.unwrap_or(NO_SESSION),
                shard as u32,
                depth,
                &stage_hists,
                &hub,
            );
        }

        // ---- hibernation bookkeeping: stamp the LRU clock for every
        // session this cycle touched, evict past the resident cap
        // (least-recently-touched first), publish the level gauges
        if let Some(h) = hib.as_mut() {
            for &id in &touched {
                if sessions.contains_key(&id) {
                    h.note_touch(id);
                }
            }
            h.enforce_cap(&mut sessions);
            h.report_resident(sessions.len());
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::faulty::{FaultSpec, FaultyEngine};
    use crate::data::profiles::Profile;
    use crate::data::synth;

    fn server_with_shards(shards: usize) -> (Server, crate::data::dataset::Dataset) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 20,
            test: 10,
            t_min: 10,
            t_max: 12,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            13,
        );
        let mut scfg = SessionConfig::new(2, 2, 20);
        scfg.train.nx = 8;
        scfg.train.epochs = 3;
        scfg.train.res_decay_epochs = vec![2];
        scfg.train.out_decay_epochs = vec![2];
        let cfg = ServerConfig {
            queue_cap: 64,
            seed: 0xFEED,
            shards,
            max_batch: 8,
            ..ServerConfig::new(scfg)
        };
        (Server::spawn(Box::new(NativeEngine::new(8, 2)), cfg), ds)
    }

    fn server() -> (Server, crate::data::dataset::Dataset) {
        server_with_shards(2)
    }

    #[test]
    fn end_to_end_train_then_serve() {
        let (srv, ds) = server();
        let mut last = None;
        for s in &ds.train {
            last = Some(
                srv.call(Request::Labelled {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap(),
            );
        }
        assert!(matches!(last, Some(Response::Trained { .. })), "{last:?}");
        let mut correct = 0;
        for s in &ds.test {
            match srv
                .call(Request::Infer {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap()
            {
                Response::Prediction { class, .. } => {
                    if class == s.label {
                        correct += 1;
                    }
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(correct >= 7, "{correct}/10");
        let stats = srv.call(Request::Stats).unwrap();
        match stats {
            Response::StatsText(t) => {
                assert!(t.contains("inferences_total 10"), "{t}");
                assert!(t.contains("trainings_total 1"), "{t}");
                // session 1 lives on shard 1 % 2
                assert!(t.contains("inferences_total{shard=\"1\"} 10"), "{t}");
            }
            other => panic!("{other:?}"),
        }
        srv.shutdown();
    }

    #[test]
    fn sessions_are_isolated() {
        let (srv, ds) = server();
        // session 2 never trained → inference rejected
        for s in ds.train.iter().take(3) {
            srv.call(Request::Labelled {
                session: 2,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv
            .call(Request::Infer {
                session: 2,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        // unknown session
        let r = srv
            .call(Request::Infer {
                session: 99,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)));
        srv.shutdown();
    }

    #[test]
    fn finalize_then_predict() {
        let (srv, ds) = server();
        for s in ds.train.iter().take(10) {
            srv.call(Request::Labelled {
                session: 5,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv.call(Request::Finalize { session: 5 }).unwrap();
        assert!(matches!(r, Response::Trained { .. }), "{r:?}");
        let r = srv
            .call(Request::Infer {
                session: 5,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }));
        srv.shutdown();
    }

    #[test]
    fn shard_count_clamps_to_at_least_one() {
        let (srv, ds) = server_with_shards(0);
        assert_eq!(srv.shards(), 1);
        let r = srv
            .call(Request::Labelled {
                session: 7,
                sample: ds.train[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Accepted { .. }), "{r:?}");
        srv.shutdown();
    }

    #[test]
    fn same_session_same_shard_across_requests() {
        // a session fed on a 4-shard server trains and serves exactly as
        // on a single shard — routing is stable
        let (srv, ds) = server_with_shards(4);
        assert_eq!(srv.shards(), 4);
        for s in &ds.train {
            srv.call(Request::Labelled {
                session: 6,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv
            .call(Request::Infer {
                session: 6,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }), "{r:?}");
        srv.shutdown();
    }

    #[test]
    fn engine_error_maps_to_typed_error_response() {
        // an always-erroring engine: Collect feeds buffer fine (no engine
        // work), but the 20th sample triggers training, which fails — the
        // reply must be the typed Error{Engine}, never a panic or a hang
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 20,
            test: 10,
            t_min: 10,
            t_max: 12,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            13,
        );
        let mut scfg = SessionConfig::new(2, 2, 20);
        scfg.train.nx = 8;
        scfg.train.epochs = 3;
        let cfg = ServerConfig {
            queue_cap: 64,
            seed: 0xFEED,
            shards: 1,
            ..ServerConfig::new(scfg)
        };
        let engine = FaultyEngine::new(
            Box::new(NativeEngine::new(8, 2)),
            FaultSpec {
                p_error: 1.0,
                ..FaultSpec::default()
            },
        );
        let srv = Server::spawn(Box::new(engine), cfg);
        let mut last = None;
        for s in &ds.train {
            last = Some(
                srv.call(Request::Labelled {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap(),
            );
        }
        assert!(
            matches!(
                last,
                Some(Response::Error {
                    kind: ErrorKind::Engine,
                    ..
                })
            ),
            "{last:?}"
        );
        // the server is still alive and answering
        let r = srv.call(Request::Stats).unwrap();
        assert!(matches!(r, Response::StatsText(_)));
        srv.shutdown();
    }

    #[test]
    fn shutdown_via_call_is_rejected_not_partial_drain() {
        // the documented footgun: Shutdown through the public paths
        // would drain exactly one shard; all three now refuse it with
        // a typed Rejected and the server keeps serving
        let (srv, ds) = server();
        let r = srv.call(Request::Shutdown).unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        let r = srv
            .call_timeout(Request::Shutdown, Duration::from_secs(1))
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        let rrx = srv.try_call(Request::Shutdown).unwrap().unwrap();
        assert!(matches!(rrx.recv().unwrap(), Response::Rejected(_)));
        let r = srv
            .call(Request::Labelled {
                session: 1,
                sample: ds.train[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Accepted { .. }), "{r:?}");
        srv.shutdown();
    }

    #[test]
    fn traces_events_ping_and_readiness() {
        let (srv, ds) = server();
        for s in ds.train.iter().take(3) {
            srv.call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap();
        }
        // a request's trace is recorded just after its reply ships, so
        // after 3 completed calls at least 2 records are durably visible
        match srv.call(Request::Traces { n: 10 }).unwrap() {
            Response::Traces(json) => {
                assert!(json.lines().count() >= 2, "{json}");
                assert!(json.contains("\"kind\":\"labelled\""), "{json}");
                assert!(json.contains("\"stages_us\""), "{json}");
            }
            other => panic!("{other:?}"),
        }
        let r = srv.call(Request::Events { n: 10 }).unwrap();
        assert!(matches!(r, Response::Events(_)), "{r:?}");
        // Ping is the internal readiness probe: public paths refuse it
        let r = srv.call(Request::Ping).unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        assert_eq!(srv.shards_active(), srv.shards() as i64);
        assert!(srv.readiness().is_ok());
        srv.shutdown();
    }

    #[test]
    fn call_timeout_times_out_instead_of_hanging() {
        // no faults: a healthy server answers well inside the deadline
        let (srv, ds) = server();
        let r = srv
            .call_timeout(
                Request::Labelled {
                    session: 1,
                    sample: ds.train[0].clone(),
                },
                Duration::from_secs(10),
            )
            .unwrap();
        assert!(matches!(r, Response::Accepted { .. }), "{r:?}");
        srv.shutdown();
    }
}
