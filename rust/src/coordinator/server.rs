//! The event loop: bounded request queue, per-session router, worker
//! execution, metrics — Rust owns the process (no tokio; see
//! `util::runtimex`).
//!
//! Sessions are sharded by id across the router's map; requests carry a
//! reply channel. Backpressure is two-level: the global bounded queue
//! (`try_submit` refuses when saturated) and each session's buffer cap.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::Result;

use super::engine::Engine;
use super::protocol::{Request, Response};
use super::session::{FeedOutcome, Session, SessionConfig};
use crate::util::metrics::Registry;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// template for newly-created sessions
    pub session: SessionConfig,
    /// request queue capacity (global backpressure)
    pub queue_cap: usize,
    pub seed: u64,
}

/// Handle to a running server (owns the event-loop thread).
pub struct Server {
    tx: mpsc::SyncSender<(Request, mpsc::Sender<Response>)>,
    handle: Option<thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
}

impl Server {
    /// Spawn the event loop over an engine.
    pub fn spawn(engine: Box<dyn Engine>, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::sync_channel::<(Request, mpsc::Sender<Response>)>(cfg.queue_cap);
        let metrics = Arc::new(Registry::default());
        let m = Arc::clone(&metrics);
        let handle = thread::spawn(move || event_loop(engine, cfg, rx, m));
        Server {
            tx,
            handle: Some(handle),
            metrics,
        }
    }

    /// Send a request and wait for the response.
    pub fn call(&self, req: Request) -> Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send((req, rtx))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx.recv()?)
    }

    /// Non-blocking send; `Err` means the queue is saturated
    /// (backpressure) — the caller should retry or shed load.
    pub fn try_call(&self, req: Request) -> Result<Option<mpsc::Receiver<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        match self.tx.try_send((req, rtx)) {
            Ok(()) => Ok(Some(rrx)),
            Err(mpsc::TrySendError::Full(_)) => Ok(None),
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(anyhow::anyhow!("server stopped"))
            }
        }
    }

    /// Graceful shutdown (drains the queue).
    pub fn shutdown(mut self) {
        let _ = self.call(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let (rtx, _rrx) = mpsc::channel();
            let _ = self.tx.send((Request::Shutdown, rtx));
            let _ = h.join();
        }
    }
}

fn event_loop(
    engine: Box<dyn Engine>,
    cfg: ServerConfig,
    rx: mpsc::Receiver<(Request, mpsc::Sender<Response>)>,
    metrics: Arc<Registry>,
) {
    let sessions: Mutex<BTreeMap<u64, Session>> = Mutex::new(BTreeMap::new());
    let req_counter = metrics.counter("requests_total");
    let infer_hist = metrics.histogram("infer_latency");
    let train_hist = metrics.histogram("train_latency");

    while let Ok((req, reply)) = rx.recv() {
        req_counter.inc();
        let resp = match req {
            Request::Shutdown => {
                let _ = reply.send(Response::Bye);
                break;
            }
            Request::Stats => Response::StatsText(metrics.render()),
            Request::Labelled { session, sample } => {
                let mut map = sessions.lock().unwrap();
                let sess = map.entry(session).or_insert_with(|| {
                    Session::new(session, cfg.session.clone(), cfg.seed)
                });
                let sw = crate::util::timer::Stopwatch::start();
                match sess.feed_labelled(engine.as_ref(), sample) {
                    Ok(FeedOutcome::Buffered(n)) => Response::Accepted {
                        phase: sess.phase.name(),
                        buffered: n,
                    },
                    Ok(FeedOutcome::Trained {
                        p,
                        q,
                        beta,
                        train_seconds,
                    }) => {
                        train_hist.record_secs(sw.elapsed_secs());
                        metrics.counter("trainings_total").inc();
                        Response::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }
                    }
                    Ok(FeedOutcome::Rejected(msg)) => {
                        metrics.counter("rejected_total").inc();
                        Response::Rejected(msg)
                    }
                    Err(e) => Response::Rejected(format!("engine error: {e:#}")),
                }
            }
            Request::Infer { session, sample } => {
                let map = sessions.lock().unwrap();
                match map.get(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => {
                        let sw = crate::util::timer::Stopwatch::start();
                        match sess.infer(engine.as_ref(), &sample) {
                            Ok(Ok((class, scores))) => {
                                infer_hist.record_secs(sw.elapsed_secs());
                                metrics.counter("inferences_total").inc();
                                Response::Prediction { class, scores }
                            }
                            Ok(Err(msg)) => Response::Rejected(msg),
                            Err(e) => Response::Rejected(format!("engine error: {e:#}")),
                        }
                    }
                }
            }
            Request::Finalize { session } => {
                let mut map = sessions.lock().unwrap();
                match map.get_mut(&session) {
                    None => Response::Rejected(format!("unknown session {session}")),
                    Some(sess) => match sess.finalize(engine.as_ref()) {
                        Ok(FeedOutcome::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        }) => Response::Trained {
                            p,
                            q,
                            beta,
                            train_seconds,
                        },
                        Ok(FeedOutcome::Rejected(msg)) => Response::Rejected(msg),
                        Ok(FeedOutcome::Buffered(_)) => unreachable!(),
                        Err(e) => Response::Rejected(format!("engine error: {e:#}")),
                    },
                }
            }
        };
        let _ = reply.send(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    fn server() -> (Server, crate::data::dataset::Dataset) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 20,
            test: 10,
            t_min: 10,
            t_max: 12,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            13,
        );
        let mut scfg = SessionConfig::new(2, 2, 20);
        scfg.train.nx = 8;
        scfg.train.epochs = 3;
        scfg.train.res_decay_epochs = vec![2];
        scfg.train.out_decay_epochs = vec![2];
        let cfg = ServerConfig {
            session: scfg,
            queue_cap: 64,
            seed: 0xFEED,
        };
        (Server::spawn(Box::new(NativeEngine::new(8, 2)), cfg), ds)
    }

    #[test]
    fn end_to_end_train_then_serve() {
        let (srv, ds) = server();
        let mut last = None;
        for s in &ds.train {
            last = Some(
                srv.call(Request::Labelled {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap(),
            );
        }
        assert!(matches!(last, Some(Response::Trained { .. })), "{last:?}");
        let mut correct = 0;
        for s in &ds.test {
            match srv
                .call(Request::Infer {
                    session: 1,
                    sample: s.clone(),
                })
                .unwrap()
            {
                Response::Prediction { class, .. } => {
                    if class == s.label {
                        correct += 1;
                    }
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(correct >= 7, "{correct}/10");
        let stats = srv.call(Request::Stats).unwrap();
        match stats {
            Response::StatsText(t) => {
                assert!(t.contains("inferences_total 10"), "{t}");
                assert!(t.contains("trainings_total 1"), "{t}");
            }
            other => panic!("{other:?}"),
        }
        srv.shutdown();
    }

    #[test]
    fn sessions_are_isolated() {
        let (srv, ds) = server();
        // session 2 never trained → inference rejected
        for s in ds.train.iter().take(3) {
            srv.call(Request::Labelled {
                session: 2,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv
            .call(Request::Infer {
                session: 2,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)), "{r:?}");
        // unknown session
        let r = srv
            .call(Request::Infer {
                session: 99,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Rejected(_)));
        srv.shutdown();
    }

    #[test]
    fn finalize_then_predict() {
        let (srv, ds) = server();
        for s in ds.train.iter().take(10) {
            srv.call(Request::Labelled {
                session: 5,
                sample: s.clone(),
            })
            .unwrap();
        }
        let r = srv.call(Request::Finalize { session: 5 }).unwrap();
        assert!(matches!(r, Response::Trained { .. }), "{r:?}");
        let r = srv
            .call(Request::Infer {
                session: 5,
                sample: ds.test[0].clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Prediction { .. }));
        srv.shutdown();
    }
}
